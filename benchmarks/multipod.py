import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Multi-pod delta analysis: what the second pod costs, and what int8
cross-pod gradient compression buys back.

For a train cell, lower the step on the single-pod (16,16) and multi-pod
(2,16,16) meshes and diff the collective inventories; then re-lower the
multi-pod step with `grad_compression="int8"` and measure the cross-pod
traffic reduction. The pod axis is pure DP, so the delta is exactly the
gradient synchronization — the slow-DCN traffic the compression targets.

    PYTHONPATH=src:. python -m benchmarks.multipod --arch tinyllama-1.1b
"""
import argparse
import dataclasses
import json
from pathlib import Path

import jax


def lower_cell(acfg, shape, mesh):
    from repro.launch.dryrun import build_step, parse_collectives
    from repro.distributed.sharding import mesh_context
    with mesh_context(mesh):
        fn, args, sh, model, don, _ = build_step(acfg, shape, mesh)
        co = jax.jit(fn, in_shardings=sh, donate_argnums=don
                     ).lower(*args).compile()
    tot, cnt = parse_collectives(co.as_text())
    return tot, cnt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--out", default="results/multipod")
    args = ap.parse_args()
    from repro.configs import get_config, shape_by_name
    from repro.launch.mesh import make_production_mesh

    acfg = get_config(args.arch)
    shape = shape_by_name("train_4k")
    single = make_production_mesh(multi_pod=False)
    multi = make_production_mesh(multi_pod=True)

    t_single, c_single = lower_cell(acfg, shape, single)
    t_multi, c_multi = lower_cell(acfg, shape, multi)
    acfg_c = dataclasses.replace(
        acfg, parallel=dataclasses.replace(acfg.parallel,
                                           grad_compression="int8"))
    t_comp, c_comp = lower_cell(acfg_c, shape, multi)

    def tot(d):
        return sum(d.values())
    rec = {
        "arch": args.arch,
        "single_pod_bytes": t_single, "single_pod_counts": c_single,
        "multi_pod_bytes": t_multi, "multi_pod_counts": c_multi,
        "multi_pod_int8_bytes": t_comp, "multi_pod_int8_counts": c_comp,
        "pod_axis_delta_bytes": tot(t_multi) - tot(t_single),
        "int8_savings_bytes": tot(t_multi) - tot(t_comp),
    }
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / f"{args.arch}.json").write_text(json.dumps(rec, indent=1))
    print(f"{args.arch} train_4k collective bytes (per compiled module):")
    print(f"  single pod : {tot(t_single)/2**30:8.2f} GiB  {c_single}")
    print(f"  multi pod  : {tot(t_multi)/2**30:8.2f} GiB  {c_multi}")
    print(f"  multi+int8 : {tot(t_comp)/2**30:8.2f} GiB  {c_comp}")
    print(f"  pod-axis delta {rec['pod_axis_delta_bytes']/2**30:.2f} GiB; "
          f"int8 saves {rec['int8_savings_bytes']/2**30:.2f} GiB of it")


if __name__ == "__main__":
    main()
