import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (§Roofline): unit-decomposition over layer counts.

cost_analysis() counts lax.scan bodies ONCE (probed), so whole-step compiles
under-count layer work by the trip count. Methodology here:

  1. For each (arch, shape) lower the SAME step function at per-segment depth
     r=1 and r=2 on the production mesh (identical shardings). The difference
     is the exact per-super-block cost (slope); the r=1 cost minus the slope
     is the intercept (embedding, head, optimizer, snapshot write).
  2. total = intercept + sum_over_segments(slope_kind x real_count), with the
     gradient-accumulation factor multiplying the in-scan (layer+embed/head)
     part only (optimizer/DMD sit outside the microbatch scan; their cost is
     measured separately and NOT multiplied).
  3. Collective bytes per device get the same slope treatment; parsed from
     HLO text with direction multipliers (AR x2, AG/RS/A2A/CP x1 of result
     bytes — DCN/ICI convention documented in EXPERIMENTS.md).

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI.
Terms are SECONDS PER STEP per device (cost_analysis of the partitioned
module reports shard-local work):

  t_compute    = flops_per_device / 197e12
  t_memory     = bytes_per_device / 819e9
  t_collective = collective_bytes_per_device / 50e9

Usage:
  PYTHONPATH=src:. python -m benchmarks.roofline [--arch A] [--shape S]
      [--out results/roofline] [--mesh single]
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = {"single": 256, "multi": 512}

# direction multipliers on RESULT bytes -> bytes on the wire per device
COLL_MULT = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
             "all-to-all": 1.0, "collective-permute": 1.0}


def scaled_config(acfg, reps: int):
    """Same-family config with `reps` repetitions of each segment kind."""
    mc = acfg.model
    kw = {}
    if mc.family == "encdec":
        kw = {"n_layers": reps, "n_encoder_layers": reps}
    elif mc.family == "hybrid":
        kw = {"n_layers": mc.shared_attn_every * reps}
    elif mc.moe.n_experts > 0 and mc.moe.moe_every == 2:
        kw = {"n_layers": 2 * reps}
    elif mc.global_every > 0:
        kw = {"n_layers": mc.global_every * reps}
    else:
        kw = {"n_layers": reps}
    return dataclasses.replace(acfg, model=dataclasses.replace(mc, **kw))


def local_tail_config(acfg, reps: int):
    """gemma local-tail slope: local-window-only layers."""
    mc = dataclasses.replace(acfg.model, n_layers=reps, global_every=0)
    return dataclasses.replace(acfg, model=mc)


def half_batch(shape):
    import dataclasses as dc
    return dc.replace(shape, global_batch=max(shape.global_batch // 2, 1))


def measure(acfg, shape, mesh, ga_one: bool = True) -> dict:
    """Lower + compile one cell variant; return flops/bytes/collectives."""
    from repro.launch.dryrun import build_step, parse_collectives
    from repro.distributed.sharding import mesh_context
    if ga_one:
        acfg = dataclasses.replace(
            acfg, parallel=dataclasses.replace(acfg.parallel, grad_accum=1))
    with mesh_context(mesh):
        # scan_layers=False: unrolled layer stacks so cost_analysis sees every
        # layer (scan bodies are counted once regardless of trip count).
        fn, args, shardings, model, donate, _ = build_step(acfg, shape, mesh,
                                                        scan_layers=False)
        compiled = jax.jit(fn, in_shardings=shardings,
                           donate_argnums=donate).lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll, _ = parse_collectives(compiled.as_text())
    coll_bytes = sum(COLL_MULT.get(k, 1.0) * v for k, v in coll.items())
    return {"flops": float(ca.get("flops") or 0.0),
            "bytes": float(ca.get("bytes accessed") or 0.0),
            "coll_bytes": coll_bytes,
            "coll_detail": coll}


def measure_optimizer(acfg, mesh) -> dict:
    """Cost of the out-of-scan part: optimizer update on the full tree."""
    from repro.models.transformer import LanguageModel, init_params
    from repro.optim import make_optimizer
    from repro.distributed.sharding import mesh_context, partition_specs
    from repro.launch import inputs as inputs_mod
    model = LanguageModel(acfg.model)
    params = model.init(abstract=True)
    opt = make_optimizer(acfg.optimizer)
    opt_state = jax.eval_shape(opt.init, params)
    grads = jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)

    def update(g, s, p):
        u, s2 = opt.update(g, s, p, jnp.zeros((), jnp.int32))
        from repro.optim import apply_updates
        return apply_updates(p, u), s2

    with mesh_context(mesh):
        p_specs = partition_specs(params, mesh)
        sh = inputs_mod.shardings_of(p_specs, mesh)
        g_specs = jax.tree_util.tree_map(lambda s: s, sh)
        from repro.launch.inputs import state_specs
        from repro.train.state import TrainState
        st = TrainState(params, opt_state, jax.ShapeDtypeStruct((), jnp.int32),
                        None)
        full = inputs_mod.state_specs(st, mesh)
        compiled = jax.jit(update, in_shardings=(
            inputs_mod.shardings_of(full.params, mesh),
            inputs_mod.shardings_of(full.opt_state, mesh),
            inputs_mod.shardings_of(full.params, mesh)),
            donate_argnums=(1, 2)).lower(grads, opt_state, params).compile()
    ca = compiled.cost_analysis() or {}
    from repro.launch.dryrun import parse_collectives
    coll, _ = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops") or 0.0),
            "bytes": float(ca.get("bytes accessed") or 0.0),
            "coll_bytes": sum(COLL_MULT.get(k, 1) * v for k, v in coll.items())}


def measure_dmd(acfg, mesh) -> dict:
    """PER-STEP amortized DMD jump cost under the group schedule.

    Each schedule group g jumps once per cycle_g = m_g + cooldown_g steps,
    and the staggered jump program is masked to that group's leaves — so
    the per-step cost is sum_g cost(jump of group g alone) / cycle_g. Each
    group's jump is lowered separately (dmd_step with static groups=(g,));
    with one group this reduces to the old whole-jump / (m + cooldown)
    accounting. Returns the amortized totals plus per-group detail.
    """
    if not acfg.dmd.enabled:
        return {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0,
                "per_group": []}
    from repro.models.transformer import LanguageModel
    from repro.train.step import make_dmd_step
    from repro.train.state import TrainState
    from repro.optim import make_optimizer
    from repro.distributed.sharding import mesh_context
    from repro.launch import inputs as inputs_mod
    from repro.launch.dryrun import parse_collectives
    from repro.core.accelerator import DMDAccelerator
    model = LanguageModel(acfg.model)
    params = model.init(abstract=True)
    opt = make_optimizer(acfg.optimizer)
    opt_state = jax.eval_shape(opt.init, params)
    acc = DMDAccelerator(acfg.dmd, mesh=mesh,
                         stack_dims=model.param_stack_dims())
    # acc.init: the DEPLOYED snapshot layout — packed arenas + per-leaf
    # remainder (DESIGN.md §7) — so the roofline prices the same program
    # the trainer runs, not the per-leaf A/B oracle
    bufs = acc.init(params)
    state = TrainState(params, opt_state, jax.ShapeDtypeStruct((), jnp.int32),
                       bufs)
    step = make_dmd_step(acfg, mesh=mesh, acc=acc)
    total = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    per_group = []
    with mesh_context(mesh):
        st_specs = inputs_mod.state_specs(state, mesh,
                                          plans=acc.plans_for(params),
                                          arena=acc.arena_for(params))
        for g in acc.groups:
            # groups positional + static: pjit rejects kwargs when
            # in_shardings is given
            compiled = jax.jit(
                step, in_shardings=(
                    inputs_mod.shardings_of(st_specs, mesh), None),
                static_argnums=(2,), donate_argnums=(0,)).lower(
                    state, jnp.zeros((), jnp.float32),
                    (g.index,)).compile()
            ca = compiled.cost_analysis() or {}
            if isinstance(ca, (list, tuple)):    # older jaxlibs: one dict
                ca = ca[0] if ca else {}         # per executable
            coll, _ = parse_collectives(compiled.as_text())
            cost = {"flops": float(ca.get("flops") or 0.0),
                    "bytes": float(ca.get("bytes accessed") or 0.0),
                    "coll_bytes": sum(COLL_MULT.get(k, 1) * v
                                      for k, v in coll.items())}
            per_group.append({"group": g.name, "cycle": g.cycle, **cost})
            for k in total:
                total[k] += cost[k] / max(g.cycle, 1)
    total["per_group"] = per_group
    return total


def model_flops(acfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: 2*N_active per token."""
    import numpy as np
    from repro.models.transformer import LanguageModel
    mc = acfg.model
    model = LanguageModel(mc)
    params = model.init(abstract=True)

    def count(tree, pred=lambda p: True):
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            key = jax.tree_util.keystr(path)
            if pred(key):
                total += int(np.prod(leaf.shape))
        return total

    n_total = count(params)
    if mc.moe.n_experts > 0:
        n_expert = count(params, lambda k: "experts_" in k)
        n_active = (n_total - n_expert
                    + n_expert * mc.moe.top_k / mc.moe.n_experts)
    else:
        n_active = n_total
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 token/seq


def analyze_cell(arch: str, shape_name: str, mesh_kind: str = "single",
                 out_dir: Path = None, overrides=None) -> dict:
    from repro.configs import get_config, shape_by_name
    from repro.launch.mesh import make_production_mesh
    from repro.train.step import resolve_grad_accum
    from repro.models.transformer import segment_plan

    acfg = get_config(arch)
    if overrides:
        acfg = overrides(acfg)
    shape = shape_by_name(shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if shape_name not in acfg.shapes:
        rec["status"] = "skipped"
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    t0 = time.time()

    KEYS = ("flops", "bytes", "coll_bytes")
    c1 = measure(scaled_config(acfg, 1), shape, mesh)
    c2 = measure(scaled_config(acfg, 2), shape, mesh)

    # ga decomposition (train only): unit lowerings run the FULL batch at
    # ga=1, but a real step with grad accumulation re-pays the
    # batch-INDEPENDENT work (param gathers/reads) every microbatch while
    # the batch-LINEAR work is ga-invariant in total. Split via a half-batch
    # lowering: param_part = 2*c(B/2) - c(B) (the batch-linear part halves,
    # the constant part doesn't).
    if shape.kind == "train":
        c1h = measure(scaled_config(acfg, 1), half_batch(shape), mesh)
        c2h = measure(scaled_config(acfg, 2), half_batch(shape), mesh)

        def split(c, ch):
            par = {k: min(max(2 * ch[k] - c[k], 0.0), c[k]) for k in KEYS}
            act = {k: c[k] - par[k] for k in KEYS}
            return par, act
        p1, a1 = split(c1, c1h)
        p2, a2 = split(c2, c2h)
        slope_p = {k: max(p2[k] - p1[k], 0.0) for k in KEYS}
        slope_a = {k: max(a2[k] - a1[k], 0.0) for k in KEYS}
        inter_p = {k: max(p1[k] - slope_p[k], 0.0) for k in KEYS}
        inter_a = {k: max(a1[k] - slope_a[k], 0.0) for k in KEYS}
    else:
        slope = {k: max(c2[k] - c1[k], 0.0) for k in KEYS}
        slope_p = {k: 0.0 for k in KEYS}
        slope_a = slope
        inter_p = {k: 0.0 for k in KEYS}
        inter_a = {k: max(c1[k] - slope[k], 0.0) for k in KEYS}

    plan = segment_plan(acfg.model)
    mc = acfg.model
    # super-block count for the dominant segment kind
    if mc.family == "encdec":
        n_units = mc.n_layers                       # enc+dec vary together
    elif mc.family == "hybrid":
        n_units = mc.n_layers // mc.shared_attn_every
    elif mc.moe.n_experts > 0 and mc.moe.moe_every == 2:
        n_units = mc.n_layers // 2
    elif mc.global_every > 0:
        n_units = mc.n_layers // mc.global_every
    else:
        n_units = mc.n_layers

    total_p = {k: inter_p[k] + slope_p[k] * n_units for k in KEYS}
    total_a = {k: inter_a[k] + slope_a[k] * n_units for k in KEYS}

    # gemma local tail (62 = 10x6 + 2)
    tail = mc.n_layers - n_units * mc.global_every if mc.global_every else 0
    if mc.global_every and tail:
        t1 = measure(local_tail_config(acfg, 1), shape, mesh)
        t2 = measure(local_tail_config(acfg, 2), shape, mesh)
        if shape.kind == "train":
            t1h = measure(local_tail_config(acfg, 1), half_batch(shape), mesh)
            t2h = measure(local_tail_config(acfg, 2), half_batch(shape), mesh)
            tp1, ta1 = split(t1, t1h)
            tp2, ta2 = split(t2, t2h)
            for k in KEYS:
                total_p[k] += max(tp2[k] - tp1[k], 0.0) * tail
                total_a[k] += max(ta2[k] - ta1[k], 0.0) * tail
        else:
            for k in KEYS:
                total_a[k] += max(t2[k] - t1[k], 0.0) * tail

    ga = 1
    opt_cost = {"flops": 0.0, "bytes": 0.0, "coll_bytes": 0.0}
    dmd_cost = dict(opt_cost, per_group=[])
    if shape.kind == "train":
        ga = resolve_grad_accum(acfg, mesh, shape.global_batch)
        opt_cost = measure_optimizer(acfg, mesh)
        dmd_cost = measure_dmd(acfg, mesh)
        # per step: ga x param-part + activation-part + optimizer (+ the
        # DMD jumps, already amortized per group over each group's own
        # cycle inside measure_dmd). The unit lowerings include one
        # param-part already (they ran at ga=1); opt cost is separate and
        # NOT multiplied.
        total = {k: (ga * total_p[k] + total_a[k] + opt_cost[k]
                     + dmd_cost[k]) for k in KEYS}
    else:
        total = {k: total_p[k] + total_a[k] for k in KEYS}

    mf = model_flops(acfg, shape)
    flops_global = total["flops"] * chips
    terms = {
        "t_compute_s": total["flops"] / PEAK_FLOPS,
        "t_memory_s": total["bytes"] / HBM_BW,
        "t_collective_s": total["coll_bytes"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound = {"t_compute_s": "compute", "t_memory_s": "memory",
             "t_collective_s": "collective"}[dominant]
    step_time = max(terms.values())
    rec.update({
        "status": "ok",
        "chips": chips,
        "grad_accum": ga,
        "per_device": total,
        "param_part": total_p,
        "act_part": total_a,
        "terms": terms,
        "bottleneck": bound,
        "roofline_fraction": (total["flops"] / PEAK_FLOPS) / step_time
        if step_time > 0 else 0.0,
        "model_flops_global": mf,
        "hlo_flops_global": flops_global,
        "useful_ratio": mf / flops_global if flops_global else 0.0,
        "optimizer_cost": opt_cost,
        "dmd_cost_per_step": {k: dmd_cost[k] for k in KEYS},
        "dmd_cost_per_group": dmd_cost.get("per_group", []),
        "wall_s": round(time.time() - t0, 1),
    })
    if out_dir:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(rec, indent=1))
    print(f"[roofline] {arch} {shape_name}: bound={bound} "
          f"t_c={terms['t_compute_s']*1e3:.1f}ms "
          f"t_m={terms['t_memory_s']*1e3:.1f}ms "
          f"t_x={terms['t_collective_s']*1e3:.1f}ms "
          f"MFU-bound={rec['roofline_fraction']:.2f} "
          f"useful={rec['useful_ratio']:.2f} ({rec['wall_s']}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="results/roofline")
    args = ap.parse_args()
    from repro.configs import STANDARD_SHAPES, list_archs
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else [s.name for s in STANDARD_SHAPES]
    out = Path(args.out)
    for arch in archs:
        for shape in shapes:
            try:
                analyze_cell(arch, shape, args.mesh, out)
            except Exception as e:
                import traceback
                print(f"[roofline FAIL] {arch} {shape}: {e}")
                traceback.print_exc(limit=6)


if __name__ == "__main__":
    main()
