import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimbing driver: named (hypothesis -> change) experiments per
cell, measured with the same unit-decomposition roofline as the baseline.

    PYTHONPATH=src:. python -m benchmarks.hillclimb --cell llama4_train \
        --variant act_stationary

Each variant is a config transform; results land in results/hillclimb/ and
EXPERIMENTS.md §Perf records hypothesis / predicted / measured / verdict.
"""
import argparse
import dataclasses
import json
from pathlib import Path


def _llama4_act_stationary(acfg):
    """H1: llama4 train is collective-bound by FSDP re-gathering 386B expert
    weights every microbatch (measured ~2 GB/layer/microbatch). Keep expert
    weights resident (FSDP their ffn dim) and move the ~50 MB of dispatched
    activations instead. Predicted: MoE-layer collective bytes drop ~20-40x;
    total t_collective drops ~5-10x (dense layers + grads unchanged)."""
    from repro.distributed.sharding import set_rule_overrides
    set_rule_overrides([
        (r"experts_(gate|in)$", ("tp", None, "fsdp")),
        (r"experts_out$", ("tp", "fsdp", None)),
    ])
    moe = dataclasses.replace(acfg.model.moe, weight_stationary=False)
    return dataclasses.replace(
        acfg, model=dataclasses.replace(acfg.model, moe=moe))


def _llama4_act_stationary_ga8(acfg):
    """H1b: on top of H1, halve grad_accum 16->8: the remaining param-part
    collectives (dense FSDP gathers) scale with ga; activation memory
    doubles (fits: peak was 3.8 GiB at ga=16)."""
    acfg = _llama4_act_stationary(acfg)
    return dataclasses.replace(
        acfg, parallel=dataclasses.replace(acfg.parallel, grad_accum=8))


def _pad_heads(acfg):
    """H2: kv-SP attention replicates q over "model" -> per-layer q/k/v
    all-gathers (~300 MB/layer/microbatch for minicpm). Padded head-TP
    (36->48 heads, zero-padded, exact) shards the attention core instead;
    cost: 33% extra core-attention flops (core is ~1/3 of layer flops ->
    ~+11% t_compute). Predicted: attention collective bytes -> ~0; total
    t_collective drops to the FSDP-gather floor (~3-5x)."""
    return dataclasses.replace(
        acfg, parallel=dataclasses.replace(acfg.parallel,
                                           pad_attn_heads_to=16))


def _qwen3_dmd_bf16_math(acfg):
    """H3: qwen3 is the MoE-DMD showcase (DMD over ALL params). The jump's
    cost is bandwidth: gram+combine read the m x params buffer in fp32
    (astype materializes a 2x copy of bf16 buffers). Keep the streaming math
    in bf16 with fp32 accumulation (preferred_element_type): predicted DMD
    bytes ~/2, flops unchanged."""
    return dataclasses.replace(
        acfg, dmd=dataclasses.replace(acfg.dmd, gram_upcast=False))


def _ga_half(acfg):
    ga = max(acfg.parallel.grad_accum // 2, 1)
    return dataclasses.replace(
        acfg, parallel=dataclasses.replace(acfg.parallel, grad_accum=ga))


CELLS = {
    "llama4_train": ("llama4-maverick-400b-a17b", "train_4k"),
    "minicpm_train": ("minicpm-2b", "train_4k"),
    "qwen3_train": ("qwen3-moe-30b-a3b", "train_4k"),
    "qwen2vl_train": ("qwen2-vl-7b", "train_4k"),
    "whisper_train": ("whisper-base", "train_4k"),
    "minicpm_prefill": ("minicpm-2b", "prefill_32k"),
}

VARIANTS = {
    "baseline": lambda a: a,
    "act_stationary": _llama4_act_stationary,
    "act_stationary_ga8": _llama4_act_stationary_ga8,
    "pad_heads": _pad_heads,
    "ga_half": _ga_half,
    "dmd_bf16_math": _qwen3_dmd_bf16_math,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--out", default="results/hillclimb")
    args = ap.parse_args()
    from benchmarks.roofline import analyze_cell
    arch, shape = CELLS[args.cell]
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    rec = analyze_cell(arch, shape, "single", None,
                       overrides=VARIANTS[args.variant])
    rec["variant"] = args.variant
    (out / f"{args.cell}__{args.variant}.json").write_text(
        json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
