"""Serving-engine benchmark (BENCH_serve.json, DESIGN.md §10).

  serve_bench   frozen-weights pump vs a pump taking live DMD weight
                hot-swaps mid-serve: steady-state tokens/sec, p50/p99
                per-decode-step latency, swap count, dropped requests,
                steady-state recompiles. The committed BENCH_serve.json
                feeds the deterministic CI guard: hot-swap tokens/sec
                >= 0.9x frozen, p99 decode-step latency <= 1.5x frozen,
                >= 3 swaps landed, zero dropped requests, zero
                steady-state recompiles.

Both pumps run the identical request trace on the identical engine
config and are timed the same way (engine.sync() after every step, so a
"step" is dispatch + device completion); the ONLY difference is the
swap_weights() calls landing between decode steps. Per-step walls
exclude the swap itself (the publish path is off the decode critical
path by construction); end-to-end tokens/sec includes it — that is the
throughput a client sees across a swap.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np
import jax
import jax.numpy as jnp


def _engine_setup():
    from repro.configs import get_config, reduced
    from repro.models.transformer import LanguageModel
    from repro.serve import ServeConfig, ServeEngine

    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=64, d_ff=128,
                 vocab_size=256, n_heads=2, n_kv_heads=2, head_dim=32)
    model = LanguageModel(mc, head_tp=False, chunk_k=16, scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    cfg = ServeConfig(n_slots=8, prompt_buckets=(8, 16),
                      batch_buckets=(1, 2, 4), max_new_tokens=64)
    return model, params, cfg, ServeEngine(model, params, cfg)


def _pump(engine, prompts, new_tokens, swap_sources=(), swap_every=0):
    """Serve the full trace; returns (walls_per_step_s, total_wall_s)."""
    for p in prompts:
        engine.submit(p, max_new_tokens=new_tokens)
    walls, results, versions = [], [], iter(swap_sources)
    t_all = time.perf_counter()
    step = 0
    while engine.queue_len or engine.active_slots:
        t0 = time.perf_counter()
        results += engine.step()
        engine.sync()
        walls.append(time.perf_counter() - t0)
        step += 1
        if swap_every and step % swap_every == 0:
            nxt = next(versions, None)
            if nxt is not None:
                version, params = nxt
                engine.swap_weights(params, version=version)
    return walls, time.perf_counter() - t_all, results


def serve_bench(n_requests=24, new_tokens=24, n_swaps=3) -> List[str]:
    """Frozen vs hot-swap pump on the identical request trace."""
    model, params, cfg, warm_engine = _engine_setup()
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, model.cfg.vocab_size,
                                 size=rng.integers(2, cfg.prompt_buckets[-1]
                                                   + 1)))
               for _ in range(n_requests)]

    def fresh():
        from repro.serve import ServeEngine
        eng = ServeEngine(model, params, cfg)
        # warm every (prompt, batch) bucket + insert + decode, then freeze
        for wave in ([3] * 4, [12] * 4, [5] * 2, [9] * 2, [4], [10]):
            for n in wave:
                eng.submit(list(range(1, n + 1)), max_new_tokens=2)
            eng.run_until_drained()
        eng.mark_steady()
        for k in eng.stats:
            if k not in ("compiles", "steady_compiles"):
                eng.stats[k] = 0
        return eng

    # swap sources: perturbed weights standing in for DMD-jumped params
    swaps = [(10 * (i + 1),
              jax.tree_util.tree_map(lambda l, i=i: l * (1 + 1e-3 * (i + 1)),
                                     params))
             for i in range(n_swaps)]

    frozen = fresh()
    fw, f_total, f_res = _pump(frozen, prompts, new_tokens)
    hot = fresh()
    # land every swap while requests are in flight: total decode steps is
    # ~ n_requests/n_slots waves * new_tokens; spread swaps over the
    # first half so none degenerate into a post-drain no-op
    n_steps_est = max(len(fw), n_swaps * 2)
    every = max(1, n_steps_est // (2 * n_swaps))
    hw, h_total, h_res = _pump(hot, prompts, new_tokens,
                               swap_sources=swaps, swap_every=every)

    tok_f = frozen.stats["tokens_emitted"] / f_total
    tok_h = hot.stats["tokens_emitted"] / h_total
    tok_ratio = tok_h / tok_f
    p99_ratio = float(np.percentile(hw, 99) / np.percentile(fw, 99))

    rows = ["serve,pump,tok_s,p50_ms,p99_ms,decode_steps,swaps,dropped,"
            "steady_compiles"]
    for name, eng, walls, total in (("frozen", frozen, fw, f_total),
                                    ("hotswap", hot, hw, h_total)):
        rows.append(
            f"serve,{name},{eng.stats['tokens_emitted'] / total:.1f},"
            f"{np.percentile(walls, 50) * 1e3:.2f},"
            f"{np.percentile(walls, 99) * 1e3:.2f},{len(walls)},"
            f"{eng.stats['swaps']},{eng.stats['dropped']},"
            f"{eng.stats['steady_compiles']}")
    ok = (tok_ratio >= 0.9 and p99_ratio <= 1.5
          and hot.stats["swaps"] >= n_swaps and hot.stats["dropped"] == 0
          and hot.stats["steady_compiles"] == 0)
    rows.append(f"serve_final,tok_s_ratio,{tok_ratio:.3f},p99_ratio,"
                f"{p99_ratio:.3f},swaps,{hot.stats['swaps']},dropped,"
                f"{hot.stats['dropped']},steady_compiles,"
                f"{hot.stats['steady_compiles']},"
                f"hotswap_{'WINS' if ok else 'LOSES'}")
    # every request served on both pumps, hot-swap stamped the versions
    assert len(f_res) == len(h_res) == n_requests
    assert {r.version_end for r in f_res} == {0}
    assert max(r.version_end for r in h_res) == swaps[-1][0]
    rows.append(f"serve_versions,frozen,0,hotswap_max,"
                f"{max(r.version_end for r in h_res)},programs,"
                f"{hot.n_programs}/{hot.max_programs}")
    return rows
