"""Benchmark harness: one function per paper table/figure + kernel timings.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out DIR]

Prints ``name,...`` CSV rows AND writes one ``BENCH_<suite>.json`` per suite
(the perf-trajectory files CI archives run-over-run): each file carries the
raw rows plus the wall time so regressions are diffable. The roofline table
(per arch x shape) is a separate, much heavier pass: ``python -m
benchmarks.roofline`` (it needs the 512-device dry-run environment).
"""
import argparse
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, "src")

# Give the sharded_gram suite a real multi-device mesh on CPU hosts (set
# before jax initializes; harmless for the single-device suites).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp


def bench_kernels() -> list:
    """Kernel wall times (interpret-mode on CPU: correctness path; the
    numbers are the jnp-oracle equivalents, useful as relative baselines)."""
    from repro.kernels import ops, ref
    rows = ["kernel,name,us_per_call,derived"]
    rng = np.random.default_rng(0)
    m, n = 14, 1_000_000
    S = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(m,)), jnp.float32)

    def timeit(f, *a, reps=5):
        out = f(*a)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = f(*a)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    t_ref = timeit(jax.jit(ref.gram_ref), S)
    rows.append(f"kernel,gram_ref_jnp,{t_ref:.0f},m={m} n={n} "
                f"{2*m*m*n/t_ref*1e-3/1e9:.1f}GFLOP/s")
    t_c = timeit(jax.jit(ref.combine_ref), S, c)
    rows.append(f"kernel,combine_ref_jnp,{t_c:.0f},bw~"
                f"{4*m*n/t_c*1e-3/1e9:.1f}GB/s")
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    t_f = timeit(jax.jit(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True)), q, k, k)
    rows.append(f"kernel,flash_ref_jnp,{t_f:.0f},B1 S512 H4 d64")
    return rows


def losing_rows(rows: list) -> list:
    """Rows that report a LOSING direction (ISSUE 9): suites mark a metric
    that regressed vs its baseline with an explicit ``_LOSES`` token (e.g.
    fig4's signed-delta final rows). Surfacing them here keeps a regression
    from hiding inside a wall of higher-is-better ratios."""
    return [r for r in rows if "_LOSES" in r]


def write_suite(out_dir: Path, suite: str, rows: list, wall_s: float,
                quick: bool) -> None:
    path = out_dir / f"BENCH_{suite}.json"
    path.write_text(json.dumps({
        "suite": suite,
        "rows": rows,
        "wall_s": round(wall_s, 2),
        "quick": quick,
        "backend": jax.default_backend(),
        "n_devices": len(jax.devices()),
    }, indent=1))
    print(f"# wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default=".",
                    help="directory for the BENCH_<suite>.json files")
    args = ap.parse_args()
    from benchmarks.paper_benches import (arena_bench, bucket_dmd,
                                          controller, fig3_sensitivity,
                                          fig4_curves, sec3_overhead,
                                          sharded_gram, staggered_jump,
                                          streaming_gram)
    from benchmarks.serving import serve_bench
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    suites = [
        ("arena", (lambda: arena_bench(n_mlp_layers=12, width=128, reps=5))
         if args.quick else arena_bench),
        ("bucket_dmd", (lambda: bucket_dmd(n_mlp_layers=12, width=128,
                                           reps=5, fig_steps=300,
                                           lm_steps=40))
         if args.quick else bucket_dmd),
        ("sec3_overhead", sec3_overhead),
        ("streaming_gram", lambda: streaming_gram(
            n=1_000_000 if args.quick else 4_000_000)),
        ("sharded_gram", sharded_gram),
        ("staggered_jump", (lambda: staggered_jump(
            sizes=(6, 400, 400, 400), reps=5)) if args.quick
         else staggered_jump),
        ("controller", (lambda: controller(
            steps=300, sizes=(6, 40, 80, 200))) if args.quick
         else controller),
        ("serve", (lambda: serve_bench(n_requests=12, new_tokens=12))
         if args.quick else serve_bench),
        ("kernels", bench_kernels),
        ("fig3", (lambda: fig3_sensitivity(ms=(6, 14), ss=(10, 55),
                                           steps=300))
         if args.quick else fig3_sensitivity),
        ("fig4", (lambda: fig4_curves(steps=300))
         if args.quick else fig4_curves),
    ]

    t_total = time.time()
    all_rows = []
    for suite, fn in suites:
        t0 = time.time()
        rows = fn()
        write_suite(out_dir, suite, rows, time.time() - t0, args.quick)
        for r in losing_rows(rows):
            print(f"# LOSING DIRECTION [{suite}]: {r}")
        all_rows += rows
    print("\n".join(all_rows))
    losers = losing_rows(all_rows)
    if losers:
        print(f"\n# {len(losers)} metric(s) in a LOSING direction — "
              "see rows above")
    print(f"\n# total bench wall: {time.time() - t_total:.0f}s")


if __name__ == "__main__":
    main()
