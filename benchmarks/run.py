"""Benchmark harness: one function per paper table/figure + kernel timings.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,...`` CSV rows. The roofline table (per arch x shape) is a
separate, much heavier pass: ``python -m benchmarks.roofline`` (it needs the
512-device dry-run environment).
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import numpy as np
import jax
import jax.numpy as jnp


def bench_kernels() -> list:
    """Kernel wall times (interpret-mode on CPU: correctness path; the
    numbers are the jnp-oracle equivalents, useful as relative baselines)."""
    from repro.kernels import ops, ref
    rows = ["kernel,name,us_per_call,derived"]
    rng = np.random.default_rng(0)
    m, n = 14, 1_000_000
    S = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(m,)), jnp.float32)

    def timeit(f, *a, reps=5):
        out = f(*a)
        jax.block_until_ready(out)
        t0 = time.time()
        for _ in range(reps):
            out = f(*a)
        jax.block_until_ready(out)
        return (time.time() - t0) / reps * 1e6

    t_ref = timeit(jax.jit(ref.gram_ref), S)
    rows.append(f"kernel,gram_ref_jnp,{t_ref:.0f},m={m} n={n} "
                f"{2*m*m*n/t_ref*1e-3/1e9:.1f}GFLOP/s")
    t_c = timeit(jax.jit(ref.combine_ref), S, c)
    rows.append(f"kernel,combine_ref_jnp,{t_c:.0f},bw~"
                f"{4*m*n/t_c*1e-3/1e9:.1f}GB/s")
    q = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 4, 64)), jnp.float32)
    t_f = timeit(jax.jit(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True)), q, k, k)
    rows.append(f"kernel,flash_ref_jnp,{t_f:.0f},B1 S512 H4 d64")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    from benchmarks.paper_benches import (fig3_sensitivity, fig4_curves,
                                          sec3_overhead, streaming_gram)
    t0 = time.time()
    rows = []
    rows += sec3_overhead()
    rows += streaming_gram(n=1_000_000 if args.quick else 4_000_000)
    rows += bench_kernels()
    if args.quick:
        rows += fig3_sensitivity(ms=(6, 14), ss=(10, 55), steps=300)
        rows += fig4_curves(steps=300)
    else:
        rows += fig3_sensitivity()
        rows += fig4_curves()
    print("\n".join(rows))
    print(f"\n# total bench wall: {time.time() - t0:.0f}s")


if __name__ == "__main__":
    main()
