"""One benchmark per paper table/figure (reduced sizes for CPU).

  fig3_sensitivity   m x s grid of mean relative improvement per DMD jump
  fig4_curves        train/test MSE curves, DMD vs baseline at equal steps
  sec3_overhead      DMD arithmetic vs backprop cost: analytic op counts
                     (n(3m^2+r^2) vs 6nt) and measured wall times
  streaming_gram     record+apply micro-benchmark: streaming-Gram engine vs
                     the full-recompute seed path, with the per-window
                     FLOP/byte accounting (DESIGN.md §2)
  staggered_jump     synchronous vs staggered per-leaf schedule: max
                     per-step jump spike, jumps-per-step concurrency, and
                     snapshot-buffer bytes (small-m groups) — DESIGN.md §4
  controller         loss-gated jump controller vs the fixed (PR-3)
                     schedule on the pollutant MLP: accept/scale/reject
                     counts, loss-vs-wall trajectory at equal step count,
                     zero unrecovered rejects, and the gate's wall overhead
                     on the jump step — DESIGN.md §5
  arena_bench        per_leaf vs pack-copy vs arena-resident routes: kernel
                     launches per recorded step, traced-program size,
                     record/jump walls and the per-record pack cost on a
                     deep MLP + reduced tinyllama — DESIGN.md §7
  bucket_dmd         leaf- vs bucket-scope Koopman DMD (dmd.scope): jump
                     solve counts (n_systems -> n_buckets), traced eigh
                     batch rows, per-record Gram-update bytes, jump walls
                     under the matpow and eig solvers, and final-loss
                     parity on the fig3/fig4 MLP + a reduced-tinyllama LM
                     run — DESIGN.md §9
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import (DMDConfig, DMDControllerConfig,
                                OptimizerConfig)
from repro.core import DMDAccelerator, leafplan
from repro.core import snapshots as snap
from repro.core.dmd import dmd_coefficients, gram_matrix
from repro.models.mlp_net import init_mlp, mse_loss
from repro.optim import apply_updates, make_optimizer


def _synthetic_regression(seed=0, n=600, n_out=400):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1, 1, size=(n, 6)).astype(np.float32)
    A1 = rng.normal(size=(6, n_out)).astype(np.float32)
    A2 = rng.normal(size=(6, n_out)).astype(np.float32)
    Y = (np.tanh(X @ A1) * np.exp(-0.5 * (X @ A2) ** 2)).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(Y)


def _train(dmd_cfg, sizes, X, Y, Xte, Yte, steps, lr=1e-3, seed=0):
    params = init_mlp(jax.random.PRNGKey(seed), sizes)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=lr))
    state = opt.init(params)
    acc = DMDAccelerator(dmd_cfg)
    bufs = acc.init(params)

    @jax.jit
    def step(p, s, t):
        loss, g = jax.value_and_grad(lambda pp: mse_loss(pp, X, Y))(p)
        u, s = opt.update(g, s, p, t)
        return apply_updates(p, u), s, loss

    jumps, curve = [], []
    for t in range(steps):
        params, state, loss = step(params, state, jnp.asarray(t))
        if dmd_cfg.enabled and acc.should_record(t):
            bufs, _ = acc.record(bufs, params, acc.slot(t))
            if acc.should_apply(t):
                before = float(mse_loss(params, X, Y))
                params, _ = acc.apply(params, bufs, acc.round_index(t))
                jumps.append(float(mse_loss(params, X, Y))
                             / max(before, 1e-30))
                state = opt.init(params)
        if t % 50 == 0 or t == steps - 1:
            curve.append((t, float(mse_loss(params, X, Y)),
                          float(mse_loss(params, Xte, Yte))))
    return curve, jumps


def fig3_sensitivity(ms=(6, 10, 14), ss=(10, 30, 55), steps=450) -> List[str]:
    """Paper Fig 3: improvement grows with m; non-monotonic in s."""
    X, Y = _synthetic_regression()
    Xte, Yte = _synthetic_regression(seed=7, n=150)
    sizes = (6, 40, 100, Y.shape[1])
    rows = ["fig3,m,s,mean_rel_improvement,n_jumps"]
    for m in ms:
        for s in ss:
            cfg = DMDConfig(m=m, s=s, tol=1e-4, warmup_steps=100,
                            cooldown_steps=10)
            _, jumps = _train(cfg, sizes, X, Y, Xte, Yte, steps)
            mri = float(np.mean(jumps)) if jumps else float("nan")
            rows.append(f"fig3,{m},{s},{mri:.4f},{len(jumps)}")
    return rows


def _train_gated(sizes, X, Y, Xval, Yval, Xte, Yte, steps, m=14, s=55,
                 lr=1e-3):
    """The validation-gated controller run for fig4 (ISSUE 9): same train
    rows and step count as `_train`, but jumps are ridge-shrinkable and
    gated on a DISJOINT validation fold of the SAME teacher (never the
    training rows, never the test set). Returns (curve, outcome_counts)."""
    from repro.configs.base import (ArchConfig, ModelConfig, ParallelConfig,
                                    TrainConfig)
    from repro.train import Trainer

    dmd = DMDConfig(
        m=m, s=s, tol=1e-4, warmup_steps=100, cooldown_steps=10,
        controller=DMDControllerConfig(
            enabled=True, eval_rows=0, val_gate=True,
            shrink_levels=(0.5, 0.25), meta_lr=0.05))
    acfg = ArchConfig(
        model=ModelConfig(name="pollutant-mlp", family="mlp"), dmd=dmd,
        optimizer=OptimizerConfig(name="adam", lr=lr),
        parallel=ParallelConfig(grad_accum=1),
        train=TrainConfig(global_batch=int(X.shape[0]), seq_len=1),
        shapes=())
    trainer = Trainer(_MLPModel(sizes), acfg,
                      val_batch={"x": Xval, "y": Yval})
    outcomes = {0: 0, 1: 0, 2: 0}

    def on_m(t, metrics):
        if "ctrl_outcome" in metrics:
            outcomes[int(metrics["ctrl_outcome"])] += 1

    batches = iter(lambda: {"x": X, "y": Y}, None)
    state, curve = trainer.init_state(), []
    # fit in segments so the curve samples (params at step t) line up with
    # `_train`'s post-update, post-jump sampling points
    for t in range(steps):
        if t % 50 == 0 or t == steps - 1:
            state = trainer.fit(batches, t + 1, state=state, on_metrics=on_m)
            curve.append((t, float(mse_loss(state.params, X, Y)),
                          float(mse_loss(state.params, Xte, Yte))))
    return curve, outcomes


def fig4_curves(steps=600) -> List[str]:
    """Paper Fig 4: MSE vs epoch (train & test) — baseline, the paper's
    ungated DMD schedule, and the ISSUE 9 validation-gated controller run,
    all at EQUAL step count.

    ONE teacher generates every split: 600 train rows, a 150-row validation
    fold (the gate batch) and a 150-row held-out TEST fold, all disjoint.
    The old bench drew its "test set" from a DIFFERENT teacher seed — an
    unrelated function, so every run's test MSE rose monotonically with
    training and the train/test comparison measured distance from an
    unrelated task, not generalization. Final rows report SIGNED deltas vs
    baseline with explicit WINS/LOSES labels — the old
    `fig4_final_ratio,test,0.97x` row formatted a test REGRESSION in the
    same higher-is-better style as the train speedup, hiding the gap this
    bench exists to expose. The committed BENCH_fig4.json feeds the
    deterministic CI guard: gated final test MSE <= baseline at equal
    steps AND train ratio >= 1.5x.
    """
    Xall, Yall = _synthetic_regression(n=900)
    X, Y = Xall[:600], Yall[:600]
    Xval, Yval = Xall[600:750], Yall[600:750]
    Xte, Yte = Xall[750:], Yall[750:]
    sizes = (6, 40, 200, Y.shape[1])
    base, _ = _train(DMDConfig(enabled=False), sizes, X, Y, Xte, Yte, steps)
    dmd, _ = _train(DMDConfig(m=14, s=55, tol=1e-4, warmup_steps=100,
                              cooldown_steps=10),
                    sizes, X, Y, Xte, Yte, steps)
    gated, outcomes = _train_gated(sizes, X, Y, Xval, Yval, Xte, Yte, steps)
    rows = ["fig4,step,baseline_train,baseline_test,dmd_train,dmd_test,"
            "gated_train,gated_test"]
    for (t, btr, bte), (_, dtr, dte), (_, gtr, gte) in zip(base, dmd, gated):
        rows.append(f"fig4,{t},{btr:.5e},{bte:.5e},{dtr:.5e},{dte:.5e},"
                    f"{gtr:.5e},{gte:.5e}")

    def final_rows(name, run):
        out = []
        for split, idx in (("train", 1), ("test", 2)):
            b, v = base[-1][idx], run[-1][idx]
            delta = (v - b) / max(b, 1e-30)
            verdict = "WINS" if v <= b else "LOSES"
            out.append(f"fig4_final,{split},{name},{v:.5e},baseline,"
                       f"{b:.5e},delta,{delta:+.1%},{name}_{verdict}")
        return out

    rows += final_rows("dmd", dmd) + final_rows("gated", gated)
    rows.append(f"fig4_final_ratio,train,"
                f"{base[-1][1] / max(dmd[-1][1], 1e-30):.2f}x,gated_train,"
                f"{base[-1][1] / max(gated[-1][1], 1e-30):.2f}x")
    rows.append(f"fig4_gate_outcomes,accepts,{outcomes[2]},scaled,"
                f"{outcomes[1]},rejects,{outcomes[0]}")
    return rows


def arena_bench(n_mlp_layers=24, width=192, reps=10) -> List[str]:
    """Tentpole evidence for arena-native residency (core/arena.py,
    train/step.py::state_resident, DESIGN.md §7) on two multi-leaf configs:

      * a deep unstacked MLP (2 leaves per layer — the dispatch-bound
        regime: hundreds of tiny per-leaf launches), and
      * reduced tinyllama (scan-stacked transformer leaves + embeddings).

    Three routes per config:

      per_leaf   dmd.arena=False — the pre-arena route: one record write
                 and one Gram pass per leaf.
      packed     dmd.arena=True, arena_native=False — the PR-5 pack-copy
                 route: params stay leaf-wise; every record re-gathers
                 them into bucket rows (the `pack_ms` column) before the
                 row write.
      resident   dmd.arena=True, arena_native=True — params LIVE in the
                 flat buckets (the layout Trainer.fit converts to at
                 entry); record degenerates to one dynamic_update_slice
                 per bucket and pack_ms is paid once per fit(), not per
                 record.

    Rows record, per route: the kernel-launch proxy (data-pass primitives
    per recorded step), the traced-program size, measured record+update /
    jump walls, and pack_ms (the per-record params->row gather that
    residency deletes; "-" where the route has no pack, 0.00 where it is
    amortized to one conversion per fit).

    Acceptance (CI bench-regression guard): record_speedup and
    jump_speedup in the summary rows compare RESIDENT vs per_leaf and
    must be > 1.0 on every config — residency exists precisely to delete
    the pack copy that made the PR-5 deep-MLP record a CPU-wall
    regression (0.53x) while it was winning launches 48x.
    """
    from repro.configs import get_config, reduced
    from repro.core import arena as arena_mod
    from repro.models.mlp_net import init_mlp
    from repro.models.transformer import init_params, param_stack_dims
    from repro.trace import count_eqns, count_launch_ops

    rows = ["arena,config,route,launches_per_recorded_step,jaxpr_eqns,"
            "record_update_ms,jump_ms,pack_ms,n_leaves,n_buckets"]

    def bench_one(name, params0, stack_dims, m=8):
        cfg = DMDConfig(m=m, s=10, tol=1e-4, anchor="first", warmup_steps=0,
                        cooldown_steps=0)
        out = {}
        for route, arena_on, native in (("per_leaf", False, False),
                                        ("packed", True, False),
                                        ("resident", True, True)):
            c = dataclasses.replace(cfg, arena=arena_on,
                                    arena_native=native)
            acc = DMDAccelerator(c, stack_dims=stack_dims)
            params = params0
            bufs = acc.init(params)
            grams = acc.init_grams(bufs)
            table = acc.arena_for(params)
            n_buckets = len(table)
            n_leaves = len(leafplan.plan_entries(acc.plans_for(params)))
            if native and table:
                # the Trainer.fit entry conversion: params move INTO the
                # buckets, outside any timed region
                params = arena_mod.tree_resident(table, params)

            def rec(b, g, p, slot):
                return acc.record(b, p, slot, g)

            slot1 = jnp.asarray(1, jnp.int32)
            jx = jax.make_jaxpr(rec)(bufs, grams, params, slot1)
            launches = count_launch_ops(jx.jaxpr)
            eqns = count_eqns(jx.jaxpr)
            rec_jit = jax.jit(rec, donate_argnums=(0, 1))

            # pack_ms: the params -> bucket-row gather. The packed route
            # pays it inside EVERY record; the resident route paid it once
            # at fit() entry (reported 0.00/rec); per_leaf has no buckets.
            if not table:
                pack_ms = "-"
            elif native:
                pack_ms = "0.00"
            else:
                pack = jax.jit(
                    lambda p: arena_mod.split_state(
                        arena_mod.tree_resident(table, p))[0])
                jax.block_until_ready(pack(params))     # compile
                walls = []
                for _ in range(reps):
                    t0 = time.time()
                    jax.block_until_ready(pack(params))
                    walls.append(time.time() - t0)
                pack_ms = f"{float(np.median(walls)) * 1e3:.2f}"

            # warm the window so the jump solves on real data
            p = params
            for t in range(m):
                p = jax.tree_util.tree_map(
                    lambda x: x + 0.01 * jnp.ones_like(x), p)
                bufs, grams = rec_jit(bufs, grams, p,
                                      jnp.asarray(t, jnp.int32))

            # donated buffers: rethread the returned state each rep (the
            # deployment idiom — see the donation audit); median wall
            bufs, grams = rec_jit(bufs, grams, p, slot1)       # compile
            jax.block_until_ready(jax.tree_util.tree_leaves(bufs))
            walls = []
            for _ in range(reps):
                t0 = time.time()
                bufs, grams = rec_jit(bufs, grams, p, slot1)
                jax.block_until_ready(jax.tree_util.tree_leaves(bufs))
                walls.append(time.time() - t0)
            t_rec = float(np.median(walls))
            # apply donates params: pre-clone outside the timed region
            clones = [jax.tree_util.tree_map(jnp.copy, p)
                      for _ in range(reps + 1)]
            jax.block_until_ready(jax.tree_util.tree_leaves(
                acc.apply(clones.pop(), bufs, grams=grams,
                          step=m - 1)[0]))               # compile
            walls = []
            for cp in clones:
                t0 = time.time()
                jax.block_until_ready(jax.tree_util.tree_leaves(
                    acc.apply(cp, bufs, grams=grams, step=m - 1)[0]))
                walls.append(time.time() - t0)
            t_jump = float(np.median(walls))
            rows.append(
                f"arena,{name},{route},{launches},{eqns},"
                f"{t_rec * 1e3:.2f},{t_jump * 1e3:.2f},{pack_ms},"
                f"{n_leaves},{n_buckets}")
            out[route] = (launches, eqns, t_rec, t_jump)
        lr, er, rr, jr = out["resident"]
        lp, ep, rp, jp = out["per_leaf"]
        _, _, rk, jk = out["packed"]
        rows.append(f"arena,{name},launch_ratio,{lp / max(lr, 1):.1f}x,"
                    f"eqn_ratio,{ep / max(er, 1):.1f}x,"
                    f"record_speedup,{rp / max(rr, 1e-9):.2f}x,"
                    f"jump_speedup,{jp / max(jr, 1e-9):.2f}x")
        rows.append(f"arena,{name},resident_vs_packed,"
                    f"record,{rk / max(rr, 1e-9):.2f}x,"
                    f"jump,{jk / max(jr, 1e-9):.2f}x")
        return out

    # deep unstacked MLP: the dispatch-bound many-leaf regime
    sizes = [width] * (n_mlp_layers + 1)
    mlp_params = init_mlp(jax.random.PRNGKey(0), sizes)
    bench_one(f"mlp{n_mlp_layers}x{width}", mlp_params, None)

    # reduced tinyllama: scan-stacked transformer leaves
    mc = reduced(get_config("tinyllama-1.1b").model, n_layers=4, d_model=64,
                 d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
                 head_dim=16)
    tl_params = init_params(mc, key=jax.random.PRNGKey(0))
    bench_one("tinyllama_reduced", tl_params,
              param_stack_dims(mc, tl_params))
    return rows


def bucket_dmd(n_mlp_layers=24, width=192, reps=10, fig_steps=600,
               lm_steps=80) -> List[str]:
    """ISSUE 8 tentpole evidence: bucket-scope Koopman DMD (dmd.scope,
    DESIGN.md §9) against the per-leaf default on the same two multi-leaf
    configs arena_bench uses.

    Per config, scope and solver mode:

      * jump_solves: batched coefficient systems per jump — the sum of
        ``gram_lead(scope)`` over the arena table plus unpacked per-leaf
        systems, i.e. exactly the budget the solve-budget audit pass
        enforces. Bucket scope collapses it from n_systems to n_buckets
        (48 -> buckets on the deep MLP, 24 -> buckets on reduced
        tinyllama).
      * eigh_rows: the SAME count measured from the traced jump jaxpr
        (batch rows flowing into the POD eigh) — proof the compiled jump
        really solves one system per bucket instead of silently falling
        back to per-leaf solves (eqn counts cannot tell: the batched
        eigh is one equation either way).
      * gram_update_bytes: fp32 bytes of Gram state written per recorded
        step (4*m^2 per solve system) — the streaming-Gram footprint the
        segment-summed bucket reduction shrinks by the same factor.
      * jump_ms: median of blocked donated ``apply`` calls, under matpow
        (TPU-native) AND the eig host-callback solver — the callback
        pays a host roundtrip per batch, so shrinking its rows is where
        bucket scope amortizes hardest.

    Parity: fig3-style mean relative improvement per jump and fig4-style
    final train/test MSE, leaf vs bucket scope, on the paper MLP (the
    acceptance bound: bucket fig4 final train MSE within 5% of leaf), and
    a reduced-tinyllama LM run at equal steps through the full Trainer.
    """
    from repro import trace
    from repro.configs import get_config, reduced
    from repro.configs.base import TrainConfig
    from repro.core import arena as arena_mod
    from repro.core.arena import arena_paths
    from repro.core.leafplan import plan_entries
    from repro.models.transformer import (LanguageModel, init_params,
                                          param_stack_dims)
    from repro.train import Trainer

    rows = ["bucket_dmd,config,scope,mode,jump_solves,eigh_rows,"
            "gram_update_bytes,jump_ms,n_systems,n_buckets"]

    def _batch_rows(aval):
        shape = getattr(aval, "shape", ())
        return int(np.prod(shape[:-2])) if len(shape) >= 2 else 1

    def bench_one(name, params0, stack_dims, m=8):
        base = DMDConfig(m=m, s=10, tol=1e-4, anchor="first",
                         warmup_steps=0, cooldown_steps=0)
        out = {}
        for scope in ("leaf", "bucket"):
            for mode in ("matpow", "eig"):
                c = dataclasses.replace(base, scope=scope, mode=mode)
                acc = DMDAccelerator(c, stack_dims=stack_dims)
                params = params0
                table = acc.arena_for(params)
                packed = arena_paths(table)
                n_buckets = len(table)
                solves = sum(b.gram_lead(scope) for b in table.values())
                n_systems = sum(b.gram_lead("leaf") for b in table.values())
                for pl in plan_entries(acc.plans_for(params)):
                    if pl.path in packed:
                        continue
                    extra = (int(np.prod(pl.shape[:pl.stack_dims]))
                             if pl.stack_dims else 1)
                    solves += extra
                    n_systems += extra
                gram_bytes = 4 * m * m * solves
                bufs = acc.init(params)
                grams = acc.init_grams(bufs)
                if table:
                    params = arena_mod.tree_resident(table, params)
                rec_jit = jax.jit(lambda b, g, p, slot: acc.record(
                    b, p, slot, g), donate_argnums=(0, 1))
                p = params
                for t in range(m):                 # fill one window
                    p = jax.tree_util.tree_map(
                        lambda x: x + 0.01 * jnp.ones_like(x), p)
                    bufs, grams = rec_jit(bufs, grams, p,
                                          jnp.asarray(t, jnp.int32))
                jx = jax.make_jaxpr(
                    lambda pp, b, g: acc.apply(pp, b, grams=g,
                                               step=m - 1)[0])(p, bufs,
                                                               grams)
                eigh_rows = trace.sum_eqns(
                    jx.jaxpr,
                    lambda e: _batch_rows(e.invars[0].aval)
                    if str(e.primitive) == "eigh" else 0)
                # apply donates params: pre-clone outside the timed region
                clones = [jax.tree_util.tree_map(jnp.copy, p)
                          for _ in range(reps + 1)]
                jax.block_until_ready(jax.tree_util.tree_leaves(
                    acc.apply(clones.pop(), bufs, grams=grams,
                              step=m - 1)[0]))     # compile
                walls = []
                for cp in clones:
                    t0 = time.time()
                    jax.block_until_ready(jax.tree_util.tree_leaves(
                        acc.apply(cp, bufs, grams=grams, step=m - 1)[0]))
                    walls.append(time.time() - t0)
                t_jump = float(np.median(walls)) * 1e3
                rows.append(f"bucket_dmd,{name},{scope},{mode},{solves},"
                            f"{eigh_rows},{gram_bytes},{t_jump:.2f},"
                            f"{n_systems},{n_buckets}")
                out[(scope, mode)] = (solves, t_jump)
        for mode in ("matpow", "eig"):
            sl, tl = out[("leaf", mode)]
            sb, tb = out[("bucket", mode)]
            rows.append(f"bucket_dmd,{name},summary,{mode},"
                        f"solve_reduction,{sl}->{sb},"
                        f"jump_speedup,{tl / max(tb, 1e-9):.2f}x")
        return out

    # deep unstacked MLP: 48 leaves, a handful of buckets
    sizes = [width] * (n_mlp_layers + 1)
    bench_one(f"mlp{n_mlp_layers}x{width}",
              init_mlp(jax.random.PRNGKey(0), sizes), None)

    # reduced tinyllama: scan-stacked transformer leaves + embeddings
    mc = reduced(get_config("tinyllama-1.1b").model, n_layers=4, d_model=64,
                 d_ff=128, vocab_size=256, n_heads=4, n_kv_heads=2,
                 head_dim=16)
    tl_params = init_params(mc, key=jax.random.PRNGKey(0))
    bench_one("tinyllama_reduced", tl_params,
              param_stack_dims(mc, tl_params))

    # fig3/fig4 parity on the paper MLP. s=10, NOT fig4's s=55: the fig3
    # grid shows s=55 jumps at this reduced step count transiently SPIKE
    # the loss (mean_rel_improvement > 1), so an equal-step final-MSE
    # sample aliases against the jump phase and swings tens of percent
    # run to run — in BOTH scopes. The s=10 cells are fig3's benign
    # regime (mri < 1: every jump nets an improvement); there the two
    # scopes' trajectories track each other and the parity bound is
    # meaningful.
    X, Y = _synthetic_regression()
    Xte, Yte = _synthetic_regression(seed=7, n=150)
    fig_sizes = (6, 40, 200, Y.shape[1])
    fig_cfg = DMDConfig(m=14, s=10, tol=1e-4, warmup_steps=100,
                        cooldown_steps=10)
    parity = {}
    for scope in ("leaf", "bucket"):
        curve, jumps = _train(dataclasses.replace(fig_cfg, scope=scope),
                              fig_sizes, X, Y, Xte, Yte, fig_steps)
        mri = float(np.mean(jumps)) if jumps else float("nan")
        parity[scope] = curve[-1][1]
        rows.append(f"bucket_dmd,fig4_mlp,{scope},final_train_mse,"
                    f"{curve[-1][1]:.5e},final_test_mse,{curve[-1][2]:.5e},"
                    f"fig3_mean_rel_improvement,{mri:.4f},"
                    f"n_jumps,{len(jumps)}")
    rel = (abs(parity["bucket"] - parity["leaf"])
           / max(parity["leaf"], 1e-30))
    rows.append(f"bucket_dmd,fig4_mlp,parity,train_mse_rel_diff,"
                f"{rel * 100:.2f}%,bound,5%")

    # reduced-tinyllama LM parity at equal steps through the full Trainer
    # (resident buckets, fused record, scope-aware jump — the deployment
    # path end to end)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, mc.vocab_size, size=(4, 32)),
                       jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    finals = {}
    for scope in ("leaf", "bucket"):
        acfg = get_config("tinyllama-1.1b")
        acfg = dataclasses.replace(
            acfg, model=mc,
            dmd=DMDConfig(m=4, s=10, tol=1e-4, warmup_steps=8,
                          cooldown_steps=2, scope=scope),
            optimizer=OptimizerConfig(name="adam", lr=3e-3),
            parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                         remat="none"),
            train=TrainConfig(global_batch=4, seq_len=32))
        losses = []
        trainer = Trainer(LanguageModel(mc, head_tp=False, chunk_k=16),
                          acfg)
        trainer.fit(iter(lambda: batch, None), lm_steps,
                    on_metrics=lambda t, mt: losses.append(
                        float(mt["loss"])))
        finals[scope] = losses[-1]
        rows.append(f"bucket_dmd,tinyllama_reduced_lm,{scope},"
                    f"final_train_loss,{losses[-1]:.5f},steps,{lm_steps}")
    diff = abs(finals["bucket"] - finals["leaf"])
    rows.append(f"bucket_dmd,tinyllama_reduced_lm,parity,"
                f"final_loss_abs_diff,{diff:.2e},"
                f"both runs at the one-batch memorization floor")
    return rows


def _timeit(fn, *args, reps=10):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.time() - t0) / reps


def streaming_gram(m=14, n=4_000_000, reps=10) -> List[str]:
    """ISSUE 1 tentpole evidence: record+apply micro-benchmark, streaming-Gram
    engine vs the full-recompute seed path, with the per-window FLOP/byte
    accounting behind the O(m^2*n) -> O(m*n) apply-side reduction.

    Per window (m records + 1 apply over an m x n buffer):
      * recompute (seed): apply pays one O(m^2*n) Gram pass + one O(m*n)
        combine pass — 2 full-buffer reads at the synchronization point.
      * streaming: each record folds one O(m*n) row pass into the train step
        (against params already resident there); apply is O(m^3) algebra +
        one combine pass — the synchronous jump cost drops ~(m+1)x in FLOPs
        and 2x in bytes.
    """
    rng = np.random.default_rng(0)
    params = {"w": jnp.asarray(rng.normal(size=(n,)), jnp.float32)}
    # arena=False: this suite measures the PER-LEAF streaming engine
    # against the seed recompute with direct snapshots.* calls (one big
    # leaf, so there is nothing to bucket anyway — the arena story has its
    # own suite, arena_bench)
    cfg = DMDConfig(m=m, s=55, tol=1e-4, anchor="first", warmup_steps=0,
                    cooldown_steps=0, streaming_gram=True, arena=False)
    acc_s = DMDAccelerator(cfg)
    acc_r = DMDAccelerator(dataclasses.replace(cfg, streaming_gram=False))
    bufs = acc_s.init(params)
    grams = acc_s.init_grams(bufs)

    plans = leafplan.build_plans(params, cfg)

    # donate like the fused train step does: record is an in-place row write
    # there, not a full-buffer copy
    rec_plain = jax.jit(snap.record, donate_argnums=(0,))
    def _rec_stream(b, g, p, slot):
        b = snap.record(b, p, slot, plans)
        return b, snap.update_grams(g, b, p, slot, cfg, plans)
    rec_stream = jax.jit(_rec_stream, donate_argnums=(0, 1))

    for slot in range(m):                        # fill one window
        params = {"w": params["w"] + 0.01}
        bufs, grams = rec_stream(bufs, grams, params, slot)

    copy = lambda t: jax.tree_util.tree_map(jnp.copy, t)

    def _loop_plain(b):
        for _ in range(reps):
            b = rec_plain(b, params, m - 1)
        return b

    def _loop_stream(b, g):
        for _ in range(reps):
            b, g = rec_stream(b, g, params, m - 1)
        return b, g

    _loop_plain(copy(bufs))                      # compile (consumes the copy)
    _loop_stream(copy(bufs), copy(grams))
    b = copy(bufs)
    jax.block_until_ready(b)
    t0 = time.time(); jax.block_until_ready(_loop_plain(b))
    t_rec_plain = (time.time() - t0) / reps
    b, g = copy(bufs), copy(grams)
    jax.block_until_ready(b)
    t0 = time.time(); jax.block_until_ready(_loop_stream(b, g))
    t_rec_stream = (time.time() - t0) / reps
    # apply() donates the param leaves: hand each call its own copies, or
    # rep 1 dies with 'Array has been deleted' on backends that honor
    # donation (TPU/GPU). The O(n) copy is equal overhead for both paths.
    fresh = lambda: jax.tree_util.tree_map(jnp.copy, params)
    t_apply_rec = _timeit(lambda: acc_r.apply(fresh(), bufs, 0), reps=reps)
    t_apply_stream = _timeit(
        lambda: acc_s.apply(fresh(), bufs, 0, grams=grams), reps=reps)

    f_gram, f_row, f_comb = 2 * m * m * n, 2 * m * n, 2 * m * n
    f_apply_rec = f_gram + f_comb
    f_apply_stream = f_comb + 2 * m ** 3
    b_buf = 4 * m * n
    rows = [
        "streaming,metric,recompute_seed,streaming,reduction",
        # The headline O(m^2*n) -> O(m*n) change: the Gram work done at each
        # maintenance event (one full recompute per window vs one row pass
        # per record) — exactly the m x factor.
        f"streaming,gram_flops_per_event,{f_gram:.3e},{f_row:.3e},"
        f"{f_gram / f_row:.1f}x (predicted m={m})",
        f"streaming,apply_flops,{f_apply_rec:.3e},{f_apply_stream:.3e},"
        f"{f_apply_rec / f_apply_stream:.1f}x (predicted ~(m+1)={m + 1}: "
        f"the combine pass is shared)",
        f"streaming,apply_buffer_bytes,{2 * b_buf:.3e},{b_buf:.3e},2.0x",
        f"streaming,apply_wall_ms,{t_apply_rec * 1e3:.2f},"
        f"{t_apply_stream * 1e3:.2f},{t_apply_rec / t_apply_stream:.1f}x "
        f"(the synchronous jump stall every m steps)",
        f"streaming,record_wall_ms,{t_rec_plain * 1e3:.2f},"
        f"{t_rec_stream * 1e3:.2f},"
        f"(streaming amortizes one O(m*n)={f_row:.1e}-FLOP row pass into "
        f"each train step, where it overlaps backprop — DESIGN.md 2.3)",
        f"streaming,m,{m},n,{n}",
    ]
    return rows


def sharded_gram(m=8, L=4, d0=256, d1=512, reps=10) -> List[str]:
    """ISSUE 2 tentpole evidence: the three LeafPlan kernel routes
    (DESIGN.md §3) on one stacked (m, L, d0, d1) buffer leaf — the shape the
    seed could never kernel-route (it fell back to the batched dot_general
    to avoid GSPMD all-gathers from flattening).

      * dot_general        batched contraction (the seed path / oracle)
      * pallas_shard_map   local flatten + kernel + psum under shard_map
                           (sharded over whatever mesh the host exposes;
                           degrades to local vmapped kernels on 1 device)
      * pallas_flat        the flat kernel on the same data pre-flattened —
                           only legal because this benchmark's buffer is
                           unsharded; shown as the roofline reference.

    On CPU the shard_map route's local compute dispatches to the dot_general
    refs (kernels/ops.py), so the comparison measures dispatch + collective
    overhead; on TPU it measures the compiled kernels.
    """
    import dataclasses as _dc

    import jax.numpy as jnp
    from repro.core import snapshots as _snap
    from repro.kernels import sharded as _sharded

    rng = np.random.default_rng(0)
    params = {"seg": jnp.asarray(rng.normal(size=(L, d0, d1)), jnp.float32)}
    cfg = DMDConfig(m=m, s=40, tol=1e-4, anchor="first", warmup_steps=0,
                    cooldown_steps=0)

    mesh = None
    try:
        ndev = len(jax.devices())
        if ndev >= 2:
            nd = 2 if ndev < 8 else 8
            mesh = jax.make_mesh((nd // 2, 2), ("data", "model"))
    except Exception:
        mesh = None

    def plans_with(route):
        c = _dc.replace(cfg, kernel_route=route)
        return leafplan.build_plans(params, c, mesh,
                                    stack_dims={"seg": 1})

    buf = jnp.asarray(rng.normal(size=(m, L, d0, d1)), jnp.float32)
    p = {"seg": buf[-1]}
    c_coef = jnp.asarray(rng.normal(size=(L, m)), jnp.float32)

    rows = ["sharded_gram,route,row_us,combine_us,note"]
    for route in ("dot_general", "pallas_shard_map"):
        plans = plans_with(route)
        pl = plans["seg"]
        grams = {"seg": jnp.zeros((L, m, m), jnp.float32)}

        def upd(g, b, pp):
            return _snap.update_grams(g, {"seg": b}, pp, m - 1, cfg, plans)
        t_row = _timeit(jax.jit(upd), grams, buf, p, reps=reps)

        if route == "pallas_shard_map":
            comb = jax.jit(lambda b, cc: _sharded.combine(b, cc, pl))
        else:
            from repro.core.dmd import combine_snapshots
            comb = jax.jit(lambda b, cc: combine_snapshots(
                b, cc, stack_dims=1))
        t_comb = _timeit(comb, buf, c_coef, reps=reps)
        note = (f"mesh={'x'.join(map(str, mesh.devices.shape))}"
                if mesh is not None and route == "pallas_shard_map"
                else "local")
        rows.append(f"sharded_gram,{route},{t_row * 1e6:.0f},"
                    f"{t_comb * 1e6:.0f},{note}")

    # pallas_flat roofline reference on the pre-flattened (unsharded) copy
    from repro.kernels import ops as _ops
    flat = buf.reshape(m, -1)
    t_row = _timeit(jax.jit(lambda b, q: _ops.gram_row(b, q)), flat, flat[-1],
                    reps=reps)
    t_comb = _timeit(jax.jit(lambda b, cc: _ops.combine(b, cc)), flat,
                     c_coef[0], reps=reps)
    rows.append(f"sharded_gram,pallas_flat,{t_row * 1e6:.0f},"
                f"{t_comb * 1e6:.0f},preflattened reference (no stack)")
    rows.append(f"sharded_gram,m,{m},shape,{L}x{d0}x{d1}")
    return rows


def staggered_jump(m=14, sizes=(6, 800, 800, 800), reps=10) -> List[str]:
    """ISSUE 3 tentpole evidence: the per-leaf schedule's two wins over the
    synchronous every-m-steps jump (DESIGN.md §4).

      1. SPIKE: the synchronous schedule jumps EVERY leaf at the same step —
         one whole-tree stall per window. The staggered config splits the
         leaves into phase-offset groups whose jump steps are provably
         disjoint, so the max per-step jump cost is the largest single
         GROUP's jump, strictly below the whole-tree spike.
      2. MEMORY: a small-m group for the 1-D leaves (norms/biases) stores
         half the snapshot rows — measured as summed buffer bytes from the
         plan table (reported absolute: the vector-leaf share of an MLP's
         bytes is small; on transformer configs the same rule also covers
         every norm scale).

    Groups: half the matrices stay on the default (m=14, phase 0, jump
    residue 13 mod 14); the other half get phase 7 via a path rule (residue
    6 mod 14); 1-D leaves get (m=7, phase 3) — cycle 7 divides 14 and both
    matrix residues are ≡ 6 mod 7 while the vector group jumps ≡ 2 mod 7,
    so ALL three jump-step residue classes are pairwise disjoint forever.
    The schedule audit row counts the max number of groups jumping on any
    one step over a long horizon (1 when staggered, "all leaves at once"
    for the synchronous baseline).
    """
    from repro.core.schedule import DMDGroupRule

    rng = np.random.default_rng(0)
    base = dict(s=55, tol=1e-4, anchor="first", warmup_steps=0,
                cooldown_steps=0)
    cfg_sync = DMDConfig(m=m, **base)
    cfg_stag = DMDConfig(m=m, groups=(
        # l2's matrix = the second heavy block: same window, half-cycle
        # phase (min_ndim=2 keeps l2's bias in the vectors group below)
        DMDGroupRule(name="late_half", path_regex="/l2/", min_ndim=2,
                     phase=m // 2),
        # 1-D leaves: half-length windows, their own disjoint residue
        DMDGroupRule(name="vectors", max_ndim=1, m=m // 2, phase=3),
    ), **base)

    params = init_mlp(jax.random.PRNGKey(0), sizes)

    def setup(cfg):
        acc = DMDAccelerator(cfg)
        bufs = acc.init(params)
        grams = acc.init_grams(bufs)
        p = params
        # fill every group's window with a drifting trajectory
        fill = max(g.warmup_steps + g.phase + g.cycle for g in acc.groups)
        for t in range(fill):
            p = jax.tree_util.tree_map(
                lambda x: x + 0.01 * jnp.asarray(
                    rng.normal(size=x.shape), jnp.float32), p)
            if acc.should_record(t):
                bufs, grams = acc.record(bufs, p, acc.slots(t), grams)
        return acc, p, bufs, grams

    def time_jump(acc, p, bufs, grams, groups):
        """Median of per-call walls, each blocked to completion — the
        SPIKE is a max-statistic, so the estimator must resist CPU timing
        noise (mean-of-pipelined-reps does not)."""
        fresh = lambda: jax.tree_util.tree_map(jnp.copy, p)
        f = lambda: acc.apply(fresh(), bufs, grams=grams, groups=groups)[0]
        jax.block_until_ready(f())                           # compile
        walls = []
        for _ in range(reps):
            p0 = fresh()
            jax.block_until_ready(p0)
            t0 = time.time()
            jax.block_until_ready(
                acc.apply(p0, bufs, grams=grams, groups=groups)[0])
            walls.append(time.time() - t0)
        return float(np.median(walls)) * 1e3                 # ms

    def jump_flops(acc, groups):
        """Analytic per-jump cost (deterministic counterpart of the wall
        row): one combine pass 2*m*n + O(m^3) algebra per jumped leaf."""
        from repro.core.leafplan import plan_entries
        return sum(2 * pl.m * pl.flat_size * int(np.prod(pl.stack_shape))
                   + 2 * pl.m ** 3
                   for pl in plan_entries(acc.plans_for(params))
                   if pl.group in groups)

    acc_sync, p_s, bufs_s, grams_s = setup(cfg_sync)
    t_sync = time_jump(acc_sync, p_s, bufs_s, grams_s, (0,))
    f_sync = jump_flops(acc_sync, (0,))

    acc_stag, p_t, bufs_t, grams_t = setup(cfg_stag)
    per_group = [time_jump(acc_stag, p_t, bufs_t, grams_t, (g.index,))
                 for g in acc_stag.groups]
    t_stag_max = max(per_group)
    f_stag_max = max(jump_flops(acc_stag, (g.index,))
                     for g in acc_stag.groups)

    # schedule audit over a long horizon: groups jumping per step
    horizon = 4000
    conc = max(len(acc_stag.apply_groups(t)) for t in range(horizon))
    n_jump_steps_sync = sum(bool(acc_sync.apply_groups(t))
                            for t in range(horizon))
    n_jump_steps_stag = sum(bool(acc_stag.apply_groups(t))
                            for t in range(horizon))

    def buffer_bytes(acc):
        from repro.core.leafplan import plan_entries
        plans = acc.plans_for(params)
        return sum(4 * pl.m * int(np.prod(pl.shape))
                   for pl in plan_entries(plans))

    b_sync, b_stag = buffer_bytes(acc_sync), buffer_bytes(acc_stag)

    rows = [
        "staggered_jump,metric,synchronous,staggered,note",
        f"staggered_jump,max_step_jump_ms,{t_sync:.2f},{t_stag_max:.2f},"
        f"spike ratio {t_sync / max(t_stag_max, 1e-9):.2f}x (largest single "
        f"group vs whole tree; median of blocked calls)",
        f"staggered_jump,max_step_jump_flops,{f_sync:.3e},{f_stag_max:.3e},"
        f"analytic {f_sync / f_stag_max:.2f}x (combine + m^3 algebra per "
        f"jumped leaf — deterministic)",
        "staggered_jump,per_group_jump_ms,-,"
        + "/".join(f"{t:.2f}" for t in per_group)
        + "," + "/".join(g.name for g in acc_stag.groups),
        f"staggered_jump,max_groups_jumping_per_step,"
        f"{len(acc_sync.groups) and 'all-leaves'},{conc},"
        f"phase residues disjoint over {horizon} steps",
        f"staggered_jump,jump_steps_per_{horizon},{n_jump_steps_sync},"
        f"{n_jump_steps_stag},staggered pays MORE often but each spike is "
        f"smaller (amortized)",
        f"staggered_jump,snapshot_buffer_bytes,{b_sync},{b_stag},"
        f"{b_sync - b_stag} bytes saved by halving the vector group's "
        f"window ({(1 - b_stag / b_sync) * 100:.2f}% of this MLP's total)",
        f"staggered_jump,m,{m},sizes,{'x'.join(map(str, sizes))}",
    ]
    return rows


class _MLPModel:
    """Trainer adapter for the paper's regression MLP: `init`/`loss` is the
    whole contract Trainer needs; batches are {"x", "y"} dicts."""

    def __init__(self, sizes):
        self.sizes = sizes

    def init(self, key):
        return init_mlp(jax.random.PRNGKey(0) if key is None else key,
                        self.sizes)

    def loss(self, params, batch):
        return mse_loss(params, batch["x"], batch["y"]), None


def controller(steps=450, sizes=(6, 40, 100, 400), m=14, s=55,
               log_every=25) -> List[str]:
    """ISSUE 4 tentpole evidence: the loss-gated adaptive jump controller
    (core/controller.py, DESIGN.md §5) against the fixed PR-3 schedule on
    the pollutant MLP at EQUAL step count.

      * final-loss row: the gated run must match or beat the fixed
        schedule's final train MSE (the gate can only drop or temper jumps
        the held-out loss dislikes; everything else is bit-identical math).
      * accept/scale/reject counters + unrecovered rejects: a rejected jump
        whose post-decision eval loss still exceeds the pre-jump loss would
        mean the rollback leaked — must be 0 (the rollback oracle test pins
        the same property elementwise).
      * loss-vs-wall trajectory: sampled (step, wall_s, train_mse) rows for
        both runs — the gate's extra forwards ride only on jump steps.
      * gate overhead: median wall of the jitted gated jump vs the ungated
        jump on the same state (the one extra params-sized buffer + 2-3
        microbatch forwards).
    """
    from repro.configs.base import (ArchConfig, ModelConfig, ParallelConfig,
                                    TrainConfig)
    from repro.train import Trainer

    # ONE teacher function, split into train + held-out rows: the gate must
    # score jumps on unseen samples of the SAME task. (fig3/fig4 use a
    # different-seed "test set", i.e. a different teacher — fine for their
    # generalization-gap curves, fatal for a loss gate: an unrelated
    # objective rejects legitimate jumps.)
    Xall, Yall = _synthetic_regression(n=750, n_out=sizes[-1])
    X, Y = Xall[:600], Yall[:600]
    batch = {"x": X, "y": Y}
    eval_batch = {"x": Xall[600:], "y": Yall[600:]}

    def acfg_for(ctrl_on):
        dmd = DMDConfig(
            m=m, s=s, tol=1e-4, warmup_steps=100, cooldown_steps=10,
            controller=DMDControllerConfig(enabled=ctrl_on, eval_rows=0))
        return ArchConfig(
            model=ModelConfig(name="pollutant-mlp", family="mlp"),
            dmd=dmd,
            optimizer=OptimizerConfig(name="adam", lr=1e-3),
            parallel=ParallelConfig(grad_accum=1),
            train=TrainConfig(global_batch=int(X.shape[0]), seq_len=1),
            shapes=())

    def run(ctrl_on):
        trainer = Trainer(_MLPModel(sizes), acfg_for(ctrl_on))
        outcomes, curve = [], []
        t0 = time.time()

        def on_m(t, metrics):
            if "ctrl_outcome" in metrics:
                outcomes.append((t, int(metrics["ctrl_outcome"]),
                                 float(metrics["ctrl_loss_pre"]),
                                 float(metrics["ctrl_loss_jump"]),
                                 float(metrics["ctrl_loss_kept"])))
            if t % log_every == 0 or t == steps - 1:
                curve.append((t, time.time() - t0, float(metrics["loss"])))

        state = trainer.fit(iter(lambda: batch, None), steps,
                            on_metrics=on_m, eval_batch=eval_batch)
        final = float(mse_loss(state.params, X, Y))
        return trainer, state, final, outcomes, curve

    tr_fix, st_fix, loss_fix, _, curve_fix = run(False)
    tr_ctl, st_ctl, loss_ctl, outcomes, curve_ctl = run(True)

    ctrl = st_ctl.controller
    n_acc = int(ctrl.accepts.sum())
    n_scl = int(ctrl.scaled.sum())
    n_rej = int(ctrl.rejects.sum())
    # Unrecovered-reject audit: a rollback leak would surface as the train
    # loss right after a rejected jump sitting above the pre-jump eval loss
    # by more than the normal step-to-step wobble. (The rollback oracle test
    # in tests/test_trainer.py pins the same property elementwise; this row
    # is the run-level evidence the acceptance criteria ask for.)
    unrecovered = 0
    for (t, o, pre, jump, kept) in outcomes:
        if o != 0:
            continue
        after = [l for (ts, _, l) in curve_ctl if ts > t]
        if after and after[0] > pre * 1.10:
            unrecovered += 1

    # gate overhead: jitted gated vs ungated jump on identical cloned state.
    # DONATED like the Trainer's deployment (donate_argnums=(0,)) — the old
    # un-donated jit here silently dropped the donation the controller path
    # relies on, so the measured "gate overhead" included params/buffer
    # copies the real training loop never pays. Donation invalidates the
    # input state, so each rep RETHREADS the returned state instead of
    # re-passing the same clone (jump steps are state -> state).
    from repro.train.step import make_dmd_step
    jump_step = next(t for t in range(steps)
                     if tr_ctl.acc.apply_groups(t))
    relax = jnp.asarray(tr_ctl.acc.relax_vector(jump_step), jnp.float32)
    groups = tr_ctl.acc.apply_groups(jump_step)
    clone = lambda st: jax.tree_util.tree_map(
        lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, st,
        is_leaf=lambda x: x is None)

    gated = jax.jit(make_dmd_step(acfg_for(True), acc=tr_ctl.acc,
                                  model=_MLPModel(sizes)),
                    donate_argnums=(0,), static_argnames=("groups",))
    plain = jax.jit(make_dmd_step(acfg_for(False), acc=tr_fix.acc),
                    donate_argnums=(0,), static_argnames=("groups",))

    def walls(fn, st, reps=7):
        st = fn(st)[0]                                # compile
        ts = []
        for _ in range(reps):
            t0 = time.time()
            st, _ = fn(st)
            jax.block_until_ready(st.params)
            ts.append(time.time() - t0)
        return float(np.median(ts)) * 1e3

    t_gated = walls(lambda st: gated(st, relax, eval_batch, groups=groups),
                    clone(st_ctl))
    t_plain = walls(lambda st: plain(st, relax, groups=groups),
                    clone(st_fix))

    rows = [
        "controller,metric,fixed_schedule,controller,note",
        f"controller,final_train_mse,{loss_fix:.5e},{loss_ctl:.5e},"
        f"equal step count ({steps}); gated run "
        f"{'BEATS' if loss_ctl <= loss_fix else 'LOSES TO'} fixed "
        f"({loss_fix / max(loss_ctl, 1e-30):.2f}x)",
        f"controller,jump_outcomes,-,"
        f"accept={n_acc}/scaled={n_scl}/reject={n_rej},"
        f"{len(outcomes)} gated jumps",
        f"controller,unrecovered_rejects,-,{unrecovered},"
        f"post-reject train loss never exceeds pre-jump eval loss +10%",
        f"controller,s_eff_final,-,"
        + "/".join(f"{v:.1f}" for v in np.asarray(ctrl.s_eff))
        + f",adapted horizon (cap {s})",
        f"controller,relax_eff_final,-,"
        + "/".join(f"{v:.3f}" for v in np.asarray(ctrl.relax_eff))
        + ",effective relax scale",
        f"controller,jump_step_wall_ms,{t_plain:.2f},{t_gated:.2f},"
        f"gate overhead {t_gated - t_plain:+.2f} ms on jump steps only "
        f"(2-3 eval forwards + one params-sized blend)",
    ]
    for (t, w, l) in curve_fix:
        rows.append(f"controller,curve_fixed,{t},{w:.2f},{l:.5e}")
    for (t, w, l) in curve_ctl:
        rows.append(f"controller,curve_gated,{t},{w:.2f},{l:.5e}")
    for (t, o, pre, jump, kept) in outcomes:
        rows.append(f"controller,gate,{t},"
                    f"{['reject', 'scaled', 'accept'][o]},"
                    f"pre={pre:.5e} jump={jump:.5e} kept={kept:.5e}")
    return rows


def sec3_overhead(m=14, t_samples=800) -> List[str]:
    """Paper §3: DMD ops ~ n(3m^2+r^2) vs backprop ~ 6nt per epoch; plus
    measured wall times for the paper-sized MLP."""
    sizes = (6, 40, 200, 1000, 2670)
    params = init_mlp(jax.random.PRNGKey(0), sizes)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    r = m - 1
    dmd_ops = n * (3 * m ** 2 + r ** 2)
    bp_ops = 6 * n * t_samples
    rows = [f"sec3,analytic_dmd_ops_per_round,{dmd_ops:.3e}",
            f"sec3,analytic_backprop_ops_per_epoch,{bp_ops:.3e}",
            f"sec3,dmd_rounds_per_m_epochs_overhead,"
            f"{dmd_ops / (m * bp_ops):.4f}"]

    # measured wall: one train step vs one DMD jump on the paper MLP
    X = jnp.asarray(np.random.default_rng(0).uniform(
        -1, 1, size=(t_samples, 6)), jnp.float32)
    Y = jnp.asarray(np.random.default_rng(1).normal(
        size=(t_samples, 2670)), jnp.float32)
    opt = make_optimizer(OptimizerConfig(name="adam", lr=1e-3))
    state = opt.init(params)

    @jax.jit
    def step(p, s, t):
        loss, g = jax.value_and_grad(lambda pp: mse_loss(pp, X, Y))(p)
        u, s = opt.update(g, s, p, t)
        return apply_updates(p, u), s, loss

    acc = DMDAccelerator(DMDConfig(m=m, s=55, tol=1e-4))
    bufs = acc.init(params)
    p, s = params, state
    for t in range(m):                               # warm + fill buffers
        p, s, _ = step(p, s, jnp.asarray(t))
        bufs, _ = acc.record(bufs, p, t % m)
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])

    t0 = time.time()
    reps = 10
    for t in range(reps):
        p, s, _ = step(p, s, jnp.asarray(t))
    jax.block_until_ready(jax.tree_util.tree_leaves(p)[0])
    t_step = (time.time() - t0) / reps

    p2, _ = acc.apply(p, bufs, 0)                    # compile
    jax.block_until_ready(jax.tree_util.tree_leaves(p2)[0])
    t0 = time.time()
    for _ in range(reps):
        p2, _ = acc.apply(p, bufs, 0)
    jax.block_until_ready(jax.tree_util.tree_leaves(p2)[0])
    t_dmd = (time.time() - t0) / reps

    overhead = 1.0 + t_dmd / (m * t_step)
    rows += [f"sec3,measured_train_step_ms,{t_step*1e3:.2f}",
             f"sec3,measured_dmd_jump_ms,{t_dmd*1e3:.2f}",
             f"sec3,wall_overhead_factor,{overhead:.3f}",
             "sec3,paper_wall_overhead_factor,1.41 (host-copy bound); "
             "theoretical 1.07"]
    return rows
