"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
results/ JSONs.

    PYTHONPATH=src:. python -m benchmarks.report [--dryrun results/dryrun]
        [--roofline results/roofline]

Prints markdown to stdout (EXPERIMENTS.md embeds the output).
"""
import argparse
import json
from pathlib import Path

from repro.configs import STANDARD_SHAPES, list_archs


def gib(x):
    return f"{x / 2**30:.2f}"


def dryrun_table(dryrun_dir: Path, mesh: str) -> str:
    rows = ["| arch | shape | status | compile s | args GiB/dev | "
            "peak GiB/dev | fits 16G | HLO GFLOPs/dev | collectives |",
            "|---|---|---|---|---|---|---|---|---|"]
    for arch in list_archs():
        for shape in [s.name for s in STANDARD_SHAPES]:
            f = dryrun_dir / f"{arch}__{shape}__{mesh}.json"
            if not f.exists():
                continue
            r = json.loads(f.read_text())
            if r["status"] == "skipped":
                rows.append(f"| {arch} | {shape} | SKIP (by design) | — | — "
                            f"| — | — | — | — |")
                continue
            if r["status"] != "ok":
                rows.append(f"| {arch} | {shape} | **FAIL** | — | — | — | — "
                            f"| — | — |")
                continue
            m = r["memory"]
            cc = r.get("collective_counts", {})
            coll = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                            for k, v in sorted(cc.items()))
            flops = (r["cost"]["flops"] or 0) / 1e9
            rows.append(
                f"| {arch} | {shape} | ok | {r['compile_s']:.0f} "
                f"| {gib(m['argument_bytes'])} | {gib(m['peak_bytes'])} "
                f"| {'yes' if r['fits_hbm'] else 'NO'} | {flops:.1f} "
                f"| {coll} |")
    return "\n".join(rows)


def roofline_table(roof_dir: Path) -> str:
    rows = ["| arch | shape | t_compute | t_memory | t_collective | "
            "bottleneck | roofline frac | useful (6ND/HLO) |",
            "|---|---|---|---|---|---|---|---|"]
    for arch in list_archs():
        for shape in [s.name for s in STANDARD_SHAPES]:
            f = roof_dir / f"{arch}__{shape}__single.json"
            if not f.exists():
                continue
            r = json.loads(f.read_text())
            if r.get("status") != "ok":
                continue
            t = r["terms"]

            def ms(x):
                return f"{x*1e3:.1f}ms" if x < 10 else f"{x:.1f}s"
            rows.append(
                f"| {arch} | {shape} | {ms(t['t_compute_s'])} "
                f"| {ms(t['t_memory_s'])} | {ms(t['t_collective_s'])} "
                f"| **{r['bottleneck']}** | {r['roofline_fraction']:.3f} "
                f"| {r['useful_ratio']:.2f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--roofline", default="results/roofline")
    args = ap.parse_args()
    print("### Dry-run (single pod, 16x16 = 256 chips)\n")
    print(dryrun_table(Path(args.dryrun), "single"))
    print("\n### Dry-run (multi-pod, 2x16x16 = 512 chips)\n")
    print(dryrun_table(Path(args.dryrun), "multi"))
    print("\n### Roofline (single pod)\n")
    print(roofline_table(Path(args.roofline)))


if __name__ == "__main__":
    main()
