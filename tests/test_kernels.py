"""Pallas kernels vs jnp oracles: shape x dtype sweeps in interpret mode."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("m,n", [(14, 5000), (8, 2048), (20, 333), (4, 128),
                                 (14, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("anchor", [False, True])
def test_gram_kernel(m, n, dtype, anchor):
    S = jnp.asarray(RNG.normal(size=(m, n)), dtype)
    g = ops.gram(S, anchor_first=anchor, interpret=True)
    g_ref = ref.gram_ref(S, anchor_first=anchor)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=tol,
                               atol=tol * max(1.0, float(jnp.max(jnp.abs(g_ref)))))


@pytest.mark.parametrize("m,n", [(14, 5000), (8, 2048), (20, 333), (4, 128),
                                 (14, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("anchor", [False, True])
def test_gram_row_kernel(m, n, dtype, anchor):
    """Streaming row kernel == ref, including row written into slot 0 (the
    anchor itself: the anchored row must be exactly zero)."""
    S = jnp.asarray(RNG.normal(size=(m, n)), dtype)
    for slot in (0, m // 2, m - 1):
        p = S[slot]
        r = ops.gram_row(S, p, anchor_first=anchor, interpret=True)
        r_ref = ref.gram_row_ref(S, p, anchor_first=anchor)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(r), np.asarray(r_ref), rtol=tol,
            atol=tol * max(1.0, float(jnp.max(jnp.abs(r_ref)))))
        if anchor and slot == 0:
            assert float(jnp.max(jnp.abs(r))) == 0.0


@pytest.mark.parametrize("n", [7, 130])
@pytest.mark.parametrize("anchor", [False, True])
def test_tiny_leaf_kernels_match_oracle(n, anchor):
    """Regression (ISSUE 2): _block used to return blocks that were not
    128-lane multiples for 128 < n < block_n (n=130 -> block 130) and
    oversized tiles for n < 128; both now clamp to one lane-padded tile with
    the padding handled by the wrappers (zero lanes contribute zero)."""
    from repro.kernels.ops import _block
    assert _block(2048, 7) == 128
    assert _block(2048, 130) == 256
    assert _block(2048, 333) == 384              # lane multiple, < 2048
    assert _block(2048, 5000) == 2048
    m = 6
    rng = np.random.default_rng(100 + n)       # local stream: the shared RNG
    S = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)   # order must stay
    c = jnp.asarray(rng.normal(size=(m,)), jnp.float32)     # stable for the
                                                            # atol=0 tests
    g = ops.gram(S, anchor_first=anchor, interpret=True)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(ref.gram_ref(S, anchor_first=anchor)),
                               rtol=1e-5, atol=1e-5)
    r = ops.gram_row(S, S[2], anchor_first=anchor, interpret=True)
    np.testing.assert_allclose(
        np.asarray(r), np.asarray(ref.gram_row_ref(S, S[2],
                                                   anchor_first=anchor)),
        rtol=1e-5, atol=1e-5)
    w = ops.combine(S, c, interpret=True)
    np.testing.assert_allclose(np.asarray(w), np.asarray(ref.combine_ref(S, c)),
                               rtol=1e-5, atol=1e-5)


def test_sharded_wrappers_local_path_matches_oracle():
    """kernels/sharded.py with no mesh degrades to local (vmapped) kernels —
    same contract as the flat kernels, per stacked layer."""
    from repro.configs.base import DMDConfig
    from repro.core import leafplan
    from repro.core.dmd import combine_snapshots, gram_matrix, gram_row_matrix
    from repro.kernels import sharded

    rng = np.random.default_rng(7)
    cfg = DMDConfig(m=5, anchor="first")
    params = {"seg": jnp.asarray(rng.normal(size=(3, 9, 11)), jnp.float32)}
    plans = leafplan.build_plans(params, cfg, stack_dims={"seg": 1})
    pl = plans["seg"]
    buf = jnp.asarray(rng.normal(size=(5, 3, 9, 11)), jnp.float32)
    g = sharded.gram(buf, pl, anchor_first=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(g), np.asarray(gram_matrix(buf, anchor="first",
                                              stack_dims=1)),
        rtol=1e-5, atol=1e-5)
    r = sharded.gram_row(buf, buf[2], pl, anchor_first=True, interpret=True)
    np.testing.assert_allclose(
        np.asarray(r), np.asarray(gram_row_matrix(buf, buf[2],
                                                  anchor="first",
                                                  stack_dims=1)),
        rtol=1e-5, atol=1e-5)
    c = jnp.asarray(rng.normal(size=(3, 5)), jnp.float32)
    w = sharded.combine(buf, c, pl, interpret=True)
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(combine_snapshots(buf, c, stack_dims=1)),
        rtol=1e-5, atol=1e-5)


def test_gram_row_matches_full_gram_row():
    """The kernel's row equals the corresponding row of the full Gram."""
    S = jnp.asarray(RNG.normal(size=(10, 700)), jnp.float32)
    g = ops.gram(S, anchor_first=True, interpret=True)
    for slot in (0, 4, 9):
        r = ops.gram_row(S, S[slot], anchor_first=True, interpret=True)
        np.testing.assert_allclose(np.asarray(r), np.asarray(g)[slot],
                                   rtol=1e-5, atol=1e-4)


def test_dispatch_routes_by_backend():
    """ops auto-routing: ref on CPU (never the Pallas interpreter), Pallas
    when forced; both agree numerically."""
    assert jax.default_backend() != "tpu"
    assert ops.active_backend() == "ref"
    S = jnp.asarray(RNG.normal(size=(6, 300)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(6,)), jnp.float32)
    auto_g = ops.gram(S, anchor_first=True)         # interpret=None -> ref
    auto_r = ops.gram_row(S, S[2], anchor_first=True)
    auto_w = ops.combine(S, c)
    try:
        ops.set_backend("pallas")                   # forced, interpret body
        assert ops.active_backend() == "pallas"
        pal_g = ops.gram(S, anchor_first=True, interpret=True)
        pal_r = ops.gram_row(S, S[2], anchor_first=True, interpret=True)
        pal_w = ops.combine(S, c, interpret=True)
    finally:
        ops.set_backend(None)
    np.testing.assert_allclose(np.asarray(auto_g), np.asarray(pal_g),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(auto_r), np.asarray(pal_r),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(auto_w), np.asarray(pal_w),
                               rtol=1e-5, atol=1e-4)


def test_dispatch_ref_path_no_flatten_multidim():
    """The ref route contracts trailing axes in place (sharding-safe) and
    matches the flattened kernel result."""
    S = jnp.asarray(RNG.normal(size=(6, 8, 12)), jnp.float32)
    g = ops.gram(S, anchor_first=True)
    flat = np.asarray(S).reshape(6, -1)
    flat = flat - flat[:1]
    np.testing.assert_allclose(np.asarray(g), flat @ flat.T, rtol=1e-5,
                               atol=1e-4)
    r = ops.gram_row(S, S[3], anchor_first=True)
    np.testing.assert_allclose(np.asarray(r), (flat @ flat[3]), rtol=1e-5,
                               atol=1e-4)


@pytest.mark.parametrize("m,n", [(14, 5000), (8, 100), (6, 4096)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_combine_kernel(m, n, dtype):
    S = jnp.asarray(RNG.normal(size=(m, n)), dtype)
    c = jnp.asarray(RNG.normal(size=(m,)), jnp.float32)
    w = ops.combine(S, c, interpret=True)
    w_ref = ref.combine_ref(S, c)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(w), np.asarray(w_ref), rtol=tol,
                               atol=tol * 10)


def test_combine_multidim():
    S = jnp.asarray(RNG.normal(size=(6, 8, 12)), jnp.float32)
    c = jnp.asarray(RNG.normal(size=(6,)), jnp.float32)
    w = ops.combine(S, c, interpret=True)
    assert w.shape == (8, 12)
    np.testing.assert_allclose(
        np.asarray(w), np.asarray(ref.combine_ref(S.reshape(6, -1), c)
                                  ).reshape(8, 12), rtol=1e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,K,d,causal,window", [
    (1, 128, 128, 4, 4, 64, True, 0),
    (2, 256, 256, 4, 2, 64, True, 0),
    (1, 256, 256, 2, 2, 64, True, 64),
    (1, 100, 100, 2, 1, 32, False, 0),
    (1, 64, 192, 2, 2, 128, True, 0),          # Sq != Sk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_kernel(B, Sq, Sk, H, K, d, causal, window, dtype):
    q = jnp.asarray(RNG.normal(size=(B, Sq, H, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Sk, K, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Sk, K, d)), dtype)
    o = ops.flash_attention(q, k, v, causal=causal, window=window,
                            tq=64, tk=64, interpret=True)
    kr, vr = jnp.repeat(k, H // K, axis=2), jnp.repeat(v, H // K, axis=2)
    o_ref = ref.flash_attention_ref(q, kr, vr, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=tol * 50)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(4, 20), n=st.integers(16, 700),
       seed=st.integers(0, 100))
def test_gram_kernel_property(m, n, seed):
    rng = np.random.default_rng(seed)
    S = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    g = np.asarray(ops.gram(S, interpret=True))
    np.testing.assert_allclose(g, g.T, rtol=1e-5, atol=1e-5)  # symmetric
    assert np.all(np.diag(g) >= -1e-5)                        # PSD diag
    np.testing.assert_allclose(g, np.asarray(ref.gram_ref(S)), rtol=1e-4,
                               atol=1e-3)
