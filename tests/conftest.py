import os
import sys

# Tests run on the single real CPU device (the dry-run alone uses the
# 512-device flag). Keep JAX quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
