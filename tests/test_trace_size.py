"""Trace-size guard: pin the jitted train step's jaxpr equation count for
a pollutant-MLP-style config and a reduced transformer config, so per-leaf
unrolling can never silently regress the trace again.

Since ISSUE 6 the counting AND the ceilings live in the shared audit
layer: repro.audit.passes::trace_budget counts via repro.trace.count_eqns
and compares against repro/audit/pins.py (keys "deep-mlp-24x32" and
"tinyllama-1.1b-reduced" here). This file only builds the programs and
routes them through the pass — bump procedure is in pins.py / DESIGN.md §8.

The packed-arena route (DESIGN.md §7) replaced the O(leaves) per-leaf
record/gram fan-out with O(buckets) segmented passes, and arena-native
residency (dmd.arena_native) then removed the pack concatenate from the
record arm entirely — the fused step records with one dynamic_update_slice
per bucket. The ceilings sit between the measured resident counts (with
slack for innocuous refactors) and the pack-copy route's counts — e.g. the
24-layer-MLP fused step traces 2906 equations per-leaf vs 1731 pack-copy
vs 1143 resident (the remainder is the model's own forward+backward+adam,
which the arena cannot shrink), and the reduced tinyllama step 1137 vs 870
vs 723. If a change pushes the count past the pin, either the change
reintroduced a per-leaf unroll or the pack-copy record (fix it) or it
legitimately grew the program (re-measure and bump the pin in the SAME
commit, with the reason)."""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import (DMDConfig, OptimizerConfig, TrainConfig)
from repro.models.mlp_net import init_mlp, mse_loss
from repro.models.transformer import LanguageModel
from repro.audit.passes import trace_budget
from repro.audit.targets import adhoc_context, jaxpr_target
from repro.train.state import TrainState
from repro.train.step import make_train_step


class _MLP:
    def __init__(self, sizes):
        self.sizes = sizes

    def init(self, key):
        return init_mlp(key, self.sizes)

    def loss(self, params, batch):
        return mse_loss(params, batch["x"], batch["y"]), None


def test_deep_mlp_train_step_trace_pinned():
    """24-layer MLP (48 DMD leaves, one bucket): the fused train step's
    trace must stay bucket-sized, not leaf-sized."""
    sizes = [32] * 25
    model = _MLP(sizes)
    acfg = get_config("pollutant-mlp")
    acfg = dataclasses.replace(
        acfg,
        dmd=DMDConfig(m=6, s=10, warmup_steps=2, cooldown_steps=1),
        optimizer=OptimizerConfig(name="adam", lr=1e-3),
        train=TrainConfig(global_batch=8, seq_len=1))
    from repro.core.accelerator import DMDAccelerator
    acc = DMDAccelerator(acfg.dmd)
    # share the accelerator with the step: a resident state only carries
    # flat buckets, so the step's acc must hold the plan/bucket tables
    # built from the leafwise params (exactly what Trainer does)
    step = make_train_step(model, acfg, loss_fn=lambda p, b: model.loss(
        p, b)[0], acc=acc)
    params = model.init(jax.random.PRNGKey(0))
    bufs = acc.init(params)
    state = TrainState(params, jax.eval_shape(
        lambda p: p, params), jnp.zeros((), jnp.int32), bufs,
        acc.init_grams(bufs))
    batch = {"x": jnp.zeros((8, 32)), "y": jnp.zeros((8, 32))}
    # opt_state shaped like adam's: build the real one
    from repro.optim import make_optimizer
    opt = make_optimizer(acfg.optimizer)
    state = state._replace(opt_state=opt.init(params))
    # trace over the layout training actually runs: resident buckets
    # (train/loop.py applies the same conversion at fit() entry)
    from repro.train.step import state_resident
    state = state_resident(acc, acfg, state)
    jx = jax.make_jaxpr(step)(state, batch, jnp.asarray(5, jnp.int32))
    # measured 1143 resident vs 1731 pack-copy vs 2906 per-leaf (the fixed
    # cost is the 24-layer forward+backward+adam); the ceiling in pins.py
    # sits below the pack-copy count so a residency regression fails first
    ctx = adhoc_context("deep-mlp-24x32", acfg,
                        {"train_step": jaxpr_target("train_step", jx)})
    violations, info = trace_budget(ctx)
    assert violations == [], violations
    assert info["train_step.pin"] == {"eqns": 1500}  # pinned, not skipped


def test_transformer_train_step_trace_pinned():
    """Reduced tinyllama: scan-stacked leaves + embeddings, two dtypes."""
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(m=4, s=10, warmup_steps=4, cooldown_steps=2),
        optimizer=OptimizerConfig(name="adam", lr=3e-3),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=4, seq_len=16))
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    from repro.core.accelerator import DMDAccelerator
    acc = DMDAccelerator(acfg.dmd, stack_dims=model.param_stack_dims())
    step = make_train_step(model, acfg, acc=acc)
    params = model.init(jax.random.PRNGKey(0))
    from repro.optim import make_optimizer
    opt = make_optimizer(acfg.optimizer)
    bufs = acc.init(params)
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                       bufs, acc.init_grams(bufs))
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
             "labels": jnp.zeros((4, 16), jnp.int32)}
    from repro.train.step import state_resident
    state = state_resident(acc, acfg, state)
    jx = jax.make_jaxpr(step)(state, batch, jnp.asarray(5, jnp.int32))
    # measured 723 resident vs 870 pack-copy vs 1137 per-leaf; the ceiling
    # in pins.py sits below the pack-copy count so a route regression fails
    # before any slack is eaten by legitimate model-side growth
    ctx = adhoc_context("tinyllama-1.1b-reduced", acfg,
                        {"train_step": jaxpr_target("train_step", jx)})
    violations, info = trace_budget(ctx)
    assert violations == [], violations
    assert info["train_step.pin"]["eqns"] == 850


def test_transformer_bucket_scope_trace_and_solve_budget_pinned():
    """The same reduced tinyllama under dmd.scope="bucket" (DESIGN.md §9):
    the fused step stays eqn-identical (pinned under the
    "tinyllama-1.1b-reduced-bucket" key) and — the guard eqn counts cannot
    provide, since the batched eigh is ONE equation in either scope — the
    jump's solve ROWS collapse to n_buckets, enforced by the solve-budget
    pass. The leaf-scope jump jaxpr run against the bucket-scope budget
    must FAIL the same pass (the silent-fallback defect is detectable)."""
    from repro.audit.passes import solve_budget
    from repro.train.step import make_dmd_step

    def build(scope):
        acfg = get_config("tinyllama-1.1b")
        mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64,
                     vocab_size=128, n_heads=2, n_kv_heads=1, head_dim=16)
        acfg = dataclasses.replace(
            acfg, model=mc,
            dmd=DMDConfig(m=4, s=10, warmup_steps=4, cooldown_steps=2,
                          scope=scope),
            optimizer=OptimizerConfig(name="adam", lr=3e-3),
            parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                         remat="none"),
            train=TrainConfig(global_batch=4, seq_len=16))
        model = LanguageModel(mc, head_tp=False, chunk_k=16)
        from repro.core.accelerator import DMDAccelerator
        acc = DMDAccelerator(acfg.dmd, stack_dims=model.param_stack_dims())
        step = make_train_step(model, acfg, acc=acc)
        params = model.init(jax.random.PRNGKey(0))
        from repro.optim import make_optimizer
        opt = make_optimizer(acfg.optimizer)
        bufs = acc.init(params)
        state = TrainState(params, opt.init(params),
                           jnp.zeros((), jnp.int32), bufs,
                           acc.init_grams(bufs))
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
                 "labels": jnp.zeros((4, 16), jnp.int32)}
        from repro.train.step import state_resident
        state = state_resident(acc, acfg, state)
        jx = jax.make_jaxpr(step)(state, batch, jnp.asarray(5, jnp.int32))
        dstep = make_dmd_step(acfg, acc=acc, model=model)
        relax = jnp.ones((acc.n_groups,), jnp.float32)
        jd = jax.make_jaxpr(lambda st, r: dstep(st, r, groups=None))(
            state, relax)
        return acfg, acc, params, jx, jd

    acfg, acc, params, jx, jd = build("bucket")
    ctx = adhoc_context(
        "tinyllama-1.1b-reduced-bucket", acfg,
        {"train_step": jaxpr_target("train_step", jx),
         "dmd_step": jaxpr_target("dmd_step", jd)},
        plans=acc.plans_for(params), arena=acc.arena_for(params))
    violations, info = trace_budget(ctx)
    assert violations == [], violations
    assert info["train_step.pin"]["eqns"] == 850   # pinned, not skipped
    assert info["dmd_step.pin"]["eqns"] == 430
    sv, sinfo = solve_budget(ctx)
    assert sv == [], sv
    # the whole point: one batched solve row per bucket (measured 2 here
    # vs 21 under leaf scope), budget == sum of gram_lead over the table
    assert sinfo["solve_budget_rows"] == len(acc.arena_for(params))
    assert sinfo["dmd_step.eigh_rows"] == sinfo["solve_budget_rows"]

    # leaf-scope jump traced into the bucket-scope context: rows explode
    # past the budget and the pass must bite
    _, _, _, _, jd_leaf = build("leaf")
    ctx_bad = adhoc_context(
        "tinyllama-1.1b-reduced-bucket", acfg,
        {"dmd_step": jaxpr_target("dmd_step", jd_leaf)},
        plans=acc.plans_for(params), arena=acc.arena_for(params))
    bad_v, bad_info = solve_budget(ctx_bad)
    assert bad_v, bad_info
    assert any("per-jump solve budget" in v.detail for v in bad_v)
