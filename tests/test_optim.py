"""Optimizers vs hand-rolled numpy references; schedules."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import OptimizerConfig
from repro.optim import (adam, adam8bit, apply_updates,
                         clip_by_global_norm, global_norm, make_optimizer,
                         make_schedule)


def _quad_problem(seed=0, n=32):
    rng = np.random.default_rng(seed)
    A = rng.normal(size=(n, n)).astype(np.float32)
    H = A @ A.T / n + 0.1 * np.eye(n, dtype=np.float32)
    w_star = rng.normal(size=n).astype(np.float32)
    params = {"w": jnp.asarray(rng.normal(size=n), jnp.float32)}

    def loss(p):
        d = p["w"] - w_star
        return 0.5 * d @ jnp.asarray(H) @ d
    return params, loss


def test_adam_matches_numpy_reference():
    lr, b1, b2, eps = 1e-2, 0.9, 0.999, 1e-8
    opt = adam(lambda s: lr, b1, b2, eps)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads_seq = [np.array([0.1, -0.2, 0.3], np.float32),
                 np.array([-0.5, 0.5, 0.0], np.float32),
                 np.array([1.0, 1.0, -1.0], np.float32)]
    state = opt.init(params)
    w_np = np.array([1.0, -2.0, 3.0])
    m = np.zeros(3)
    v = np.zeros(3)
    for t, g in enumerate(grads_seq):
        u, state = opt.update({"w": jnp.asarray(g)}, state, params,
                              jnp.asarray(t))
        params = apply_updates(params, u)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** (t + 1))
        vh = v / (1 - b2 ** (t + 1))
        w_np = w_np - lr * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.asarray(params["w"]), w_np, rtol=1e-5)


@pytest.mark.parametrize("name", ["sgd", "momentum", "adam", "adamw",
                                  "adafactor", "adam8bit"])
def test_optimizers_decrease_quadratic(name):
    params, loss = _quad_problem()
    # adafactor/adam8bit need larger steps here: update-RMS clipping and the
    # int8 block-absmax noise floor respectively (tiny 1-block problem).
    lr = {"adafactor": 0.5, "adam8bit": 0.15}.get(name, 5e-2)
    cfg = OptimizerConfig(name=name, lr=lr)
    opt = make_optimizer(cfg)
    state = opt.init(params)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s, t: opt.update(jax.grad(loss)(p), s, p, t))
    for t in range(60):
        u, state = step(params, state, jnp.asarray(t))
        params = apply_updates(params, u)
    target = 0.5 if name == "adam8bit" else 0.3   # int8 moment noise
    assert float(loss(params)) < target * l0, name


def test_adam8bit_tracks_adam():
    params, loss = _quad_problem(seed=1)
    o1 = adam(lambda s: 2e-2)
    o2 = adam8bit(lambda s: 2e-2)
    p1 = p2 = params
    s1, s2 = o1.init(p1), o2.init(p2)
    for t in range(30):
        g1 = jax.grad(loss)(p1)
        g2 = jax.grad(loss)(p2)
        u1, s1 = o1.update(g1, s1, p1, jnp.asarray(t))
        u2, s2 = o2.update(g2, s2, p2, jnp.asarray(t))
        p1 = apply_updates(p1, u1)
        p2 = apply_updates(p2, u2)
    l1, l2 = float(loss(p1)), float(loss(p2))
    assert abs(l1 - l2) < 0.5 * abs(l1) + 0.05


def test_wsd_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, schedule="wsd", warmup_steps=10,
                          total_steps=100, decay_fraction=0.2,
                          min_lr_ratio=0.1)
    f = make_schedule(cfg)
    assert float(f(0)) < 0.2                       # warming up
    assert abs(float(f(50)) - 1.0) < 1e-6          # stable plateau
    assert abs(float(f(79)) - 1.0) < 0.06          # just before decay
    assert float(f(99)) < 0.2                      # decayed
    assert float(f(99)) >= 0.1 - 1e-6              # floor


def test_cosine_schedule_monotone_after_warmup():
    cfg = OptimizerConfig(lr=1.0, schedule="cosine", warmup_steps=5,
                          total_steps=50, min_lr_ratio=0.0)
    f = make_schedule(cfg)
    vals = [float(f(s)) for s in range(5, 50, 5)]
    assert all(a >= b - 1e-6 for a, b in zip(vals, vals[1:]))


def test_global_norm_clip():
    tree = {"a": jnp.asarray([3.0, 4.0])}
    assert abs(float(global_norm(tree)) - 5.0) < 1e-6
    clipped = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_grad_clip_in_factory():
    cfg = OptimizerConfig(name="sgd", lr=1.0, grad_clip=1.0)
    opt = make_optimizer(cfg)
    params = {"w": jnp.zeros(2)}
    state = opt.init(params)
    u, _ = opt.update({"w": jnp.asarray([30.0, 40.0])}, state, params,
                      jnp.asarray(0))
    assert abs(float(global_norm(u)) - 1.0) < 1e-4
