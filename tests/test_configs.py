"""Config registry + assigned shape coverage + LeafPlan dispatch pins."""
import pytest

from repro.configs import (STANDARD_SHAPES, get_config, list_archs,
                           shape_by_name)

LONG_RUNNERS = {"gemma3-27b", "zamba2-2.7b", "mamba2-2.7b"}


def test_ten_archs_registered():
    assert len(list_archs()) == 10


def test_all_configs_load():
    for arch in list_archs():
        acfg = get_config(arch)
        assert acfg.model.name == arch


def test_standard_shapes():
    names = [s.name for s in STANDARD_SHAPES]
    assert names == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    assert shape_by_name("train_4k").global_batch == 256
    assert shape_by_name("long_500k").seq_len == 524288


def test_long500k_assignment_matches_design():
    for arch in list_archs():
        acfg = get_config(arch)
        has_long = "long_500k" in acfg.shapes
        assert has_long == (arch in LONG_RUNNERS), arch
        if not has_long:
            assert acfg.skip_notes            # the skip is documented


def test_exact_paper_dims():
    g = get_config("gemma3-27b").model
    assert (g.n_layers, g.d_model, g.n_heads, g.n_kv_heads, g.d_ff,
            g.vocab_size) == (62, 5376, 32, 16, 21504, 262144)
    q = get_config("qwen3-moe-30b-a3b").model
    assert (q.moe.n_experts, q.moe.top_k, q.moe.expert_d_ff) == (128, 8, 768)
    l4 = get_config("llama4-maverick-400b-a17b").model
    assert (l4.moe.n_experts, l4.moe.top_k) == (128, 1)
    m = get_config("mamba2-2.7b").model
    assert (m.n_layers, m.d_model, m.ssm.state_dim) == (64, 2560, 128)
    z = get_config("zamba2-2.7b").model
    assert (z.n_layers, z.ssm.state_dim, z.shared_attn_every) == (54, 64, 6)
    w = get_config("whisper-base").model
    assert (w.n_layers, w.n_encoder_layers, w.d_model, w.vocab_size) == \
        (6, 6, 512, 51865)
    mc = get_config("minicpm-2b")
    assert mc.optimizer.schedule == "wsd"
    assert mc.model.vocab_size == 122753
    gr = get_config("granite-20b").model
    assert (gr.n_kv_heads, gr.d_ff) == (1, 24576)
    qv = get_config("qwen2-vl-7b").model
    assert qv.mrope_sections == (16, 24, 24)
    t = get_config("tinyllama-1.1b").model
    assert (t.n_layers, t.n_kv_heads, t.vocab_size) == (22, 4, 32000)


def test_llama4_dmd_excludes_experts():
    acfg = get_config("llama4-maverick-400b-a17b")
    assert acfg.dmd.param_filter == "non_expert"


# ---------------------------------------------------------------------------
# LeafPlan dispatch-table pins (ISSUE 2 acceptance): every selected leaf of
# the production configs gets a route + structural stack_dims. Regression-
# pinned so a refactor of the plan layer cannot silently reroute a leaf.
# ---------------------------------------------------------------------------

# {arch: {path: (route, stack_dims)}} — meshless table: flat-safe leaves ->
# pallas_flat, every stacked leaf -> pallas_shard_map (vmapped kernels;
# shard_map + psum once a mesh is active and the leaf is sharded).
PLAN_PINS = {
    "qwen3-moe-30b-a3b": {
        "/emb": ("pallas_flat", 0),
        "/lm_head": ("pallas_flat", 0),
        "/final_norm/scale": ("pallas_flat", 0),
        "/seg0/attn/wq": ("pallas_shard_map", 1),
        "/seg0/attn/wo": ("pallas_shard_map", 1),
        "/seg0/moe/experts_in": ("pallas_shard_map", 1),
        "/seg0/moe/experts_out": ("pallas_shard_map", 1),
        "/seg0/moe/router": ("pallas_shard_map", 1),
    },
    "zamba2-2.7b": {
        "/emb": ("pallas_flat", 0),
        "/shared_block/attn/wq": ("pallas_flat", 0),     # stored ONCE
        "/shared_block/mlp/w_in": ("pallas_flat", 0),
        "/seg0/mamba/ssm/A_log": ("pallas_shard_map", 2),
        "/seg0/mamba/ssm/in_proj/x": ("pallas_shard_map", 2),
        "/seg0/mamba/ssm/out_proj": ("pallas_shard_map", 2),
    },
    "gemma3-27b": {
        "/emb": ("pallas_flat", 0),
        "/final_norm/scale": ("pallas_flat", 0),
        "/seg0/local/attn/wq": ("pallas_shard_map", 2),  # 5 locals per group
        "/seg0/local/mlp/w_in": ("pallas_shard_map", 2),
        "/seg0/global/attn/wq": ("pallas_shard_map", 1),
        "/seg1/attn/wq": ("pallas_shard_map", 1),        # 2-local tail
    },
}


@pytest.mark.parametrize("arch", sorted(PLAN_PINS))
def test_leafplan_table_pinned(arch):
    """plan_table() assigns EVERY selected leaf a route, and the pinned
    (route, stack_dims) entries match the structural segment layout."""
    from repro.core import DMDAccelerator, leafplan
    from repro.models.transformer import init_params, param_stack_dims

    acfg = get_config(arch)
    params = init_params(acfg.model, abstract=True)
    acc = DMDAccelerator(acfg.dmd,
                         stack_dims=param_stack_dims(acfg.model, params))
    table = acc.plan_table(params)
    plans = acc.plans_for(params)
    summ = leafplan.plan_summary(plans)

    # every selected leaf has a valid route and appears in the table
    assert summ, arch
    for path, (route, k) in summ.items():
        assert route in leafplan.ROUTES, (path, route)
        assert path in table
    # stack dims == leading dims consumed by the scan layout; buffers' Gram
    # batch shape follows (plan_shapes test covers the shape agreement)
    for path, expect in PLAN_PINS[arch].items():
        assert summ.get(path) == expect, (path, summ.get(path), expect)
    # stacked leaves never route to the flat kernels (flatten would merge
    # per-layer trajectories — the paper's DMD is per-layer)
    for path, (route, k) in summ.items():
        if k > 0:
            assert route != "pallas_flat", path
