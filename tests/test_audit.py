"""The audit lane must bite (ISSUE 6 acceptance): a clean build passes
every registered pass, and EACH seeded mutation (repro.audit.mutations)
flips exactly its pass to failing. A lane that cannot fail guards
nothing — these tests pin the failure side the CI mutation step relies
on, on the cheapest config that exercises each pass (the reduced paper
MLP; force-allgather needs a sharded build, so it runs the real CLI in a
subprocess that forces an 8-device CPU topology)."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

from repro.audit import run_audit
from repro.audit.mutations import get as get_mutation, list_mutations


def _failed(report):
    return {r.name for r in report.results if not r.ok}


def test_clean_reduced_mlp_audit_is_green():
    report = run_audit("pollutant-mlp", reduced=True)
    assert report.ok, report.render()
    assert {r.name for r in report.results} == {
        "donation-alias", "collective-budget", "trace-budget",
        "solve-budget", "dtype-flow", "host-callback-in-hot-loop",
        "arena-layout", "arena-residency", "schedule-conflict",
        "serve-compile"}


def test_drop_donation_bites():
    report = run_audit("pollutant-mlp", reduced=True,
                       mutate="drop-donation")
    assert not report.ok
    assert "donation-alias" in _failed(report), report.render()


def test_misalign_arena_bites():
    report = run_audit("pollutant-mlp", reduced=True,
                       mutate="misalign-arena", passes=["arena-layout"])
    assert _failed(report) == {"arena-layout"}, report.render()
    details = " ".join(v.detail for v in report.violations)
    assert "aligned" in details or "lane_start" in details


def test_overlap_groups_bites():
    report = run_audit("pollutant-mlp", reduced=True,
                       mutate="overlap-groups",
                       passes=["schedule-conflict"])
    assert _failed(report) == {"schedule-conflict"}, report.render()
    assert any("rules match one leaf" in v.detail
               for v in report.violations)


def test_force_pack_bites():
    """Re-packing resident params inside record_update must trip the
    arena-residency pass: the bucket-sized 1-D concatenate the resident
    record exists to delete reappears in the traced program."""
    report = run_audit("pollutant-mlp", reduced=True, mutate="force-pack",
                       passes=["arena-residency"])
    assert _failed(report) == {"arena-residency"}, report.render()
    assert any("concatenate/gather" in v.detail
               for v in report.violations)


def test_force_allgather_needs_mesh():
    with pytest.raises(Exception, match="mesh"):
        run_audit("pollutant-mlp", reduced=True, mutate="force-allgather",
                  passes=["collective-budget"])


def test_force_leaf_solves_bites():
    """A bucket-scope build whose jump still batches one coefficient
    system per leaf must trip the solve-budget pass: the eigh/callback
    batch rows exceed the one-solve-per-bucket budget (DESIGN.md §9)."""
    report = run_audit("pollutant-mlp", reduced=True,
                       mutate="force-leaf-solves", passes=["solve-budget"])
    assert _failed(report) == {"solve-budget"}, report.render()
    assert any("per-jump solve budget" in v.detail
               for v in report.violations)


def test_mutation_registry_is_complete():
    assert list_mutations() == ["drop-donation", "force-allgather",
                                "force-leaf-solves", "force-pack",
                                "force-recompile", "misalign-arena",
                                "overlap-groups"]
    for name in list_mutations():
        m = get_mutation(name)
        assert m.expect_fail in ("donation-alias", "collective-budget",
                                 "solve-budget", "arena-layout",
                                 "arena-residency", "schedule-conflict",
                                 "serve-compile")


@pytest.mark.slow
def test_cli_mesh_clean_and_force_allgather_bites(tmp_path):
    """The sharded build end-to-end through the real CLI: clean rc=0 with
    an AUDIT json artifact, force-allgather rc!=0 with a buffer-sized
    all-gather in the collective-budget violations. Subprocess because
    --mesh must force the CPU device count before jax imports."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = str(Path(__file__).parent.parent / "src")
    base = [sys.executable, "-m", "repro.audit", "--arch",
            "tinyllama-1.1b", "--reduced", "--mesh", "2x4",
            "--out", str(tmp_path)]
    clean = subprocess.run(base, capture_output=True, text=True, env=env,
                           timeout=900)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    artifact = tmp_path / "AUDIT_tinyllama-1.1b-reduced-mesh.json"
    payload = json.loads(artifact.read_text())
    assert payload["ok"] is True
    assert {"plans", "arena", "groups"} <= set(payload["tables"])

    mutated = subprocess.run(
        base + ["--mutate", "force-allgather", "--no-json"],
        capture_output=True, text=True, env=env, timeout=900)
    assert mutated.returncode != 0, mutated.stdout + mutated.stderr
    assert "all-gather" in mutated.stdout
    assert "[FAIL] collective-budget" in mutated.stdout


def test_schedule_conflict_flags_bad_controller_keys():
    """ISSUE 9: the schedule-conflict pass audits the NEW controller knobs —
    an unsatisfiable gate (accept_tol <= -1), an out-of-range shrink
    ladder, a negative ridge_max, and a non-EMA meta_lr each produce a
    violation; the clean default config reports the knob table in info."""
    import dataclasses

    import jax.numpy as jnp

    from repro.audit.passes import schedule_conflict
    from repro.audit.targets import adhoc_context
    from repro.configs import get_config
    from repro.configs.base import DMDConfig, DMDControllerConfig
    from repro.core import DMDAccelerator
    from repro.core.schedule import resolve_groups

    def ctx_for(ccfg):
        acfg = dataclasses.replace(
            get_config("pollutant-mlp"),
            dmd=DMDConfig(m=4, s=10, controller=ccfg))
        acc = DMDAccelerator(acfg.dmd)
        params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
        return adhoc_context("ctrl-audit", acfg, {},
                             plans=acc.plans_for(params),
                             groups=resolve_groups(acfg.dmd))

    vs, info = schedule_conflict(ctx_for(DMDControllerConfig(enabled=True)))
    assert vs == [], vs
    # satellite: the DEFAULT accept_tol is a small positive band (0.0 let
    # noise-level ties reject real jumps)
    assert info["controller"]["accept_tol"] == pytest.approx(1e-3)
    assert info["controller"]["shrink_levels"] == [0.5]

    bad = DMDControllerConfig(enabled=True, accept_tol=-1.0,
                              shrink_levels=(0.0, 1.5), meta_lr=2.0,
                              ridge_max=-1.0)
    vs, _ = schedule_conflict(ctx_for(bad))
    details = " ".join(v.detail for v in vs)
    for frag in ("accept_tol", "shrink_levels entry", "ridge_max",
                 "meta_lr"):
        assert frag in details, (frag, details)

    # controller OFF: no controller block, no controller violations
    vs, info = schedule_conflict(ctx_for(DMDControllerConfig()))
    assert vs == [] and "controller" not in info
