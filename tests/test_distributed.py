"""Distributed behaviour via subprocess workers (8 virtual host devices).

Single-device equivalence, sharded DMD Gram correctness, int8 cross-pod
gradient sync, and ELASTIC restart (checkpoint written on a (2,2) mesh
restored onto a (4,2) mesh).
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

WORKER = str(Path(__file__).parent / "dist_worker.py")


def run_worker(*args, ndev="8", timeout=600):
    env = dict(os.environ)
    env["TEST_NDEV"] = ndev
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, WORKER, *args],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"worker failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


def _parse(line_prefix, stdout):
    for line in stdout.splitlines():
        if line.startswith(line_prefix):
            return line.split()[1:]
    raise AssertionError(f"{line_prefix} not in output:\n{stdout}")


@pytest.mark.slow
def test_sharded_training_matches_single_device():
    out_sharded = run_worker("train", "2x4")
    out_single = run_worker("train", "1x1", ndev="1")
    l_sh = [float(x) for x in _parse("LOSSES", out_sharded)]
    l_si = [float(x) for x in _parse("LOSSES", out_single)]
    for a, b in zip(l_sh, l_si):
        assert abs(a - b) / max(abs(b), 1e-6) < 2e-2, (l_sh, l_si)


@pytest.mark.slow
def test_multipod_training_runs():
    out = run_worker("train", "2x2x2")
    losses = [float(x) for x in _parse("LOSSES", out)]
    assert losses[-1] < losses[0] * 1.5
    assert all(l == l for l in losses)           # no NaN


def test_sharded_gram_matches_numpy():
    out = run_worker("gram")
    err = float(_parse("GRAM_ERR", out)[0])
    assert err < 1e-5


def test_int8_cross_pod_gradsync():
    out = run_worker("gradsync")


@pytest.mark.slow
def test_elastic_restart_different_mesh(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    run_worker("elastic_save", ckpt)
    out = run_worker("elastic_restore", ckpt)
    assert "RESTORED" in out


@pytest.mark.slow
@pytest.mark.parametrize("variant,saved_step", [("jump", 6), ("mid", 8)])
def test_controller_preempt_restore_on_remapped_mesh(tmp_path, variant,
                                                     saved_step):
    """ISSUE 4 satellite: SIGTERM lands on the exact jump step ("jump" —
    the checkpoint carries that jump's fresh gate outcome) or mid-window
    ("mid") with the loss-gated controller on, on a (2,2) mesh; restore on
    the REMAPPED (4,2) mesh must resume controller counters, effective s_g,
    relax/gain EMAs, and the cooldown/window phase BIT-EXACTLY (the workers
    print a canonical CTRL line; save and restore must emit it verbatim),
    then finish the run with the remaining gated jumps firing."""
    ckpt = str(tmp_path / f"ckpt_{variant}")
    out_save = run_worker("ctrl_save", ckpt, variant)
    assert f"SAVED {saved_step}" in out_save
    out_restore = run_worker("ctrl_restore", ckpt, str(saved_step))
    assert "CTRL_OK" in out_restore
    line_save = next(l for l in out_save.splitlines()
                     if l.startswith("CTRL "))
    line_restore = next(l for l in out_restore.splitlines()
                        if l.startswith("CTRL "))
    assert line_save == line_restore


@pytest.mark.slow
def test_resident_restore_on_remapped_mesh(tmp_path):
    """ISSUE 7: a run whose params live arena-RESIDENT (adam,
    arena_native on) checkpoints mid-training on a (2,2) mesh — the
    on-disk format is leaf-wise — and restores on the REMAPPED (4,2)
    mesh, where the per-leaf elastic re-placement rebuilds the resident
    sharded buckets for the NEW topology and training continues on the
    resident layout. The params checksum survives the save/remap/restore
    round trip."""
    ckpt = str(tmp_path / "ckpt_resident")
    out_save = run_worker("resident_save", ckpt)
    out_restore = run_worker("resident_restore", ckpt)
    assert "RESIDENT_OK" in out_restore
    saved = float(_parse("SAVED", out_save)[0])
    restored = float(_parse("RESTORED", out_restore)[0])
    assert abs(saved - restored) / max(abs(saved), 1.0) < 1e-5


@pytest.mark.slow
@pytest.mark.parametrize("variant", ["keep", "zero", "hetero"])
def test_gram_restore_on_remapped_mesh(tmp_path, variant):
    """A streaming-era checkpoint (grams carried), a zeroed-gram /
    pre-streaming checkpoint (grams rebuilt by recompute_grams' batched
    staleness pass), and a HETEROGENEOUS two-group checkpoint (norm scales
    on m=3 windows, the rest on m=4) all resume to gram_matrix equality on
    a REMAPPED mesh with per-group buffer/Gram shapes intact."""
    ckpt = str(tmp_path / f"ckpt_{variant}")
    run_worker("gram_save", ckpt, variant)
    out = (run_worker("gram_restore", ckpt, "hetero")
           if variant == "hetero" else run_worker("gram_restore", ckpt))
    assert "GRAMS_OK" in out
