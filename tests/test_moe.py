"""MoE: capacity dispatch vs a dense per-token loop oracle."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig, ModelConfig
from repro.models.moe import apply_moe, moe_init


def dense_moe_oracle(x, p, cfg):
    """Every token through its full top-k experts (no capacity)."""
    m = cfg.moe
    B, S, D = x.shape
    x2 = np.asarray(x, np.float64).reshape(-1, D)
    logits = x2 @ np.asarray(p["router"], np.float64)
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, m.top_k)
    top_p, top_i = np.asarray(top_p, np.float64), np.asarray(top_i)
    wi = np.asarray(p["experts_in"], np.float64)
    wg = np.asarray(p["experts_gate"], np.float64)
    wo = np.asarray(p["experts_out"], np.float64)
    out = np.zeros_like(x2)
    for t in range(x2.shape[0]):
        for j in range(m.top_k):
            e = int(top_i[t, j])
            h = x2[t] @ wi[e]
            g = x2[t] @ wg[e]
            g = g / (1 + np.exp(-g))                      # silu
            out[t] += top_p[t, j] * ((g * h) @ wo[e])
    return out.reshape(B, S, D)


def _cfg(top_k=2, n_experts=8, cf=8.0):
    return ModelConfig(
        d_model=16, act="silu", dtype="float32",
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, expert_d_ff=32,
                      capacity_factor=cf))


@pytest.mark.parametrize("top_k", [1, 2, 4])
def test_moe_matches_oracle_at_high_capacity(top_k):
    """cf high enough that nothing drops -> exact match with dense loop."""
    cfg = _cfg(top_k=top_k)
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    out, aux = apply_moe(x, p, cfg)
    ref = dense_moe_oracle(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out), ref, atol=1e-4, rtol=1e-3)
    assert float(aux) >= 0.0


def test_moe_capacity_drops_bounded():
    """Low capacity: output differs but stays finite & bounded."""
    cfg = _cfg(top_k=2, cf=0.25)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    out, _ = apply_moe(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    ref = dense_moe_oracle(x, p, cfg)
    assert float(jnp.max(jnp.abs(out))) <= abs(ref).max() * 2 + 1.0


def test_shared_expert_added():
    cfg = dataclasses.replace(
        _cfg(), moe=dataclasses.replace(_cfg().moe, n_shared_experts=1,
                                        shared_d_ff=32))
    p = moe_init(jax.random.PRNGKey(0), cfg)
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    out, _ = apply_moe(x, p, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_aux_loss_prefers_balance():
    """Uniform routing gives aux ~ weight*1; collapsed routing > uniform."""
    from repro.models.moe import aux_load_balance_loss
    E, T = 8, 256
    probs_u = jnp.full((1, T, E), 1.0 / E)
    top_u = jnp.asarray(np.random.default_rng(0).integers(0, E, (1, T, 1)))
    aux_u = aux_load_balance_loss(probs_u, top_u, E)
    probs_c = jnp.zeros((1, T, E)).at[..., 0].set(1.0)
    top_c = jnp.zeros((1, T, 1), jnp.int32)
    aux_c = aux_load_balance_loss(probs_c, top_c, E)
    assert float(aux_c) > float(aux_u)
    np.testing.assert_allclose(float(aux_u), 1.0, atol=0.1)
