"""LeafPlan registry: structural stack dims, route selection, spec
derivation, and the plan-threaded accelerator invariants."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import DMDConfig
from repro.core import DMDAccelerator, leafplan
from repro.core import snapshots as snap
from repro.models.transformer import init_params, param_stack_dims


def small_params():
    rng = np.random.default_rng(0)
    return {"w": jnp.asarray(rng.normal(size=(16, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32),
            "seg0": {"wq": jnp.asarray(rng.normal(size=(3, 16, 8)),
                                       jnp.float32)}}


def test_build_plans_routes_and_stack_dims():
    cfg = DMDConfig(m=4)
    plans = leafplan.build_plans(small_params(), cfg,
                                 stack_dims={"w": 0, "b": 0,
                                             "seg0": {"wq": 1}})
    summ = leafplan.plan_summary(plans)
    assert summ == {"/w": ("pallas_flat", 0), "/b": ("pallas_flat", 0),
                    "/seg0/wq": ("pallas_shard_map", 1)}
    pl = plans["seg0"]["wq"]
    assert pl.stack_shape == (3,)
    assert pl.flat_size == 16 * 8
    assert pl.gram_spec == P(None, None, None)
    assert pl.psum_axes() == ()                  # no mesh -> fully local


SD = {"w": 0, "b": 0, "seg0": {"wq": 1}}


def test_param_filter_excludes_leaves():
    cfg = DMDConfig(m=4, min_param_size=10)
    plans = leafplan.build_plans(small_params(), cfg, stack_dims=SD)
    assert plans["b"] is None                    # 8 < 10
    assert plans["w"] is not None


def test_missing_stack_annotation_on_segmented_tree_raises():
    """A seg<i>-keyed tree with no stack_dims would silently merge per-layer
    trajectories into one Gram — build_plans refuses instead."""
    with pytest.raises(ValueError, match="stack_dims"):
        leafplan.build_plans(small_params(), DMDConfig(m=4))
    # flat pytrees (no segment convention) still default to stack 0
    plans = leafplan.build_plans({"w": small_params()["w"]}, DMDConfig(m=4))
    assert plans["w"].stack_dims == 0


def test_kernel_route_override():
    cfg = DMDConfig(m=4, kernel_route="dot_general")
    plans = leafplan.build_plans(small_params(), cfg, stack_dims=SD)
    assert all(p.route == "dot_general"
               for p in leafplan.plan_entries(plans))
    # forcing pallas_flat cannot apply to stacked leaves — they keep auto
    cfg2 = DMDConfig(m=4, kernel_route="pallas_flat")
    plans2 = leafplan.build_plans(small_params(), cfg2, stack_dims=SD)
    assert plans2["w"].route == "pallas_flat"
    assert plans2["seg0"]["wq"].route == "pallas_shard_map"
    with pytest.raises(ValueError, match="kernel_route"):
        leafplan.build_plans(small_params(), DMDConfig(kernel_route="nope"),
                             stack_dims=SD)


def test_block_n_clamped_to_leaf():
    cfg = DMDConfig(m=4)
    plans = leafplan.build_plans(small_params(), cfg, stack_dims=SD)
    assert plans["b"].block_n == 128             # 8 -> one 128-lane tile
    assert plans["w"].block_n == 128             # 16*8 = exactly one tile
    assert leafplan.default_block_n(5000) == 2048
    assert leafplan.default_block_n(130) == 256
    assert leafplan.default_block_n(7) == 128


def test_structural_stack_dims_match_model_layout():
    """The stack annotation is derived from the segment plan — spot-check
    each stacking pattern (plain seg scan, gemma local sub-stack, zamba
    mamba sub-stack, unstacked shared block)."""
    g = get_config("gemma3-27b").model
    sd = param_stack_dims(g)
    assert sd["emb"] == 0
    assert sd["seg0"]["local"]["attn"]["wq"] == 2
    assert sd["seg0"]["global"]["attn"]["wq"] == 1
    assert sd["seg1"]["attn"]["wq"] == 1         # dense_local tail

    z = get_config("zamba2-2.7b").model
    sdz = param_stack_dims(z)
    assert sdz["shared_block"]["attn"]["wq"] == 0
    assert sdz["seg0"]["mamba"]["ssm"]["A_log"] == 2

    q = get_config("qwen3-moe-30b-a3b").model
    sdq = param_stack_dims(q)
    assert sdq["seg0"]["moe"]["experts_in"] == 1
    assert sdq["lm_head"] == 0


def test_plan_shapes_consistent_with_buffers_and_grams():
    """init_buffers/init_grams sized by the plan agree with the leaf shapes
    for every production config (abstract params — no allocation)."""
    for arch in ("gemma3-27b", "zamba2-2.7b", "qwen3-moe-30b-a3b"):
        acfg = get_config(arch)
        params = init_params(acfg.model, abstract=True)
        plans = leafplan.build_plans(params, acfg.dmd, None,
                                     param_stack_dims(acfg.model, params))
        bufs = snap.init_buffers(params, acfg.dmd, plans)
        grams = snap.init_grams(bufs, acfg.dmd, plans)

        def chk(pl, p, b, g):
            if pl is None:
                assert b is None and g is None
                return None
            assert b.shape == (acfg.dmd.m,) + tuple(p.shape)
            assert g.shape == pl.stack_shape + (acfg.dmd.m, acfg.dmd.m)
            assert pl.stack_shape == tuple(p.shape[:pl.stack_dims])
            return None
        jax.tree_util.tree_map(chk, plans, params, bufs, grams,
                               is_leaf=leafplan.is_plan_leaf)


def test_plan_table_renders_every_selected_leaf():
    acfg = get_config("qwen3-moe-30b-a3b")
    acc = DMDAccelerator(acfg.dmd,
                         stack_dims=param_stack_dims(acfg.model))
    table = acc.plan_table(init_params(acfg.model, abstract=True))
    assert "/seg0/attn/wqkv" in table or "/seg0/attn/wq" in table
    assert "pallas_shard_map" in table and "route" in table
    n_selected = len(leafplan.plan_entries(acc._plans))
    assert len(table.splitlines()) == n_selected + 2   # header + rule


def test_plans_for_cache_keyed_by_dtype():
    """Regression (ISSUE 3 satellite): the plan cache used to hash only
    structure+shape, so a bf16<->fp32 param cast silently reused a stale
    table (wrong recorded dtypes / audit rows). The key now includes leaf
    dtypes: a cast rebuilds the plans, identical metadata reuses them."""
    cfg = DMDConfig(m=4)
    acc = DMDAccelerator(cfg, stack_dims=SD)
    params = small_params()
    plans_f32 = acc.plans_for(params)
    assert plans_f32["w"].dtype == "float32"
    assert acc.plans_for(params) is plans_f32            # cache hit
    cast = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), params)
    plans_bf16 = acc.plans_for(cast)
    assert plans_bf16 is not plans_f32                   # dtype -> rebuild
    assert plans_bf16["w"].dtype == "bfloat16"
    assert acc.plans_for(cast) is plans_bf16


def test_trace_time_plan_building():
    """build_plans reads only metadata, so it works on tracers inside jit —
    the train step builds the table at trace time."""
    cfg = DMDConfig(m=4)
    acc = DMDAccelerator(cfg, stack_dims={"w": 0, "b": 0, "seg0": {"wq": 1}})
    params = small_params()

    @jax.jit
    def probe(p):
        plans = acc.plans_for(p)
        assert plans["seg0"]["wq"].stack_dims == 1
        return jax.tree_util.tree_map(lambda x: x * 1.0, p)

    probe(params)


def test_apply_handles_tuple_leaf_params():
    """Regression (ISSUE 2): a params pytree containing a genuine 2-tuple
    node must round-trip through apply unharmed — the old (params, rank)
    tuple-sniffing silently mis-split it; LeafJump is isinstance-checked."""
    cfg = DMDConfig(m=4, s=5, tol=1e-4, warmup_steps=0, cooldown_steps=0)
    acc = DMDAccelerator(cfg)
    rng = np.random.default_rng(1)
    params = {"pair": (jnp.asarray(rng.normal(size=(6,)), jnp.float32),
                       jnp.asarray(rng.normal(size=(6,)), jnp.float32)),
              "w": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)}
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    for slot in range(cfg.m):
        params = jax.tree_util.tree_map(
            lambda p: p + 0.02 * jnp.asarray(rng.normal(size=p.shape),
                                             jnp.float32), params)
        bufs, grams = acc.record(bufs, params, slot, grams)
    new_params, info = acc.apply(
        jax.tree_util.tree_map(jnp.copy, params), bufs, 0, grams=grams)
    assert isinstance(new_params["pair"], tuple)
    assert len(new_params["pair"]) == 2
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert leaf.shape in ((6,), (4, 3))
        assert bool(jnp.all(jnp.isfinite(leaf)))
    assert float(info["mean_rank"]) >= 1


def test_dmd_step_handles_tuple_leaf_params():
    """Same regression for the jitted train-side jump."""
    from repro.train.state import TrainState
    from repro.train.step import make_dmd_step

    acfg = get_config("tinyllama-1.1b")
    acfg = dataclasses.replace(
        acfg, dmd=DMDConfig(m=4, s=5, tol=1e-4, warmup_steps=0,
                            cooldown_steps=0))
    acc = DMDAccelerator(acfg.dmd)
    rng = np.random.default_rng(2)
    params = {"pair": (jnp.asarray(rng.normal(size=(6,)), jnp.float32),
                       jnp.asarray(rng.normal(size=(6,)), jnp.float32))}
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    for slot in range(acfg.dmd.m):
        params = jax.tree_util.tree_map(
            lambda p: p + 0.05 * jnp.asarray(rng.normal(size=p.shape),
                                             jnp.float32), params)
        bufs, grams = acc.record(bufs, params, slot, grams)
    from repro.optim import make_optimizer
    opt_state = make_optimizer(acfg.optimizer).init(params)
    state = TrainState(params, opt_state, jnp.zeros((), jnp.int32), bufs,
                       grams)
    dmd_step = jax.jit(make_dmd_step(acfg, acc=acc))
    new_state, info = dmd_step(state, jnp.asarray(1.0))
    assert isinstance(new_state.params["pair"], tuple)
    for leaf in jax.tree_util.tree_leaves(new_state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))
