"""Donation audit (ISSUE 5 satellite): ``donate_argnums=(0,)`` must
actually donate the snapshot buffers and Grams — per-leaf and packed-arena
— in the fused train step and BOTH dmd_step variants. Verified against the
compiled HLO: every buffer/Gram leaf appears in the module's
``input_output_alias`` table, and no copy op of a buffer/Gram shape
survives (a silently-dropped donation shows up as exactly such a copy).

The plain (ungated) jump reads only the buffers — the param VALUES are
dead, XLA prunes those inputs, and only the pass-through leaves can alias;
the gated (controller) jump reads params for the loss gate, so there the
WHOLE TrainState must alias through (the rollback branch passes the
donated pre-jump params and moments straight through untouched).
"""
import dataclasses
import re

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import (DMDConfig, DMDControllerConfig,
                                OptimizerConfig, TrainConfig)
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer


def _setup(controller=None, arena=True):
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=True, m=4, s=10, tol=1e-4, warmup_steps=4,
                      cooldown_steps=2, arena=arena,
                      controller=controller or DMDControllerConfig()),
        optimizer=OptimizerConfig(name="adam", lr=3e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=4, seq_len=16))
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    return Trainer(model, acfg), synthetic_lm_batches(0, 4, 16, mc.vocab_size)


def _alias_count(hlo: str) -> int:
    line = next(l for l in hlo.splitlines() if "input_output_alias" in l)
    return len(re.findall(r"\{\d+\}: \(\d+", line))


def _shape_str(leaf) -> str:
    d = {"float32": "f32", "bfloat16": "bf16"}.get(str(leaf.dtype),
                                                   str(leaf.dtype))
    return d + "[" + ",".join(map(str, leaf.shape)) + "]"


def _dmd_shapes(state):
    out = set()
    for kp, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        k = jax.tree_util.keystr(kp)
        if leaf is not None and ("dmd_buffers" in k or "dmd_gram" in k):
            out.add(_shape_str(leaf))
    return out


def _buffer_copies(hlo: str, shapes) -> list:
    copies = re.findall(r"= (\S+?)\{[^}]*\} copy\(", hlo)
    copies += re.findall(r"= (\S+?) copy\(", hlo)
    return [c for c in copies if any(c.startswith(s) for s in shapes)]


@pytest.mark.parametrize("arena", [True, False])
def test_train_step_donates_everything(arena):
    trainer, batches = _setup(arena=arena)
    state = trainer.init_state()
    hlo = trainer.train_step.lower(
        state, next(batches), jnp.asarray(5, jnp.int32)).compile().as_text()
    n_leaves = len(jax.tree_util.tree_leaves(state))
    assert _alias_count(hlo) == n_leaves
    assert _buffer_copies(hlo, _dmd_shapes(state)) == []


@pytest.mark.parametrize("arena", [True, False])
def test_plain_dmd_step_donates_buffers_and_grams(arena):
    trainer, _ = _setup(arena=arena)
    state = trainer.init_state()
    relax = jnp.ones((trainer.acc.n_groups,), jnp.float32)
    hlo = trainer.dmd_step.lower(state, relax,
                                 groups=(0,)).compile().as_text()
    shapes = _dmd_shapes(state)
    n_dmd = sum(1 for kp, l in jax.tree_util.tree_flatten_with_path(state)[0]
                if l is not None
                and ("dmd_buffers" in jax.tree_util.keystr(kp)
                     or "dmd_gram" in jax.tree_util.keystr(kp)))
    # buffers+grams (and the step scalar) pass through -> must all alias
    assert _alias_count(hlo) >= n_dmd
    assert _buffer_copies(hlo, shapes) == []


@pytest.mark.parametrize("arena", [True, False])
def test_gated_dmd_step_donates_whole_state(arena):
    """The controller path: accept/scale/reject all thread the donated
    state — every TrainState leaf must alias input to output."""
    trainer, batches = _setup(
        controller=DMDControllerConfig(enabled=True, eval_rows=4),
        arena=arena)
    state = trainer.init_state()
    relax = jnp.ones((trainer.acc.n_groups,), jnp.float32)
    hlo = trainer.dmd_step.lower(state, relax, next(batches),
                                 groups=(0,)).compile().as_text()
    assert _alias_count(hlo) == len(jax.tree_util.tree_leaves(state))
    assert _buffer_copies(hlo, _dmd_shapes(state)) == []
