"""Donation audit: ``donate_argnums=(0,)`` must actually donate the
snapshot buffers and Grams — per-leaf and packed-arena — in the fused
train step and BOTH dmd_step variants.

Since ISSUE 6 the invariant itself lives in the shared static-audit layer
(repro.audit.passes::donation_alias — the same pass the
``python -m repro.audit`` CLI runs): every buffer/Gram leaf appears in
the compiled module's ``input_output_alias`` table, and no copy op of a
buffer/Gram shape survives. This file routes the Trainer's REAL jitted
programs through that pass; no standalone HLO-regex logic remains here.

The plain (ungated) jump reads only the buffers — the param VALUES are
dead, XLA prunes those inputs, and only the pass-through leaves can alias;
the gated (controller) jump reads params for the loss gate, so there the
WHOLE TrainState must alias through (the rollback branch passes the
donated pre-jump params and moments straight through untouched).
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.audit.passes import donation_alias
from repro.audit.targets import adhoc_context, trace_target
from repro.configs import get_config, reduced
from repro.configs.base import (DMDConfig, DMDControllerConfig,
                                OptimizerConfig, TrainConfig)
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer


def _setup(controller=None, arena=True):
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    acfg = dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=True, m=4, s=10, tol=1e-4, warmup_steps=4,
                      cooldown_steps=2, arena=arena,
                      controller=controller or DMDControllerConfig()),
        optimizer=OptimizerConfig(name="adam", lr=3e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=4, seq_len=16))
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    return Trainer(model, acfg), synthetic_lm_batches(0, 4, 16, mc.vocab_size)


def _audit(trainer, name, target):
    """Run the shared donation pass over one Trainer program."""
    ctx = adhoc_context("tinyllama-1.1b-reduced", trainer.acfg,
                        {name: target})
    violations, info = donation_alias(ctx)
    return [v for v in violations if v.severity == "error"], info


@pytest.mark.parametrize("arena", [True, False])
def test_train_step_donates_everything(arena):
    trainer, batches = _setup(arena=arena)
    state = trainer.init_state()
    t = trace_target("train_step", trainer.train_step,
                     (state, next(batches), jnp.asarray(5, jnp.int32)), {},
                     state)
    errors, info = _audit(trainer, "train_step", t)
    assert errors == [], errors
    # the pass pins exact whole-state aliasing for the fused step
    assert info["train_step.alias_count"] == len(
        jax.tree_util.tree_leaves(state))
    assert info["train_step.dmd_copies"] == 0


@pytest.mark.parametrize("arena", [True, False])
def test_plain_dmd_step_donates_buffers_and_grams(arena):
    trainer, _ = _setup(arena=arena)
    state = trainer.init_state()
    relax = jnp.ones((trainer.acc.n_groups,), jnp.float32)
    t = trace_target("dmd_step", trainer.dmd_step, (state, relax),
                     {"groups": (0,)}, state)
    # buffers+grams (and the step scalar) pass through -> must all alias
    errors, info = _audit(trainer, "dmd_step", t)
    assert errors == [], errors
    assert info["dmd_step.alias_count"] >= t.n_dmd_leaves
    assert info["dmd_step.dmd_copies"] == 0


@pytest.mark.parametrize("arena", [True, False])
def test_gated_dmd_step_donates_whole_state(arena):
    """The controller path: accept/scale/reject all thread the donated
    state — every TrainState leaf must alias input to output."""
    trainer, batches = _setup(
        controller=DMDControllerConfig(enabled=True, eval_rows=4),
        arena=arena)
    state = trainer.init_state()
    relax = jnp.ones((trainer.acc.n_groups,), jnp.float32)
    t = trace_target("dmd_step_gated", trainer.dmd_step,
                     (state, relax, next(batches)), {"groups": (0,)}, state)
    errors, info = _audit(trainer, "dmd_step_gated", t)
    assert errors == [], errors
    assert info["dmd_step_gated.alias_count"] == len(
        jax.tree_util.tree_leaves(state))
    assert info["dmd_step_gated.dmd_copies"] == 0


def test_dropped_donation_is_caught():
    """Mutation check riding the same build: compiling WITHOUT
    donate_argnums must flip the pass to failing (the audit lane bites —
    ISSUE 6 acceptance)."""
    from repro.train.step import audit_step_fns

    trainer, batches = _setup()
    state = trainer.init_state()
    _, fns = audit_step_fns(trainer.model, trainer.acfg, acc=trainer.acc,
                            donate=False)
    t = trace_target("train_step", fns["train_step"],
                     (state, next(batches), jnp.asarray(5, jnp.int32)), {},
                     state, donated=False)
    errors, _ = _audit(trainer, "train_step", t)
    assert errors, "donation pass failed to flag an undonated train step"
