"""Live weight hot-swap end to end (ISSUE 10 satellites): the trainer's
publish gate, the torn-write-safe WeightsChannel, SIGTERM fault injection
on the exact publish step (mirroring tests/test_checkpoint.py), and the
trainer -> channel -> engine integration serving bit-exact weights.

The swap protocol's atomicity claims, each pinned here:

  * a publisher killed mid-write never exposes a half-version — step
    dirs without a manifest and leftover ``.tmp_`` dirs are invisible to
    ``latest_version()`` and to a polling server;
  * SIGTERM delivered inside the publish hook on the exact jump step
    leaves the channel serving the last complete version, and the
    resumed trainer's NEXT publish succeeds with a higher version;
  * the trainer publishes exactly the non-REJECT jumps (``_publish``
    consults ``ctrl_outcome``), stamped ``step + 1``;
  * a server that adopted a published version serves tokens and logits
    identical to a server cold-started on ``channel.load()``.
"""
import os
import signal

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import list_checkpoints
from repro.models.transformer import LanguageModel
from repro.serve import ServeConfig, ServeEngine, WeightsChannel


def _leaves_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _toy_params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"emb": jax.random.normal(k, (8, 4)),
            "blk": {"w": jax.random.normal(k, (4, 4)),
                    "b": jnp.zeros(4)}}


def test_channel_roundtrip(tmp_path):
    ch = WeightsChannel(tmp_path)
    assert ch.latest_version() is None
    assert ch.load(_toy_params()) is None
    p10 = _toy_params(1)
    ch.publish(p10, 10)
    assert ch.latest_version() == 10
    _leaves_equal(ch.load(_toy_params()), p10)
    p16 = _toy_params(2)
    ch.publish(p16, 16)
    assert ch.latest_version() == 16
    _leaves_equal(ch.load(_toy_params()), p16)
    _leaves_equal(ch.load(_toy_params(), version=10), p10)  # keep=2


def test_torn_publish_is_invisible(tmp_path):
    """A publisher killed mid-write leaves either a ``.tmp_`` dir or a
    renamed dir without its manifest; both must be invisible to the
    channel, and the NEXT publish over them must succeed."""
    ch = WeightsChannel(tmp_path)
    p10 = _toy_params(1)
    ch.publish(p10, 10)

    # torn artifact 1: interrupted before the rename
    (tmp_path / ".tmp_dead").mkdir()
    (tmp_path / ".tmp_dead" / "arrays.npz").write_bytes(b"garbage")
    # torn artifact 2: step dir present but manifest never landed
    (tmp_path / "step_99").mkdir()

    assert ch.latest_version() == 10
    _leaves_equal(ch.load(_toy_params()), p10)

    p11 = _toy_params(2)
    ch.publish(p11, 11)
    assert ch.latest_version() == 11
    _leaves_equal(ch.load(_toy_params()), p11)


def test_publish_gate_follows_controller_outcome():
    """Trainer._publish forwards ACCEPT and SCALED jumps and swallows
    REJECT; with the controller off every jump publishes."""
    from test_trainer import _ctrl_cfg, _tiny_setup
    from repro.core import controller as C

    tr, batches = _tiny_setup(dmd=True, controller=_ctrl_cfg())
    state = tr.fit(batches, steps=2)
    got = []
    tr.on_publish = lambda params, version: got.append(version)

    for outcome, expect in ((C.REJECT, []), (C.SCALED, [5]),
                            (C.ACCEPT, [5, 5])):
        tr._publish(state, {"ctrl_outcome": jnp.asarray(outcome)}, 5)
        assert got == expect, (outcome, got)

    # controller off: ctrl_outcome is absent and everything publishes
    tr2, batches2 = _tiny_setup(dmd=True)
    state2 = tr2.fit(batches2, steps=2)
    got2 = []
    tr2.on_publish = lambda params, version: got2.append(version)
    tr2._publish(state2, {}, 7)
    assert got2 == [7]


@pytest.mark.slow
def test_trainer_publishes_on_jumps_and_leafwise():
    """Schedule (warmup 4, cooldown 2, m 4) jumps at 9, 15, 21: without a
    controller the trainer publishes versions 10, 16, 22, and the payload
    is plain per-leaf arrays (arena residency unwrapped) matching the
    final state's leafwise export bit-exactly on the last publish."""
    from test_trainer import _tiny_setup

    published = {}
    tr, batches = _tiny_setup(dmd=True)
    tr.on_publish = lambda params, version: published.update(
        {version: params})
    final = tr.fit(batches, steps=22)
    assert sorted(published) == [10, 16, 22]
    ref = tr.acc.params_leafwise(final.params)
    assert (jax.tree_util.tree_structure(published[22])
            == jax.tree_util.tree_structure(ref))
    _leaves_equal(published[22], ref)


@pytest.mark.slow
def test_sigterm_on_exact_publish_step(tmp_path):
    """SIGTERM inside the publish hook on the exact publish step (the
    jump at 9 publishes version 10). The channel must keep serving the
    last COMPLETE version (no torn dirs), the trainer checkpoints and
    exits per its preempt contract, and the resumed trainer's next
    publishes (16, 22) succeed — matching an uninterrupted run
    bit-exactly."""
    from test_trainer import _tiny_setup
    from repro.checkpoint import latest_step
    from repro.data.tokens import synthetic_lm_batches

    steps = 22
    try:
        # uninterrupted reference, recording every published payload
        ref = {}
        tr_a, batches_a = _tiny_setup(dmd=True)
        tr_a.on_publish = lambda p, v: ref.update({v: p})
        tr_a.fit(batches_a, steps=steps)
        assert sorted(ref) == [10, 16, 22]

        # preempted run: the bomb publishes v10 then dies "mid-swap" —
        # after the channel's atomic rename, before the trainer returns
        ckpt_dir = tmp_path / "ckpt"
        ch = WeightsChannel(tmp_path / "weights")

        def bomb(params, version):
            ch.publish(params, version)
            if version == 10:
                signal.raise_signal(signal.SIGTERM)
        tr_b, batches_b = _tiny_setup(ckpt_dir, dmd=True)
        tr_b.on_publish = bomb
        state_b = tr_b.fit(batches_b, steps=steps)
        assert int(state_b.step) == 10               # preempt save at step+1
        assert latest_step(ckpt_dir) == 10

        # no torn half-version on the bus
        assert ch.latest_version() == 10
        assert [p for p in os.listdir(ch.root)
                if p.startswith(".tmp_")] == []
        _leaves_equal(ch.load(ref[10]), ref[10])

        # resumed trainer: the NEXT publishes land with higher versions
        tr_c, _ = _tiny_setup(ckpt_dir, dmd=True)
        tr_c.on_publish = lambda p, v: ch.publish(p, v)
        vocab = tr_c.model.cfg.vocab_size
        batches_c = synthetic_lm_batches(0, 4, 16, vocab, start_step=10)
        tr_c.fit(batches_c, steps=steps)
    finally:
        signal.signal(signal.SIGTERM, signal.SIG_DFL)

    assert ch.latest_version() == 22
    assert list_checkpoints(ch.root) == [16, 22]     # keep=2 pruning
    for v in (16, 22):
        _leaves_equal(ch.load(ref[v], version=v), ref[v])


@pytest.mark.slow
def test_published_weights_serve_bitexact(tmp_path):
    """trainer -> channel -> engine: a server that polled the published
    version serves tokens AND final logits identical to a server
    cold-started on channel.load(), with version stamps to match."""
    from test_trainer import _tiny_setup

    ch = WeightsChannel(tmp_path)
    tr, batches = _tiny_setup(dmd=True)
    tr.on_publish = lambda p, v: ch.publish(p, v)
    tr.fit(batches, steps=10)                        # one jump -> v10
    assert ch.latest_version() == 10

    # serving build of the SAME arch (scan_layers=False per launch/serve)
    model = LanguageModel(tr.model.cfg, head_tp=False, chunk_k=16,
                          scan_layers=False)
    template = model.init(jax.random.PRNGKey(3))
    scfg = ServeConfig(n_slots=2, prompt_buckets=(4,), batch_buckets=(1,),
                       max_new_tokens=4)

    hot = ServeEngine(model, template, scfg)
    assert ch.poll(hot, template) == 10
    assert ch.poll(hot, template) is None            # idempotent
    assert hot.version == 10

    cold = ServeEngine(model, ch.load(template), scfg)
    for p in ([1, 2, 3], [4, 5]):
        hot.submit(p); cold.submit(p)
    rh = sorted(hot.run_until_drained(), key=lambda r: r.uid)
    rc = sorted(cold.run_until_drained(), key=lambda r: r.uid)
    for h, c in zip(rh, rc):
        assert h.tokens == c.tokens
        np.testing.assert_array_equal(h.last_logits, c.last_logits)
        assert (h.version_start, h.version_end) == (10, 10)
        assert (c.version_start, c.version_end) == (0, 0)
    assert hot.stats["dropped"] == cold.stats["dropped"] == 0
