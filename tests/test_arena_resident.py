"""Arena-native parameter residency (ISSUE 7, DESIGN.md §7).

Covers the residency route contracts end-to-end through the real Trainer:

  * three-route full-cycle equality — resident (arena_native=True) vs
    pack-copy (arena_native=False) vs per-leaf (arena=False) must agree
    on params, optimizer moments, snapshot buffers, Grams AND controller
    state after full jump cycles. The trajectory is kept exactly dyadic
    (integer batches, momentum with beta=lr=0.5) so every fp32 Gram sum
    is exact in ANY summation order and the comparison is
    assert_array_equal, not allclose — any view/offset/masking slip in
    the residency layout changes bits;
  * resident vs pack-copy on FLOAT trajectories with adam: the two
    routes execute the identical elementwise math and the identical
    segmented kernels on identical buffers, so they are bit-equal even
    where per-leaf is not (exercises the NamedTuple opt-state residency);
  * checkpoint interop in both directions: a checkpoint written mid-fit
    by a RESIDENT run (state_leafwise on the live resident state)
    restores into an arena=False run and vice versa — disk format is
    leaf-wise either way, so pre-residency checkpoints load unchanged;
  * the ISSUE 7 bugfix oracle: with RESIDENT moments, the post-jump
    group-masked optimizer reset must mask on bucket ranges, not leaves —
    pinned by a two-group staggered schedule where one group jumps while
    the other is mid-window;
  * tree_resident/tree_leafwise round-trip + pad-lane zeroing.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import (DMDConfig, DMDControllerConfig,
                                OptimizerConfig, TrainConfig)
from repro.core import DMDAccelerator
from repro.core import arena as arena_mod
from repro.core.schedule import DMDGroupRule
from repro.train import Trainer
from repro.train.step import RESIDENT_OPTIMIZERS, resident_enabled


SIZES = {"w": (16, 13), "b": (7,), "v": (130,), "stack": (3, 5, 6)}


class _DotModel:
    """loss = sum_leaf <params[k], batch[k]>: the gradient IS the batch
    tensor, independent of params — integer batches give integer grads,
    so momentum(beta=0.5, lr=0.5) keeps every snapshot exactly dyadic
    and all fp32 Gram sums exact in any summation order."""

    def init(self, key):
        rng = np.random.default_rng(0)
        return {k: jnp.asarray(rng.integers(-4, 5, size=s), jnp.float32)
                for k, s in SIZES.items()}

    def loss(self, params, batch):
        loss = sum(jnp.vdot(params[k], batch[k]) for k in SIZES)
        return loss, None

    def param_stack_dims(self):
        return {"w": 0, "b": 0, "v": 0, "stack": 1}


def _int_batches(n, seed=1):
    rng = np.random.default_rng(seed)
    return [{k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
             for k, s in SIZES.items()} for _ in range(n)]


def _float_batches(n, seed=2):
    rng = np.random.default_rng(seed)
    return [{k: jnp.asarray(rng.normal(size=s), jnp.float32)
             for k, s in SIZES.items()} for _ in range(n)]


def _acfg(optimizer, *, native=True, arena=True, controller=False,
          groups=(), ckpt="", ckpt_every=0):
    acfg = get_config("pollutant-mlp")
    return dataclasses.replace(
        acfg,
        dmd=DMDConfig(m=4, s=8, tol=1e-6, warmup_steps=2, cooldown_steps=0,
                      arena=arena, arena_native=native, groups=groups,
                      controller=DMDControllerConfig(enabled=controller,
                                                     eval_rows=0)),
        optimizer=optimizer,
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=8, seq_len=1, checkpoint_dir=ckpt,
                          checkpoint_every=ckpt_every))


def _fit(acfg, batches, steps, eval_batch=None, state=None):
    trainer = Trainer(_DotModel(), acfg)
    state = trainer.fit(iter(batches), steps=steps, state=state,
                        eval_batch=eval_batch)
    return trainer, state


def _assert_trees_equal(a, b, msg):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), (msg, len(la), len(lb))
    for i, (x, y) in enumerate(zip(la, lb)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=f"{msg}[{i}]")


def test_three_route_full_cycle_bitexact():
    """Resident vs pack-copy vs per-leaf through Trainer.fit with the
    loss-gated controller on. Through the FIRST complete gated cycle
    every snapshot is exactly dyadic, so params, momentum moments,
    buffers, Grams and controller counters must be bit-equal on all
    three routes. The continuation through a SECOND cycle (whose
    snapshots carry the jump's full-mantissa output) stays bit-equal
    between resident and pack-copy — identical ops in identical order —
    while per-leaf is pinned at the fp32 summation-order noise floor
    (the same bound the PR-5 float-trajectory oracle documents)."""
    batches = _int_batches(16)
    eval_batch = _int_batches(1, seed=9)[0]
    opt = OptimizerConfig(name="momentum", lr=0.5, b1=0.5, grad_clip=0.0)
    routes = {
        "resident": _acfg(opt, native=True, controller=True),
        "packed": _acfg(opt, native=False, controller=True),
        "per_leaf": _acfg(opt, arena=False, controller=True),
    }
    runs = {}
    for name, acfg in routes.items():
        trainer, state = _fit(acfg, batches, 6, eval_batch=eval_batch)
        if name == "resident":
            assert resident_enabled(trainer.acc, acfg)
        # fit returns the unresident layout; unpack arenas for comparison
        assert not arena_mod.is_arena_state(state.params)
        runs[name] = (trainer, state)

    ref_tr, ref_st = runs["resident"]
    ref = ref_tr.acc.state_leafwise(ref_st)
    # the first gated jump fired (otherwise the test pins nothing)
    assert int(np.asarray(ref.controller.accepts).sum()
               + np.asarray(ref.controller.scaled).sum()
               + np.asarray(ref.controller.rejects).sum()) > 0
    for other in ("packed", "per_leaf"):
        tr, raw = runs[other]
        st = tr.acc.state_leafwise(raw)
        _assert_trees_equal(ref.params, st.params, f"params:{other}")
        _assert_trees_equal(ref.opt_state, st.opt_state, f"moments:{other}")
        _assert_trees_equal(ref.dmd_buffers, st.dmd_buffers,
                            f"buffers:{other}")
        _assert_trees_equal(ref.dmd_gram, st.dmd_gram, f"grams:{other}")
        _assert_trees_equal(ref.controller, st.controller, f"ctrl:{other}")

    # second cycle: resume each run to step 12 (second gated jump at 11)
    finals = {}
    for name in routes:
        trainer, state = runs[name]
        state = trainer.fit(iter(batches[6:]), steps=12, state=state,
                            eval_batch=eval_batch)
        finals[name] = trainer.acc.state_leafwise(state)
    ref = finals["resident"]
    _assert_trees_equal(ref.params, finals["packed"].params,
                        "params:packed-cycle2")
    _assert_trees_equal(ref.opt_state, finals["packed"].opt_state,
                        "moments:packed-cycle2")
    _assert_trees_equal(ref.dmd_gram, finals["packed"].dmd_gram,
                        "grams:packed-cycle2")
    _assert_trees_equal(ref.controller, finals["packed"].controller,
                        "ctrl:packed-cycle2")
    for x, y in zip(jax.tree_util.tree_leaves(ref.params),
                    jax.tree_util.tree_leaves(finals["per_leaf"].params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=1e-5, atol=1e-4)


def test_resident_vs_packed_adam_float_bitexact():
    """On arbitrary float trajectories the resident and pack-copy routes
    run the same elementwise ops and the same segmented kernels on the
    same buffer bits, so adam params/moments/buffers/Grams are bit-equal
    (per-leaf is excluded here: its Gram summation order differs)."""
    batches = _float_batches(14)
    opt = OptimizerConfig(name="adam", lr=1e-2, grad_clip=0.0)
    tr_r, st_r = _fit(_acfg(opt, native=True), batches, 10)
    tr_p, st_p = _fit(_acfg(opt, native=False), batches, 10)
    lr, lp = tr_r.acc.state_leafwise(st_r), tr_p.acc.state_leafwise(st_p)
    _assert_trees_equal(lr.params, lp.params, "params")
    _assert_trees_equal(lr.opt_state, lp.opt_state, "adam-moments")
    _assert_trees_equal(lr.dmd_buffers, lp.dmd_buffers, "buffers")
    _assert_trees_equal(lr.dmd_gram, lp.dmd_gram, "grams")


def test_checkpoint_interop_resident_both_directions(tmp_path):
    """A checkpoint written MID-FIT by a resident run (the live state is
    in the wrapper layout when Trainer.save fires) restores into an
    arena=False run, and a per-leaf checkpoint restores into a resident
    run — both continuations land bit-equal with the uninterrupted
    reference run of their target route."""
    batches = _int_batches(20)
    opt = OptimizerConfig(name="momentum", lr=0.5, b1=0.5, grad_clip=0.0)

    # uninterrupted references, one per target route: a continuation is
    # compared against ITS OWN route's straight-through run (across
    # routes the post-jump Gram summation orders differ at fp32 ulp —
    # the three-route test above pins that boundary)
    tr_ol, st_ol = _fit(_acfg(opt, arena=False), batches, 16)
    oracle_leaf = tr_ol.acc.state_leafwise(st_ol)
    tr_or, st_or = _fit(_acfg(opt, native=True), batches, 16)
    oracle_res = tr_or.acc.state_leafwise(st_or)

    # resident run saves at step 5 mid-fit (the live state is resident
    # when Trainer.save fires) -> per-leaf run resumes
    dir_a = str(tmp_path / "resident_writes")
    _fit(_acfg(opt, native=True, ckpt=dir_a, ckpt_every=5), batches, 8)
    tr_b = Trainer(_DotModel(), _acfg(opt, arena=False, ckpt=dir_a))
    st_b = tr_b.restore()
    assert st_b is not None and int(st_b.step) == 5
    st_b = tr_b.fit(iter(batches[5:]), steps=16, state=st_b)
    _assert_trees_equal(oracle_leaf.params, st_b.params, "res->leaf params")
    _assert_trees_equal(oracle_leaf.opt_state, st_b.opt_state,
                        "res->leaf mom")
    _assert_trees_equal(oracle_leaf.dmd_buffers, st_b.dmd_buffers,
                        "res->leaf bufs")
    _assert_trees_equal(oracle_leaf.dmd_gram, st_b.dmd_gram,
                        "res->leaf grams")

    # per-leaf run saves -> resident run resumes (pre-residency format)
    dir_c = str(tmp_path / "leaf_writes")
    _fit(_acfg(opt, arena=False, ckpt=dir_c, ckpt_every=5), batches, 8)
    acfg_d = _acfg(opt, native=True, ckpt=dir_c)
    tr_d = Trainer(_DotModel(), acfg_d)
    st_d = tr_d.restore()
    assert st_d is not None and int(st_d.step) == 5
    assert arena_mod.is_arena_state(st_d.dmd_buffers)   # re-arenaized
    st_d = tr_d.fit(iter(batches[5:]), steps=16, state=st_d)
    ld = tr_d.acc.state_leafwise(st_d)
    _assert_trees_equal(oracle_res.params, ld.params, "leaf->res params")
    _assert_trees_equal(oracle_res.opt_state, ld.opt_state,
                        "leaf->res mom")
    _assert_trees_equal(oracle_res.dmd_buffers, ld.dmd_buffers,
                        "leaf->res bufs")
    _assert_trees_equal(oracle_res.dmd_gram, ld.dmd_gram,
                        "leaf->res grams")


def test_staggered_moment_reset_masks_bucket_ranges():
    """ISSUE 7 bugfix oracle: two groups on staggered phases, adam. When
    the default group jumps at step 5 the vector group (phase 2) is
    mid-window: the masked post-jump reset must zero ONLY the jumped
    group's moments. With resident moments the mask unit is the bucket
    range — a leaf-granularity slip either clobbers the other group's
    segments or misses its own; bit-compared against the pack-copy
    route's leaf-masked reset."""
    groups = (DMDGroupRule(name="vecs", path_regex="/b|/v", phase=2),)
    batches = _float_batches(8)
    opt = OptimizerConfig(name="adam", lr=1e-2, grad_clip=0.0)
    # steps 0..5: default group (w, stack) jumps at 5; vecs mid-window
    tr_r, st_r = _fit(_acfg(opt, native=True, groups=groups), batches, 6)
    tr_p, st_p = _fit(_acfg(opt, native=False, groups=groups), batches, 6)
    assert len(tr_r.acc.groups) == 2
    assert tr_r.acc.apply_groups(5) and 1 not in tr_r.acc.apply_groups(5)

    mu_r = st_r.opt_state.m
    mu_p = st_p.opt_state.m
    _assert_trees_equal(mu_r, mu_p, "mu")
    _assert_trees_equal(st_r.opt_state.v, st_p.opt_state.v, "nu")
    _assert_trees_equal(st_r.params, st_p.params, "params")
    # jumped group's moments are freshly reset, the staggered group's are
    # mid-accumulation — the mask really is group-scoped
    for k in ("w", "stack"):
        assert float(jnp.abs(mu_r[k]).max()) == 0.0, k
    for k in ("b", "v"):
        assert float(jnp.abs(mu_r[k]).max()) > 0.0, k


def test_tree_resident_leafwise_roundtrip():
    """Pack/unpack round-trips bit-exactly, pad lanes are zero, and the
    wrapper marks every packed path None in the leaf subtree."""
    rng = np.random.default_rng(3)
    params = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
              for k, s in SIZES.items()}
    acc = DMDAccelerator(DMDConfig(m=4, s=8, warmup_steps=0,
                                   cooldown_steps=0),
                         stack_dims=_DotModel().param_stack_dims())
    table = acc.arena_for(params)
    assert table
    res = arena_mod.tree_resident(table, params)
    assert arena_mod.is_arena_state(res)
    arenas, leaf = arena_mod.split_state(res)
    assert all(x is None for x in jax.tree_util.tree_leaves(
        leaf, is_leaf=lambda x: x is None))
    for key, buf in arenas.items():
        b = table[key]
        assert buf.shape == (b.n_lanes,)
        mask = np.ones(b.n_lanes, bool)
        for seg in b.segments:
            flat = np.asarray(buf[seg.lane_start:
                                  seg.lane_start + seg.lanes])
            for s in range(seg.n_sys):
                lo = s * seg.seg_lanes
                mask[seg.lane_start + lo:
                     seg.lane_start + lo + seg.flat_local] = False
        assert np.all(np.asarray(buf)[mask] == 0.0)     # pad lanes zero
    back = arena_mod.tree_leafwise(table, res)
    for k in SIZES:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(params[k]), err_msg=k)


def test_resident_optimizer_gate():
    """Non-elementwise optimizers must NOT residentize (adafactor reads
    trailing-dim structure a flat buffer destroys)."""
    assert "adafactor" not in RESIDENT_OPTIMIZERS
    opt = OptimizerConfig(name="adafactor", lr=1e-2)
    acfg = _acfg(opt, native=True)
    trainer = Trainer(_DotModel(), acfg)
    assert not resident_enabled(trainer.acc, acfg)
    state = trainer.fit(iter(_float_batches(4)), steps=3)
    assert not arena_mod.is_arena_state(state.params)
    assert arena_mod.is_arena_state(state.dmd_buffers)  # arenas still on
