"""Data pipelines: pollutant PDE physics sanity + token determinism."""
import numpy as np
import jax.numpy as jnp

from repro.data import pollutant as pol
from repro.data.tokens import batch_for_step


def test_blasius_flat_plate():
    """No-slip flat plate: f'(inf)=1 and f''(0) ~ 0.4696 (textbook value)."""
    eta, f, fp = pol.solve_blasius(1.0, 0.0, 0.0)
    assert abs(fp[-1] - 1.0) < 1e-3
    fpp0 = (fp[1] - fp[0]) / (eta[1] - eta[0])
    # first-order estimate of the curvature at the wall is biased low;
    # integrate the profile instead: f(10) ~ eta - 1.72 for Blasius
    assert abs((eta[-1] - f[-1]) - 1.7208) < 0.02
    assert 0.3 < fpp0 < 0.6


def test_velocity_field_monotone_profile():
    X, Y = pol.make_grid(32, 16)
    ux, uy = pol.velocity_field(1.0, 0.0, 0.0, X, Y)
    assert np.isfinite(ux).all() and np.isfinite(uy).all()
    col = ux[16, :]
    assert col[0] <= col[-1] + 1e-6            # speeds up away from ground
    assert abs(col[-1] - 1.0) < 0.05           # freestream


def test_steady_transport_residual_small():
    X, Y = pol.make_grid(48, 24)
    q1, q2 = pol.source_fields(X, Y)
    ux, uy = pol.velocity_field(1.0, 0.1, 0.05, X, Y)
    dx, dy = 2.0 / 47, 1.0 / 23
    c1, c2, c3 = pol.steady_transport(jnp.asarray(ux), jnp.asarray(uy),
                                      0.1, 5.0, 1.0,
                                      jnp.asarray(q1), jnp.asarray(q2),
                                      dx, dy, n_iter=20000)
    c1, c2, c3 = map(np.asarray, (c1, c2, c3))
    assert np.isfinite(c3).all()
    assert c3.min() >= 0.0
    assert c3.max() > 1e-5          # pollutant actually produced
    # pollutant needs BOTH reactants: should peak downstream of sources
    peak = np.unravel_index(np.argmax(c3), c3.shape)
    assert peak[0] >= 1


def test_reaction_consumes_reactants():
    """Higher K12 -> more pollutant produced near the source overlap."""
    X, Y = pol.make_grid(48, 24)
    q1, q2 = pol.source_fields(X, Y)
    ux, uy = pol.velocity_field(0.5, 0.0, 0.0, X, Y)
    dx, dy = 2.0 / 47, 1.0 / 23

    def total_c3(k12):
        _, _, c3 = pol.steady_transport(jnp.asarray(ux), jnp.asarray(uy),
                                        0.1, k12, 0.5, jnp.asarray(q1),
                                        jnp.asarray(q2), dx, dy,
                                        n_iter=15000)
        return float(np.asarray(c3).sum())
    assert total_c3(10.0) > total_c3(1.0)


def test_lhs_stratified():
    u = pol.latin_hypercube(16, 3, seed=0)
    assert u.shape == (16, 3)
    for j in range(3):
        bins = np.floor(u[:, j] * 16).astype(int)
        assert sorted(bins.tolist()) == list(range(16))   # one per stratum


def test_dataset_small_end_to_end():
    data = pol.generate_dataset(n_samples=3, nx=32, ny=16, n_points=50,
                                n_iter=5000, seed=0, batch=3)
    assert data["X"].shape == (3, 6)
    assert data["Y"].shape == (3, 50)
    assert np.isfinite(data["Y"]).all()
    assert np.abs(data["X"]).max() <= 1.0 + 1e-6
    (xtr, ytr), (xte, yte) = pol.train_test_split(data, 0.67)
    assert xtr.shape[0] == 2 and xte.shape[0] == 1


def test_tokens_deterministic_and_distinct():
    b1 = batch_for_step(0, 5, 4, 16, 100)
    b2 = batch_for_step(0, 5, 4, 16, 100)
    b3 = batch_for_step(0, 6, 4, 16, 100)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))
