"""Per-leaf scheduling (core/schedule.py): group resolution, schedule
invariants over (warmup, cooldown, m, phase), legacy param_filter mapping,
trace/host agreement, and bit-exactness with the pre-refactor closed form."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, st

from repro.configs.base import DMDConfig
from repro.core import DMDAccelerator, leafplan
from repro.core.schedule import (DMDGroupRule, GroupSchedule, group_for_leaf,
                                 resolve_groups, rules_for_config,
                                 slots_array, slots_for_step)


def _sched(m=4, s=8, warmup=0, cooldown=0, phase=0, relax=1.0, anneal=1.0,
           index=0, name="g"):
    return GroupSchedule(index=index, name=name, m=m, s=s,
                         warmup_steps=warmup, cooldown_steps=cooldown,
                         phase=phase, relax=relax, anneal=anneal)


# ---------------------------------------------------------------------------
# schedule invariants
# ---------------------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(warmup=st.integers(0, 17), cooldown=st.integers(0, 5),
       m=st.integers(3, 12), phase=st.integers(0, 19))
def test_slot_should_apply_round_invariants(warmup, cooldown, m, phase):
    g = _sched(m=m, warmup=warmup, cooldown=cooldown, phase=phase)
    cycle = m + cooldown
    start = warmup + phase
    applies, slots_seen = [], []
    for step in range(start + 3 * cycle + 2):
        s = g.slot(step)
        if step < start:
            assert s == -1                       # not started
        else:
            assert -cooldown <= s <= m - 1       # cooldown or window row
        assert g.should_record(step) == (s >= 0)
        assert g.should_apply(step) == (s == m - 1)
        if g.should_apply(step):
            applies.append(step)
        if s >= 0:
            slots_seen.append((step, s))
    # first jump closes the first full window; spacing is exactly the cycle
    assert applies[0] == start + cooldown + m - 1
    assert all(b - a == cycle for a, b in zip(applies, applies[1:]))
    # recorded slots run 0..m-1 consecutively within each window
    for (t0, s0), (t1, s1) in zip(slots_seen, slots_seen[1:]):
        if s1 != 0:
            assert (s1 - s0, t1 - t0) == (1, 1)
    # round_index is constant within a cycle and increments across it,
    # and equals the number of completed jumps at each jump step
    for i, t in enumerate(applies):
        assert g.round_index(t) == i
        assert g.round_index(t + cycle) == i + 1


@settings(max_examples=10, deadline=None)
@given(m=st.integers(3, 10), anneal=st.floats(0.5, 1.0))
def test_relax_anneal_per_round(m, anneal):
    g = _sched(m=m, relax=0.8, anneal=anneal)
    for r in range(4):
        assert g.relax_for_round(r) == pytest.approx(0.8 * anneal ** r)
    assert g.relax_for_round(-2) == pytest.approx(0.8)   # pre-start clamps


def test_traced_slots_match_host():
    groups = (_sched(m=5, warmup=3, cooldown=2, phase=0),
              _sched(m=3, warmup=3, cooldown=0, phase=4, index=1, name="h"))
    f = jax.jit(lambda t: slots_for_step(groups, t))
    for step in range(40):
        np.testing.assert_array_equal(np.asarray(f(jnp.int32(step))),
                                      slots_array(groups, step))


def test_default_group_bit_exact_with_legacy_formula():
    """The pre-refactor scalar schedule, reimplemented verbatim: a config
    with no group rules must reproduce it exactly (oracle for the
    'default single-group configs bit-exact' acceptance)."""
    def legacy_slot(cfg, step):
        eff = step - cfg.warmup_steps
        if eff < 0:
            return -1
        return (eff % (cfg.cooldown_steps + cfg.m)) - cfg.cooldown_steps

    for cfg in (DMDConfig(), DMDConfig(m=6, s=10, warmup_steps=7,
                                       cooldown_steps=3, relax=0.7,
                                       anneal=0.9)):
        acc = DMDAccelerator(cfg)
        assert acc.n_groups == 1
        for step in range(250):
            s = legacy_slot(cfg, step)
            assert acc.slot(step) == s
            assert acc.slots(step).tolist() == [s]
            assert acc.should_record(step) == (s >= 0)
            assert acc.should_apply(step) == (s == cfg.m - 1)
            assert acc.round_index(step) == \
                (step - cfg.warmup_steps) // (cfg.cooldown_steps + cfg.m)
            r = acc.round_index(step)
            assert acc.relax_for_round(r) == pytest.approx(
                cfg.relax * cfg.anneal ** max(r, 0))


def test_issue_example_two_groups_never_jump_together():
    """The acceptance-criteria config — matrices m=14 phase 0, norms/biases
    m=6 phase 7 (cooldown 0): matrix jumps land on odd effective steps,
    bias jumps on even ones, so the staggered schedule never pays two jump
    spikes in one step."""
    cfg = DMDConfig(m=14, s=55, warmup_steps=100, cooldown_steps=0,
                    groups=(DMDGroupRule(name="small", max_ndim=1, m=6,
                                         phase=7),))
    acc = DMDAccelerator(cfg)
    n_jumps = [0, 0]
    for step in range(20000):
        gs = acc.apply_groups(step)
        assert len(gs) <= 1, (step, gs)
        for g in gs:
            n_jumps[g] += 1
    assert n_jumps[0] > 0 and n_jumps[1] > 0


def test_group_validation_errors():
    with pytest.raises(ValueError, match="m >= 3"):
        resolve_groups(DMDConfig(m=2))
    with pytest.raises(ValueError, match="phase"):
        resolve_groups(DMDConfig(groups=(DMDGroupRule(phase=-1),)))
    with pytest.raises(ValueError, match="m >= 3"):
        resolve_groups(DMDConfig(groups=(DMDGroupRule(m=1),)))


# ---------------------------------------------------------------------------
# rule resolution + legacy mapping
# ---------------------------------------------------------------------------

def test_param_filter_strings_map_to_rules():
    """Satellite pin: the three legacy param_filter values become exclusion
    rules (no string dispatch below the config layer)."""
    assert rules_for_config(DMDConfig(param_filter="all")) == ()
    assert rules_for_config(DMDConfig(param_filter="non_expert")) == (
        DMDGroupRule(name="legacy_non_expert", path_regex="expert",
                     exclude=True),)
    assert rules_for_config(DMDConfig(param_filter="matrices_only")) == (
        DMDGroupRule(name="legacy_matrices_only", max_ndim=1, exclude=True),)
    assert rules_for_config(DMDConfig(min_param_size=10)) == (
        DMDGroupRule(name="legacy_min_param_size", max_size=9, exclude=True),)
    with pytest.raises(ValueError, match="param_filter"):
        rules_for_config(DMDConfig(param_filter="nope"))
    # legacy exclusions resolve BEFORE user group rules
    cfg = DMDConfig(param_filter="non_expert",
                    groups=(DMDGroupRule(name="experts", path_regex="expert",
                                         m=6),))
    assert group_for_leaf(cfg, "/moe/experts_in", 3, 4096) is None


def test_legacy_filters_equal_explicit_rules():
    params = {"experts_in": jnp.zeros((4, 8, 8)), "wq": jnp.zeros((8, 8)),
              "scale": jnp.zeros((8,)), "tiny": jnp.zeros((3,))}

    def selected(cfg):
        plans = leafplan.build_plans(params, cfg)
        return {k for k, v in plans.items() if v is not None}

    assert selected(DMDConfig(param_filter="non_expert")) == \
        selected(DMDConfig(groups=(DMDGroupRule(path_regex="expert",
                                                exclude=True),)))
    assert selected(DMDConfig(param_filter="matrices_only")) == \
        selected(DMDConfig(groups=(DMDGroupRule(max_ndim=1, exclude=True),)))
    assert selected(DMDConfig(min_param_size=4)) == \
        {"experts_in", "wq", "scale"}


def test_first_matching_rule_wins_and_default_falls_through():
    cfg = DMDConfig(m=10, s=20, groups=(
        DMDGroupRule(name="a", path_regex="/attn/", m=4),
        DMDGroupRule(name="b", min_ndim=2, m=6, phase=2),
        DMDGroupRule(name="drop", path_regex="skip_me", exclude=True),
    ))
    groups = resolve_groups(cfg)
    assert [g.name for g in groups] == ["default", "a", "b"]
    assert [g.m for g in groups] == [10, 4, 6]
    assert groups[2].s == 20                     # inherits the global s
    # /attn/ matches rule a even though rule b also matches
    assert group_for_leaf(cfg, "/seg0/attn/wq", 3, 999) == 1
    assert group_for_leaf(cfg, "/seg0/mlp/w_in", 3, 999) == 2
    assert group_for_leaf(cfg, "/seg0/skip_me", 1, 999) is None
    assert group_for_leaf(cfg, "/final_norm/scale", 1, 999) == 0
    assert group_for_leaf(cfg, "/zero", 1, 0) is None    # empty leaf


def test_plans_carry_group_and_heterogeneous_buffers():
    cfg = DMDConfig(m=8, s=16, groups=(
        DMDGroupRule(name="small", max_ndim=1, m=4, phase=3),))
    params = {"w": jnp.zeros((16, 8)), "b": jnp.zeros((8,))}
    acc = DMDAccelerator(cfg)
    plans = acc.plans_for(params)
    assert (plans["w"].group, plans["w"].m) == (0, 8)
    assert (plans["b"].group, plans["b"].m) == (1, 4)
    assert plans["b"].sched.phase == 3
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    # default route packs each group into its own arena bucket (m differs
    # per group -> different bucket); the leaf-wise view keeps the
    # heterogeneous per-leaf shapes
    from repro.core import arena as arena_mod
    from repro.train.state import TrainState
    assert arena_mod.is_arena_state(bufs)
    assert sorted(b.m for b in acc.arena_for(params).values()) == [4, 8]
    lw = acc.state_leafwise(TrainState(params, None,
                                       jnp.zeros((), jnp.int32), bufs,
                                       grams))
    assert lw.dmd_buffers["w"].shape == (8, 16, 8)
    assert lw.dmd_buffers["b"].shape == (4, 8)
    assert lw.dmd_gram["w"].shape == (8, 8)
    assert lw.dmd_gram["b"].shape == (4, 4)
    # plan_table shows the schedule columns
    table = acc.plan_table()
    assert "group" in table and "phase" in table
    assert "small" in table and "default" in table


def test_multi_group_record_requires_slot_vector():
    cfg = DMDConfig(m=6, groups=(DMDGroupRule(max_ndim=1, m=4),))
    acc = DMDAccelerator(cfg)
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))}
    bufs = acc.init(params)
    with pytest.raises(ValueError, match="slot"):
        acc.record(bufs, params, 0)
    bufs, _ = acc.record(bufs, params, acc.slots(cfg.warmup_steps))


def test_staggered_streaming_grams_match_oracle_at_window_close():
    """End-to-end through the accelerator: two groups with different m and
    phases; at every group's window-complete step its streaming Gram equals
    the gram_matrix oracle over ITS buffer."""
    from repro.core import dmd as dmd_mod
    rng = np.random.default_rng(0)
    cfg = DMDConfig(m=5, s=9, tol=1e-4, warmup_steps=2, cooldown_steps=0,
                    groups=(DMDGroupRule(name="vec", max_ndim=1, m=4,
                                         phase=2),))
    acc = DMDAccelerator(cfg)
    params = {"w": jnp.asarray(rng.normal(size=(6, 5)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(7,)), jnp.float32)}
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    checked = 0
    for t in range(40):
        params = jax.tree_util.tree_map(
            lambda p: p + 0.05 * jnp.asarray(rng.normal(size=p.shape),
                                             jnp.float32), params)
        if acc.should_record(t):
            bufs, grams = acc.record(bufs, params, acc.slots(t), grams)
        closing = acc.apply_groups(t)
        if closing:
            # audit through the leaf-wise view (the run carries arenas)
            from repro.train.state import TrainState
            lw = acc.state_leafwise(TrainState(
                params, None, jnp.zeros((), jnp.int32), bufs, grams))
        for g in closing:
            key = "w" if g == 0 else "b"
            oracle = dmd_mod.gram_matrix(lw.dmd_buffers[key],
                                         anchor=cfg.anchor)
            np.testing.assert_allclose(np.asarray(lw.dmd_gram[key]),
                                       np.asarray(oracle), rtol=1e-5,
                                       atol=1e-5)
            checked += 1
    assert checked >= 4
