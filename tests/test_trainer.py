"""Trainer: loss decreases, DMD schedule fires, failure-inject + resume is
bit-exact, preemption-style checkpointing."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer


def _tiny_setup(tmpdir=None, dmd=False, fail_at=None, ckpt_every=0,
                groups=(), controller=None, arena=True):
    from repro.configs.base import DMDControllerConfig
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    acfg = dataclasses.replace(
        acfg,
        model=mc,
        dmd=DMDConfig(enabled=dmd, m=4, s=10, tol=1e-4, warmup_steps=4,
                      cooldown_steps=2, groups=groups, arena=arena,
                      controller=controller or DMDControllerConfig()),
        optimizer=OptimizerConfig(name="adam", lr=3e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=4, seq_len=16,
                          checkpoint_every=ckpt_every,
                          checkpoint_dir=str(tmpdir) if tmpdir else ""))
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    trainer = Trainer(model, acfg, checkpoint_dir=str(tmpdir) if tmpdir
                      else None, fail_at_step=fail_at)
    batches = synthetic_lm_batches(0, 4, 16, mc.vocab_size)
    return trainer, batches


def test_loss_decreases():
    trainer, batches = _tiny_setup()
    losses = []
    trainer.fit(batches, steps=30,
                on_metrics=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]


def test_dmd_schedule_fires():
    trainer, batches = _tiny_setup(dmd=True)
    ranks = []

    def on_m(s, m):
        if "mean_rank" in m:
            ranks.append(float(m["mean_rank"]))
    trainer.fit(batches, steps=22, on_metrics=on_m)
    # warmup 4, then cycles of (cooldown 2 + m 4): jumps at steps 9, 15, 21
    assert len(ranks) == 3
    assert all(r >= 1 for r in ranks)


def test_failure_injection_and_bitexact_resume(tmp_path):
    # uninterrupted reference run
    trainer_a, batches_a = _tiny_setup()
    final_a = trainer_a.fit(batches_a, steps=12)

    # interrupted at step 8 with checkpointing every 4
    trainer_b, batches_b = _tiny_setup(tmp_path, fail_at=8, ckpt_every=4)
    with pytest.raises(RuntimeError, match="injected failure"):
        trainer_b.fit(batches_b, steps=12)

    # resume: new trainer, data stream replayed from the checkpointed step
    trainer_c, _ = _tiny_setup(tmp_path)
    from repro.checkpoint import latest_step
    start = latest_step(tmp_path)
    assert start == 8
    batches_c = synthetic_lm_batches(0, 4, 16,
                                     trainer_c.model.cfg.vocab_size,
                                     start_step=start)
    final_c = trainer_c.fit(batches_c, steps=12)

    for a, c in zip(jax.tree_util.tree_leaves(final_a.params),
                    jax.tree_util.tree_leaves(final_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_dmd_trainer_end_to_end_finite(tmp_path):
    trainer, batches = _tiny_setup(tmp_path, dmd=True, ckpt_every=6)
    state = trainer.fit(batches, steps=14)
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def _two_groups():
    """Two schedule groups with different windows AND phases: norm scales
    (incl. the scan-stacked ln1/ln2) on m=3/phase=1/no-cooldown windows,
    the rest on the default m=4 + cooldown 2."""
    from repro.core.schedule import DMDGroupRule
    return (DMDGroupRule(name="norms", path_regex="norm|/ln", m=3, phase=1,
                         cooldown_steps=0),)


def test_two_group_trainer_staggers_jumps():
    """The fused step + masked dmd_step drive a two-group schedule end to
    end: both groups jump, never in lock-step with identical cadence, and
    the params stay finite."""
    trainer, batches = _tiny_setup(dmd=True, groups=_two_groups())
    acc = trainer.acc
    assert acc.n_groups == 2
    # plan-table sanity: both groups own leaves, heterogeneous buffers
    state = trainer.init_state()
    plans = acc.plans_for(state.params)
    from repro.core.leafplan import plan_entries
    ms = {pl.m for pl in plan_entries(plans)}
    assert ms == {3, 4}
    jumped = {0: 0, 1: 0}
    state = trainer.fit(batches, steps=26, state=state)
    for step in range(26):
        for g in acc.apply_groups(step):
            jumped[g] += 1
    assert jumped[0] > 0 and jumped[1] > 0
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_mixed_m_mid_window_resume_bitexact(tmp_path):
    """Mid-window checkpoint resume with HETEROGENEOUS windows: a run
    interrupted while both groups sit at different points of different-m
    windows must resume bit-exactly (slots are re-derived from the restored
    step index; buffers/grams restore at per-group shapes)."""
    groups = _two_groups()
    trainer_a, batches_a = _tiny_setup(dmd=True, groups=groups)
    final_a = trainer_a.fit(batches_a, steps=18)

    # checkpoint at step 7: default group (warmup 4, cooldown 2) is at
    # slot 0 of its window; norms group (m=3, phase 1) mid-window too
    trainer_b, batches_b = _tiny_setup(tmp_path, dmd=True, fail_at=12,
                                       ckpt_every=7, groups=groups)
    with pytest.raises(RuntimeError, match="injected failure"):
        trainer_b.fit(batches_b, steps=18)

    trainer_c, _ = _tiny_setup(tmp_path, dmd=True, groups=groups)
    from repro.checkpoint import latest_step
    start = latest_step(tmp_path)
    assert 0 < start < 18
    batches_c = synthetic_lm_batches(0, 4, 16,
                                     trainer_c.model.cfg.vocab_size,
                                     start_step=start)
    final_c = trainer_c.fit(batches_c, steps=18)
    for a, c in zip(jax.tree_util.tree_leaves(final_a.params),
                    jax.tree_util.tree_leaves(final_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # the mixed-m DMD state round-tripped too
    for a, c in zip(jax.tree_util.tree_leaves(final_a.dmd_buffers),
                    jax.tree_util.tree_leaves(final_c.dmd_buffers)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_default_config_fused_path_matches_pre_refactor_oracle():
    """Oracle for the schedule refactor (acceptance: 'default single-group
    configs bit-exact with pre-refactor behavior'): the pre-refactor fused
    step — one scalar dmd_slot argument, one lax.cond, scalar relax, full
    opt reset — reimplemented verbatim here, driven by the legacy scalar
    schedule, must produce the BIT-IDENTICAL trajectory to the new
    step-index-driven Trainer path.

    Pinned to dmd.arena=False: the per-leaf route IS the oracle the packed
    arenas are A/B'd against (tests/test_arena.py pins arena-vs-per-leaf
    agreement separately), and this test's hand-rolled legacy step is
    per-leaf by construction."""
    from repro.core import snapshots as snap
    from repro.core.accelerator import jump_tree
    from repro.optim import apply_updates, make_optimizer
    from repro.train.state import TrainState

    trainer, batches = _tiny_setup(dmd=True, arena=False)
    acfg, model, acc = trainer.acfg, trainer.model, trainer.acc
    cfg = acfg.dmd
    steps = 16

    state_f = trainer.fit(batches, steps=steps)

    opt = make_optimizer(acfg.optimizer)

    def old_train_step(state, batch, dmd_slot):
        params = state.params
        loss, grads = jax.value_and_grad(
            lambda p: model.loss(p, batch)[0])(params)
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)
        updates, opt_state = opt.update(grads, state.opt_state, params,
                                        state.step)
        params = apply_updates(params, updates)
        buffers, grams = state.dmd_buffers, state.dmd_gram
        plans = acc.plans_for(params)

        def write(args):
            bufs, g = args
            slot = jnp.maximum(dmd_slot, 0)
            bufs = snap.record(bufs, params, slot, plans)
            g = snap.update_grams(g, bufs, params, slot, cfg, plans)
            return bufs, g
        buffers, grams = jax.lax.cond(dmd_slot >= 0, write, lambda a: a,
                                      (buffers, grams))
        new_state = TrainState(params, opt_state, state.step + 1, buffers,
                               grams)
        gnorm = jnp.sqrt(sum(jnp.vdot(g, g)
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm}

    def old_dmd_step(state, relax):
        plans = acc.plans_for(state.params)
        params, mean_rank = jump_tree(cfg, plans, state.params,
                                      state.dmd_buffers, state.dmd_gram,
                                      relax)
        opt_state = opt.init(params) if cfg.reset_opt_state \
            else state.opt_state
        return TrainState(params, opt_state, state.step, state.dmd_buffers,
                          state.dmd_gram), {"mean_rank": mean_rank}

    old_train = jax.jit(old_train_step, donate_argnums=(0,))
    old_jump = jax.jit(old_dmd_step, donate_argnums=(0,))

    def legacy_slot(t):
        eff = t - cfg.warmup_steps
        if eff < 0:
            return -1
        return eff % (cfg.cooldown_steps + cfg.m) - cfg.cooldown_steps

    state = trainer.init_state()
    batches2 = synthetic_lm_batches(0, 4, 16, model.cfg.vocab_size)
    for t in range(steps):
        state, _ = old_train(state, next(batches2),
                             jnp.asarray(legacy_slot(t), jnp.int32))
        if legacy_slot(t) == cfg.m - 1:
            round_idx = (t - cfg.warmup_steps) // (cfg.cooldown_steps + cfg.m)
            relax = jnp.asarray(
                cfg.relax * cfg.anneal ** max(round_idx, 0), jnp.float32)
            state, _ = old_jump(state, relax)

    for a, b in zip(jax.tree_util.tree_leaves(state_f.params),
                    jax.tree_util.tree_leaves(state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(state_f.dmd_gram),
                    jax.tree_util.tree_leaves(state.dmd_gram)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Loss-gated jump controller (ISSUE 4)
# ---------------------------------------------------------------------------

def _ctrl_cfg(**kw):
    from repro.configs.base import DMDControllerConfig
    return DMDControllerConfig(enabled=True, **kw)


def _eval_batch_for(trainer):
    """A step-independent held-out batch (deterministic across resumes)."""
    from repro.data.tokens import batch_for_step
    return batch_for_step(0, 10 ** 6, 4, 16, trainer.model.cfg.vocab_size)


def test_controller_rollback_oracle():
    """ISSUE 4 satellite: force every jump to REJECT (adversarial gate — an
    accept threshold no positive eval loss can meet) and pin the rollback:
    the final TrainState must be assert_array_equal-IDENTICAL to a run that
    never jumped at all — params, optimizer moments, snapshot buffers, and
    Gram slots. The oracle run drives trainer.train_step directly and never
    dispatches a dmd_step, on the same batch stream."""
    ctrl = _ctrl_cfg(accept_tol=-1.0)          # loss_post <= 0: impossible
    trainer, batches = _tiny_setup(dmd=True, controller=ctrl)
    eval_batch = _eval_batch_for(trainer)
    outcomes = []

    def on_m(s, m):
        if "ctrl_outcome" in m:
            outcomes.append(int(m["ctrl_outcome"]))
    state = trainer.fit(batches, steps=16, on_metrics=on_m,
                        eval_batch=eval_batch)
    assert outcomes and all(o == 0 for o in outcomes)     # all rejected
    assert int(state.controller.rejects.sum()) == len(outcomes)

    # oracle: identical trainer, train_step only — "a run that never jumped"
    oracle, _ = _tiny_setup(dmd=True, controller=ctrl)
    o_state = oracle.init_state()
    batches2 = synthetic_lm_batches(0, 4, 16, oracle.model.cfg.vocab_size)
    for t in range(16):
        o_state, _ = oracle.train_step(o_state, next(batches2),
                                       jnp.asarray(t, jnp.int32))

    for name, a_tree, b_tree in (
            ("params", state.params, o_state.params),
            ("opt_state", state.opt_state, o_state.opt_state),
            ("dmd_buffers", state.dmd_buffers, o_state.dmd_buffers),
            ("dmd_gram", state.dmd_gram, o_state.dmd_gram)):
        for a, b in zip(jax.tree_util.tree_leaves(a_tree),
                        jax.tree_util.tree_leaves(b_tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


def test_controller_accepts_and_adapts():
    """End to end with the gate on: outcomes are recorded, the counters add
    up, rejected jumps shrink s_eff below the cap, and params stay finite."""
    trainer, batches = _tiny_setup(dmd=True, controller=_ctrl_cfg())
    outcomes = []

    def on_m(s, m):
        if "ctrl_outcome" in m:
            outcomes.append(int(m["ctrl_outcome"]))
    state = trainer.fit(batches, steps=28, on_metrics=on_m,
                        eval_batch=_eval_batch_for(trainer))
    ctrl = state.controller
    assert len(outcomes) == 4                  # jumps at 9, 15, 21, 27
    assert int(ctrl.accepts.sum() + ctrl.scaled.sum()
               + ctrl.rejects.sum()) == len(outcomes)
    assert outcomes.count(2) == int(ctrl.accepts.sum())
    assert outcomes.count(0) == int(ctrl.rejects.sum())
    cap = trainer.acc.groups[0].s
    if int(ctrl.rejects.sum()):
        assert float(ctrl.s_eff[0]) < cap
    else:
        assert float(ctrl.s_eff[0]) <= cap
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_controller_off_is_default_and_state_free():
    """controller.enabled=False keeps the PR-3 surface exactly: no
    controller state in TrainState, the 3-arg dmd_step signature, and the
    same trajectory as ever (the fused-step oracle above pins bit-exactness
    of that path)."""
    trainer, batches = _tiny_setup(dmd=True)
    assert not trainer.controller_on
    state = trainer.fit(batches, steps=12)
    assert state.controller is None


def test_controller_two_group_staggered_gates_each_jump():
    """Controller + two staggered groups: each group's jump step gets its
    own gate decision; only the jumped group's counters move."""
    trainer, batches = _tiny_setup(dmd=True, groups=_two_groups(),
                                   controller=_ctrl_cfg())
    state = trainer.fit(batches, steps=26,
                        eval_batch=_eval_batch_for(trainer))
    ctrl = state.controller
    total = int(ctrl.accepts.sum() + ctrl.scaled.sum()
                + ctrl.rejects.sum())
    n_jump_steps = sum(len(trainer.acc.apply_groups(t)) for t in range(26))
    assert total == n_jump_steps
    per_group = np.asarray(ctrl.accepts + ctrl.scaled + ctrl.rejects)
    for g in range(trainer.acc.n_groups):
        expect = sum(1 for t in range(26)
                     if g in trainer.acc.apply_groups(t))
        assert per_group[g] == expect, (g, per_group, expect)


def test_restore_rebuilds_grams_from_pre_streaming_checkpoint(tmp_path):
    """A checkpoint without dmd_gram leaves (written before the streaming
    engine existed) must not resume with the template's all-zero Grams:
    restore rebuilds them from the restored buffers."""
    from repro.core import dmd, snapshots as snap
    from repro.checkpoint import save_checkpoint

    trainer, batches = _tiny_setup(tmp_path, dmd=True)
    # run past warmup+cooldown so the buffers hold real snapshots mid-window
    state = trainer.fit(batches, steps=9)
    assert state.dmd_gram is not None
    # simulate the old format: leaf-wise on disk (checkpoints are ALWAYS
    # written leaf-wise — arenas unpacked), gram subtree dropped
    save_checkpoint(str(tmp_path),
                    trainer.acc.state_leafwise(state)._replace(dmd_gram=None),
                    9)

    trainer2, _ = _tiny_setup(tmp_path, dmd=True)
    restored = trainer2.restore()
    assert restored is not None and int(restored.step) == 9
    # verify against the leaf-wise view (the run itself carries arenas)
    restored = trainer2.acc.state_leafwise(restored)
    plans = trainer2.acc.plans_for(restored.params)

    def chk(plan, buf, g):
        if buf is None or plan is None:
            return None
        assert g is not None
        if bool(jnp.any(buf != 0)):
            oracle = dmd.gram_matrix(buf, anchor=trainer2.acfg.dmd.anchor,
                                     stack_dims=plan.stack_dims)
            np.testing.assert_allclose(np.asarray(g), np.asarray(oracle),
                                       rtol=1e-5, atol=1e-5)
        return None
    from repro.core.leafplan import is_plan_leaf
    jax.tree_util.tree_map(chk, plans, restored.dmd_buffers,
                           restored.dmd_gram, is_leaf=is_plan_leaf)


# ---------------------------------------------------------------------------
# Validation-gated controller (ISSUE 9)
# ---------------------------------------------------------------------------

class _CountingIter:
    """Wraps the batch iterator and counts next() calls — the stream-position
    probe for the gate-leak regression."""

    def __init__(self, it):
        self.it, self.n = it, 0

    def __iter__(self):
        return self

    def __next__(self):
        self.n += 1
        return next(self.it)


def test_gate_never_consumes_training_batches():
    """Regression (ISSUE 9 tentpole bug): the old controller fallback drew
    its gate batch via next(batches), consuming a TRAINING batch — the
    stream position shifted by one and the gate scored on training data. A
    gated fit with no explicit eval_batch must consume exactly `steps`
    batches (gate rounds included) and gate on the init-carved validation
    split instead."""
    trainer, batches = _tiny_setup(dmd=True, controller=_ctrl_cfg())
    assert trainer.val_batch is not None       # carved at init (vocab model)
    wrapped = _CountingIter(batches)
    outcomes = []

    def on_m(s, m):
        if "ctrl_outcome" in m:
            outcomes.append(int(m["ctrl_outcome"]))
    trainer.fit(wrapped, steps=16, on_metrics=on_m)
    assert outcomes                            # the gate DID fire
    assert wrapped.n == 16                     # ... without touching the stream


def test_val_gate_rollback_oracle():
    """ISSUE 9 satellite: the PR-4 forced-reject oracle through the NEW
    validation-gate path — accept_tol=-1.0 with val_gate=True and NO
    explicit eval_batch (the gate runs on the trainer's carved validation
    split). Every jump must reject and the final TrainState must be
    array-equal-IDENTICAL to a run that never dispatched a dmd_step."""
    ctrl = _ctrl_cfg(accept_tol=-1.0, val_gate=True)
    trainer, batches = _tiny_setup(dmd=True, controller=ctrl)
    assert trainer.val_batch is not None
    outcomes = []

    def on_m(s, m):
        if "ctrl_outcome" in m:
            outcomes.append(int(m["ctrl_outcome"]))
    state = trainer.fit(batches, steps=16, on_metrics=on_m)
    assert outcomes and all(o == 0 for o in outcomes)
    assert int(state.controller.rejects.sum()) == len(outcomes)

    oracle, _ = _tiny_setup(dmd=True, controller=ctrl)
    o_state = oracle.init_state()
    batches2 = synthetic_lm_batches(0, 4, 16, oracle.model.cfg.vocab_size)
    for t in range(16):
        o_state, _ = oracle.train_step(o_state, next(batches2),
                                       jnp.asarray(t, jnp.int32))
    for name, a_tree, b_tree in (
            ("params", state.params, o_state.params),
            ("opt_state", state.opt_state, o_state.opt_state),
            ("dmd_buffers", state.dmd_buffers, o_state.dmd_buffers),
            ("dmd_gram", state.dmd_gram, o_state.dmd_gram)):
        for a, b in zip(jax.tree_util.tree_leaves(a_tree),
                        jax.tree_util.tree_leaves(b_tree)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=name)


def test_val_gate_prefers_validation_split():
    """val_gate=True must gate on the carved validation split even when the
    caller hands fit() a DIFFERENT eval_batch: the run with a decoy batch
    and the run with none are bit-identical."""
    ctrl = _ctrl_cfg(val_gate=True)
    trainer_a, batches_a = _tiny_setup(dmd=True, controller=ctrl)
    decoy = _eval_batch_for(trainer_a)         # stream offset 10^6 != fold
    state_a = trainer_a.fit(batches_a, steps=16, eval_batch=decoy)

    trainer_b, batches_b = _tiny_setup(dmd=True, controller=ctrl)
    state_b = trainer_b.fit(batches_b, steps=16)

    for a, b in zip(jax.tree_util.tree_leaves(state_a.params),
                    jax.tree_util.tree_leaves(state_b.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(
        np.asarray(state_a.controller.accepts),
        np.asarray(state_b.controller.accepts))


def test_controller_without_gate_batch_raises():
    """No carved split AND no explicit eval_batch must be a loud error —
    never a silent draw from the training iterator (the old leak)."""
    trainer, batches = _tiny_setup(dmd=True, controller=_ctrl_cfg())
    trainer.val_batch = None                   # simulate a vocab-less model
    with pytest.raises(ValueError, match="gate batch"):
        trainer.fit(batches, steps=10)


def test_eval_rows_clamped_to_batch_size():
    """eval_rows far past the actual batch size clamps instead of slicing
    into nothing; the gate still fires and the run stays finite."""
    ctrl = _ctrl_cfg(eval_rows=999)            # batch has 4 rows
    trainer, batches = _tiny_setup(dmd=True, controller=ctrl)
    outcomes = []

    def on_m(s, m):
        if "ctrl_outcome" in m:
            outcomes.append(int(m["ctrl_outcome"]))
    state = trainer.fit(batches, steps=16, on_metrics=on_m)
    assert outcomes
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_meta_tuning_moves_knobs_and_stays_finite():
    """meta_lr > 0 (matpow mode): after gated jumps the per-group
    relax_eff/ridge_eff have been EMA'd somewhere INSIDE their bands and
    the trajectory stays finite; with meta off they sit exactly at their
    init values (ridge_eff == schedule ridge, relax only moved by
    accept/scale dynamics)."""
    ctrl = _ctrl_cfg(val_gate=True, meta_lr=0.25, ridge_max=0.1)
    trainer, batches = _tiny_setup(dmd=True, controller=ctrl)
    state = trainer.fit(batches, steps=16)
    ctrl_st = state.controller
    r = np.asarray(ctrl_st.ridge_eff)
    assert np.all(np.isfinite(r)) and np.all(r >= 0.0) and np.all(r <= 0.1)
    assert np.all(np.isfinite(np.asarray(ctrl_st.relax_eff)))
    # meta actually moved the jumped group's ridge off its init (init is
    # the schedule ridge = 0.0 here; EMA toward 0 keeps it 0 ONLY if every
    # gradient said "less ridge" — either way the run recorded jumps)
    assert int(ctrl_st.accepts.sum() + ctrl_st.scaled.sum()
               + ctrl_st.rejects.sum()) > 0
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_shrink_levels_validation():
    """Bad shrink ladders fail at BUILD time, not mid-run."""
    from repro.train.step import make_dmd_step
    ctrl = _ctrl_cfg(shrink_levels=(0.5, 1.5))
    trainer, _ = _tiny_setup(dmd=True)         # plain trainer for acc/model
    acfg = dataclasses.replace(
        trainer.acfg, dmd=dataclasses.replace(trainer.acfg.dmd,
                                              controller=ctrl))
    with pytest.raises(ValueError, match="shrink_levels"):
        make_dmd_step(acfg, model=trainer.model)
