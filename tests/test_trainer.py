"""Trainer: loss decreases, DMD schedule fires, failure-inject + resume is
bit-exact, preemption-style checkpointing."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import synthetic_lm_batches
from repro.models.transformer import LanguageModel
from repro.train import Trainer


def _tiny_setup(tmpdir=None, dmd=False, fail_at=None, ckpt_every=0):
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    acfg = dataclasses.replace(
        acfg,
        model=mc,
        dmd=DMDConfig(enabled=dmd, m=4, s=10, tol=1e-4, warmup_steps=4,
                      cooldown_steps=2),
        optimizer=OptimizerConfig(name="adam", lr=3e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                     remat="none"),
        train=TrainConfig(global_batch=4, seq_len=16,
                          checkpoint_every=ckpt_every,
                          checkpoint_dir=str(tmpdir) if tmpdir else ""))
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    trainer = Trainer(model, acfg, checkpoint_dir=str(tmpdir) if tmpdir
                      else None, fail_at_step=fail_at)
    batches = synthetic_lm_batches(0, 4, 16, mc.vocab_size)
    return trainer, batches


def test_loss_decreases():
    trainer, batches = _tiny_setup()
    losses = []
    trainer.fit(batches, steps=30,
                on_metrics=lambda s, m: losses.append(float(m["loss"])))
    assert losses[-1] < losses[0]


def test_dmd_schedule_fires():
    trainer, batches = _tiny_setup(dmd=True)
    ranks = []

    def on_m(s, m):
        if "mean_rank" in m:
            ranks.append(float(m["mean_rank"]))
    trainer.fit(batches, steps=22, on_metrics=on_m)
    # warmup 4, then cycles of (cooldown 2 + m 4): jumps at steps 9, 15, 21
    assert len(ranks) == 3
    assert all(r >= 1 for r in ranks)


def test_failure_injection_and_bitexact_resume(tmp_path):
    # uninterrupted reference run
    trainer_a, batches_a = _tiny_setup()
    final_a = trainer_a.fit(batches_a, steps=12)

    # interrupted at step 8 with checkpointing every 4
    trainer_b, batches_b = _tiny_setup(tmp_path, fail_at=8, ckpt_every=4)
    with pytest.raises(RuntimeError, match="injected failure"):
        trainer_b.fit(batches_b, steps=12)

    # resume: new trainer, data stream replayed from the checkpointed step
    trainer_c, _ = _tiny_setup(tmp_path)
    from repro.checkpoint import latest_step
    start = latest_step(tmp_path)
    assert start == 8
    batches_c = synthetic_lm_batches(0, 4, 16,
                                     trainer_c.model.cfg.vocab_size,
                                     start_step=start)
    final_c = trainer_c.fit(batches_c, steps=12)

    for a, c in zip(jax.tree_util.tree_leaves(final_a.params),
                    jax.tree_util.tree_leaves(final_c.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_dmd_trainer_end_to_end_finite(tmp_path):
    trainer, batches = _tiny_setup(tmp_path, dmd=True, ckpt_every=6)
    state = trainer.fit(batches, steps=14)
    for leaf in jax.tree_util.tree_leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf)))


def test_restore_rebuilds_grams_from_pre_streaming_checkpoint(tmp_path):
    """A checkpoint without dmd_gram leaves (written before the streaming
    engine existed) must not resume with the template's all-zero Grams:
    restore rebuilds them from the restored buffers."""
    from repro.core import dmd, snapshots as snap
    from repro.checkpoint import save_checkpoint

    trainer, batches = _tiny_setup(tmp_path, dmd=True)
    # run past warmup+cooldown so the buffers hold real snapshots mid-window
    state = trainer.fit(batches, steps=9)
    assert state.dmd_gram is not None
    # simulate the old format: drop the gram subtree before saving
    save_checkpoint(str(tmp_path), state._replace(dmd_gram=None), 9)

    trainer2, _ = _tiny_setup(tmp_path, dmd=True)
    restored = trainer2.restore()
    assert restored is not None and int(restored.step) == 9
    plans = trainer2.acc.plans_for(restored.params)

    def chk(plan, buf, g):
        if buf is None or plan is None:
            return None
        assert g is not None
        if bool(jnp.any(buf != 0)):
            oracle = dmd.gram_matrix(buf, anchor=trainer2.acfg.dmd.anchor,
                                     stack_dims=plan.stack_dims)
            np.testing.assert_allclose(np.asarray(g), np.asarray(oracle),
                                       rtol=1e-5, atol=1e-5)
        return None
    from repro.core.leafplan import is_plan_leaf
    jax.tree_util.tree_map(chk, plans, restored.dmd_buffers,
                           restored.dmd_gram, is_leaf=is_plan_leaf)
