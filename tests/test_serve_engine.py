"""Continuous-batching engine invariants (ISSUE 10 tentpole).

The load-bearing claims of repro.serve.engine, each pinned here:

  * bucketed padded prompts are BIT-EXACT against the reference
    serve_fns prefill+decode loop (exact-length caches, per-request),
    including batched admission with filler rows into a live slot table;
  * one decode dispatch per generated token and ZERO host syncs between
    dispatches — sampling (argmax / top-k) lives inside the jitted
    decode program (the seed drivers' per-token ``jnp.argmax`` host
    round-trip is the defect this pins against);
  * steady state never recompiles: after warming every bucket the
    program registry is frozen (mark_steady + steady_compiles == 0);
  * hot-swap: serving a swapped-in version is bit-exact with a
    cold-started server on those weights, in-flight requests adopt per
    policy ("step" immediately, "drain" finishes on the start version);
  * unsupported cache families fail loudly at construction.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.serve import serve_fns
from repro.models.transformer import LanguageModel
from repro.serve import ServeConfig, ServeEngine

PROMPTS = [[1, 2, 3], [5, 6, 7, 8, 9], [2, 4], [7] * 8, [3, 1, 4, 1, 5, 9]]


@functools.lru_cache(maxsize=None)
def _model_and_params():
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    # scan_layers=False is the serving build (launch/serve.py)
    model = LanguageModel(mc, head_tp=False, chunk_k=16, scan_layers=False)
    return model, model.init(jax.random.PRNGKey(0))


@functools.lru_cache(maxsize=None)
def _reference_fns():
    model, _ = _model_and_params()
    return serve_fns(model, donate=False)


def _engine(**kw):
    model, params = _model_and_params()
    kw.setdefault("n_slots", 4)
    kw.setdefault("prompt_buckets", (4, 8))
    kw.setdefault("batch_buckets", (1, 2))
    kw.setdefault("max_new_tokens", 5)
    return ServeEngine(model, params, ServeConfig(**kw))


def _reference_greedy(prompt, n_new, params=None):
    """The pre-engine serving loop: exact-length prefill, then the
    (host-side) greedy argmax decode — the correctness oracle."""
    model, p0 = _model_and_params()
    fns = _reference_fns()
    params = p0 if params is None else params
    caches = model.init_cache(1, len(prompt) + n_new)
    toks = jnp.asarray(np.asarray(prompt, np.int32)[None])
    logits, caches = fns["prefill"](params, {"tokens": toks}, caches)
    out = []
    for _ in range(n_new):
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        out.append(int(tok[0, 0]))
        logits, caches = fns["decode_step"](params, {"tokens": tok}, caches)
    return out


def test_engine_matches_reference_greedy():
    """Mixed prompt lengths across both buckets, concurrent slots, padded
    prefill: every request's tokens equal the exact-length reference."""
    eng = _engine()
    for p in PROMPTS:
        eng.submit(p)
    res = {r.uid: r for r in eng.run_until_drained()}
    assert len(res) == len(PROMPTS)
    for i, p in enumerate(PROMPTS):
        assert res[i].tokens == _reference_greedy(p, 5), (i, p)
        assert res[i].prompt_len == len(p)
    assert eng.stats["dropped"] == 0


def test_batched_admission_preserves_live_slots():
    """A batch-bucketed insert scatters per-request rows; filler rows
    carry an out-of-range sentinel slot and must not clobber anything —
    neither free slots nor mid-flight requests admitted earlier."""
    eng = _engine(n_slots=8, batch_buckets=(1, 2, 4))
    for p in ([1, 2, 3], [2, 4], [3, 3, 3, 1]):      # one bucket, 3 reqs
        eng.submit(p)
    eng.step()                                        # Bb=4 + filler row
    assert "insert_b4" in eng._programs
    eng.submit([9, 9, 9])                             # admit mid-flight
    res = {r.uid: r.tokens for r in eng.run_until_drained()}
    for i, p in enumerate([[1, 2, 3], [2, 4], [3, 3, 3, 1], [9, 9, 9]]):
        assert res[i] == _reference_greedy(p, 5), (i, p)


def test_one_dispatch_per_token_and_in_jit_sampling():
    """The dispatch-count pin for the per-token host-sync fix: N generated
    tokens cost exactly N decode dispatches of ONE compiled program, and
    the sampling argmax is inside that program's jaxpr — not host code
    between dispatches."""
    eng = _engine()
    eng.submit([1, 2, 3], max_new_tokens=5)
    eng.run_until_drained()
    assert eng.stats["decode_dispatches"] == 5
    assert eng.stats["prefill_dispatches"] == 1
    decode_programs = [n for n in eng._programs if n.startswith("decode")]
    assert decode_programs == ["decode"]
    assert "argmax" in str(eng._programs["decode"].jaxpr)

    # concurrent slots share dispatches: 2 more requests, still one
    # dispatch per decode STEP (not per request-token)
    eng.submit([4, 5]); eng.submit([6, 7, 8])
    eng.run_until_drained()
    assert eng.stats["decode_dispatches"] == 10
    assert eng.stats["tokens_emitted"] == 15


def test_steady_state_never_recompiles():
    eng = _engine()
    # warmup: touch both prompt buckets at batch buckets 1 and 2
    for wave in ([3, 3], [7, 7], [2], [5]):
        for n in wave:
            eng.submit(list(range(1, n + 1)))
        eng.run_until_drained()
    eng.mark_steady()
    warm = eng.n_programs
    for wave in ([4, 4], [8, 8], [1], [6]):           # new in-bucket lens
        for n in wave:
            eng.submit(list(range(1, n + 1)))
        eng.run_until_drained()
    assert eng.stats["steady_compiles"] == 0
    assert eng.n_programs == warm <= eng.max_programs


def test_topk_sampling_is_deterministic_and_in_jit():
    kw = dict(sampling="topk", top_k=4, seed=11)
    a, b = _engine(**kw), _engine(**kw)
    for e in (a, b):
        e.submit([1, 2, 3]); e.submit([4, 5])
    ra = {r.uid: r.tokens for r in a.run_until_drained()}
    rb = {r.uid: r.tokens for r in b.run_until_drained()}
    assert ra == rb
    assert all(len(t) == 5 for t in ra.values())
    assert a.stats["decode_dispatches"] == 5


def test_swap_is_bit_exact_vs_cold_start():
    """The swapped-in version serves tokens AND final logits identical to
    a server cold-started on those weights (ISSUE 10 satellite)."""
    model, params = _model_and_params()
    bumped = jax.tree_util.tree_map(lambda l: l * 1.5, params)
    hot = _engine()
    hot.submit([1, 2, 3])
    hot.run_until_drained()                       # serve v0 first
    assert hot.swap_weights(bumped, version=7) == 7
    assert hot.version == 7
    cold = ServeEngine(model, bumped, ServeConfig(
        n_slots=4, prompt_buckets=(4, 8), batch_buckets=(1, 2),
        max_new_tokens=5))
    for p in PROMPTS[:3]:
        hot.submit(p); cold.submit(p)
    rh = {r.uid: r for r in hot.run_until_drained()}
    rc = {r.uid: r for r in cold.run_until_drained()}
    # uids differ (hot served one request before), align by submit order
    for uh, uc in zip(sorted(rh), sorted(rc)):
        assert rh[uh].tokens == rc[uc].tokens
        np.testing.assert_array_equal(rh[uh].last_logits,
                                      rc[uc].last_logits)
        assert (rh[uh].version_start, rh[uh].version_end) == (7, 7)
    # the swap itself never compiles: same registry before and after
    assert hot.stats["compiles"] == cold.stats["compiles"]
    assert hot.stats["dropped"] == 0


def test_step_adopt_swaps_in_flight_requests():
    model, params = _model_and_params()
    bumped = jax.tree_util.tree_map(lambda l: l * 1.5, params)
    eng = _engine(adopt="step", max_new_tokens=6)
    eng.submit([1, 2, 3])
    eng.step(); eng.step()                        # 2 of 6 tokens on v0
    eng.swap_weights(bumped, version=3)
    (res,) = eng.run_until_drained()
    assert (res.version_start, res.version_end) == (0, 3)
    assert eng.stats["swaps"] == 1


def test_drain_adopt_holds_until_table_empties():
    model, params = _model_and_params()
    bumped = jax.tree_util.tree_map(lambda l: l * 1.5, params)
    eng = _engine(adopt="drain", max_new_tokens=4)
    eng.submit([1, 2, 3])
    eng.step()
    eng.swap_weights(bumped, version=3)
    assert eng.version == 0                       # active slot: no adopt
    eng.submit([4, 5])                            # held while pending
    res = {r.uid: r for r in eng.run_until_drained()}
    assert (res[0].version_start, res[0].version_end) == (0, 0)
    assert (res[1].version_start, res[1].version_end) == (3, 3)
    assert eng.version == 3
    # the held request was NOT dropped, just deferred
    assert res[1].tokens == _reference_greedy([4, 5], 4, params=bumped)


def test_submit_validation():
    eng = _engine()
    with pytest.raises(ValueError, match="exceeds the largest"):
        eng.submit(list(range(20)))
    with pytest.raises(ValueError, match="empty"):
        eng.submit([])
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], max_new_tokens=99)
    with pytest.raises(ValueError, match="stale publish"):
        _, params = _model_and_params()
        eng.swap_weights(params, version=0)


def test_unsupported_families_fail_loudly():
    _, params = _model_and_params()
    acfg = get_config("gemma3-27b")               # ring caches (dense_local)
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    ring = LanguageModel(mc, head_tp=False, chunk_k=16, scan_layers=False)
    with pytest.raises(NotImplementedError, match="segment kinds"):
        ServeEngine(ring, ring.init(jax.random.PRNGKey(0)), ServeConfig())

    model, params = _model_and_params()
    scanned = LanguageModel(model.cfg, head_tp=False, chunk_k=16,
                            scan_layers=True)
    with pytest.raises(ValueError, match="scan_layers"):
        ServeEngine(scanned, params, ServeConfig())


def test_serve_state_specs_cover_the_slot_table():
    """launch/inputs.serve_state_specs: slot axis over the batch axes,
    kv-head TP preserved, PRNG key and scalars replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.inputs import serve_state_specs

    eng = _engine(n_slots=4)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    specs = serve_state_specs(eng._dstate, mesh)
    flat = {jax.tree_util.keystr(kp): s
            for kp, s in jax.tree_util.tree_flatten_with_path(specs)[0]}
    assert flat["['key']"] == P()
    assert flat["['out_buf']"][0] == ("data",)
    # cache k/v leaves: slot axis first, nothing on the garbage dims
    cache_specs = [s for p, s in flat.items() if "caches" in p]
    assert cache_specs, flat.keys()
    for s in cache_specs:
        assert s[0] in (("data",), None)
    # same structure as the decode state: shardings_of can map it 1:1
    jax.tree_util.tree_map(lambda a, b: None, specs, eng._dstate)