"""Subprocess worker for distributed tests (run with
XLA_FLAGS=--xla_force_host_platform_device_count=N).

Modes (argv[1]):
  train <ndev> <ckpt_dir?>   3 sharded train steps; prints loss + checksum
  gram                        sharded DMD gram == numpy
  gradsync                    int8 cross-pod psum correctness
  elastic_save <dir>          train 2 steps on (2,2) mesh, checkpoint
  elastic_restore <dir>       restore on (4,) x model=2... different mesh,
                              run 1 more step, print checksum
"""
import os
import sys

n_dev = os.environ.get("TEST_NDEV", "8")
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import batch_for_step
from repro.distributed.sharding import mesh_context, partition_specs, set_mesh
from repro.models.transformer import LanguageModel
from repro.train import Trainer
from repro.train.state import TrainState


def small_acfg():
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=4, n_kv_heads=2, head_dim=8)
    return dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=True, m=4, s=8, tol=1e-4, warmup_steps=2,
                      cooldown_steps=0),
        optimizer=OptimizerConfig(name="adam", lr=1e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=2,
                                     remat="none"),
        train=TrainConfig(global_batch=8, seq_len=16))


def checksum(tree):
    return float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
                     for l in jax.tree_util.tree_leaves(tree)))


def run_train(mesh_shape, axis_names, steps=6):
    acfg = small_acfg()
    mesh = jax.make_mesh(mesh_shape, axis_names)
    model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
    with mesh_context(mesh):
        trainer = Trainer(model, acfg, mesh=mesh)
        state = trainer.init_state()
        losses = []
        from repro.train.step import make_train_step
        for step in range(steps):
            batch = batch_for_step(0, step, 8, 16, acfg.model.vocab_size)
            slot = trainer.acc.slot(step)
            state, m = trainer.train_step(state, batch,
                                          jnp.asarray(slot, jnp.int32))
            if trainer.acc.should_apply(step):
                state, _ = trainer.dmd_step(state, jnp.asarray(1.0))
            losses.append(float(m["loss"]))
        return losses, checksum(state.params)


def main():
    mode = sys.argv[1]
    if mode == "train":
        shape = sys.argv[2]
        if shape == "2x4":
            losses, cs = run_train((2, 4), ("data", "model"))
        elif shape == "1x1":
            losses, cs = run_train((1, 1), ("data", "model"))
        elif shape == "2x2x2":
            losses, cs = run_train((2, 2, 2), ("pod", "data", "model"))
        print("LOSSES", " ".join(f"{l:.6f}" for l in losses))
        print("CHECKSUM", f"{cs:.4f}")
    elif mode == "gram":
        from repro.core.dmd import gram_matrix
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        S = rng.normal(size=(6, 64, 32)).astype(np.float32)
        from jax.sharding import NamedSharding, PartitionSpec as P
        sharded = jax.device_put(
            S, NamedSharding(mesh, P(None, "data", "model")))
        with set_mesh(mesh):
            g = jax.jit(lambda s: gram_matrix(s, anchor="first"))(sharded)
        flat = S.reshape(6, -1)
        flat = flat - flat[:1]
        ref = flat @ flat.T
        err = float(np.abs(np.asarray(g) - ref).max() / np.abs(ref).max())
        print("GRAM_ERR", f"{err:.2e}")
        assert err < 1e-5
    elif mode == "gradsync":
        from repro.distributed.gradsync import int8_psum_grads
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
        with set_mesh(mesh):
            synced = jax.jit(lambda t: int8_psum_grads(t, mesh))(g)
        # replicated input: mean over pods == input (up to int8 quantization)
        err = float(jnp.max(jnp.abs(synced["w"] - g["w"])))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        print("GRADSYNC_ERR", f"{err:.4f}", "TOL", f"{scale:.4f}")
        assert err <= scale * 1.01 + 1e-6
    elif mode == "elastic_save":
        ckpt = sys.argv[2]
        acfg = small_acfg()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(100))
            state = trainer.fit(batches, steps=2)
            trainer.save(state, 2)
        print("SAVED", checksum(state.params))
    elif mode == "elastic_restore":
        ckpt = sys.argv[2]
        acfg = small_acfg()
        mesh = jax.make_mesh((4, 2), ("data", "model"))   # DIFFERENT topology
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            state = trainer.restore()
            assert state is not None and int(state.step) == 2
            batch = batch_for_step(0, 2, 8, 16, acfg.model.vocab_size)
            state, m = trainer.train_step(state, batch,
                                          jnp.asarray(-1, jnp.int32))
            assert np.isfinite(float(m["loss"]))
        print("RESTORED", checksum(state.params), f"{float(m['loss']):.6f}")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
