"""Subprocess worker for distributed tests (run with
XLA_FLAGS=--xla_force_host_platform_device_count=N).

Modes (argv[1]):
  train <ndev> <ckpt_dir?>   3 sharded train steps; prints loss + checksum
  gram                        sharded DMD gram == numpy
  gradsync                    int8 cross-pod psum correctness
  elastic_save <dir>          train 2 steps on (2,2) mesh, checkpoint
  elastic_restore <dir>       restore on (4,) x model=2... different mesh,
                              run 1 more step, print checksum
  gram_save <dir> keep|zero|hetero
                              train through full DMD window(s) on (2,2),
                              checkpoint (zero: strip dmd_gram — the
                              pre-streaming format; hetero: TWO schedule
                              groups with different m, saved at a step
                              where both windows are complete)
  gram_restore <dir> [hetero] restore on the REMAPPED (4,2) mesh; check every
                              running Gram == gram_matrix oracle; GRAMS_OK
  sharded_kernels             pallas_shard_map route vs dot_general oracle
                              across window wraps (fsdp/tp-sharded + stacked
                              leaves, forced interpret-mode Pallas), plus the
                              update_grams HLO all-gather audit
  ctrl_save <dir> jump|mid    controller-enabled run on (2,2), SIGTERM
                              raised on the exact jump step (5) or
                              mid-window (7) -> preempt-save; prints the
                              CTRL line (counters / s_eff / relax_eff /
                              slot vector at the saved step)
  ctrl_restore <dir> <step>   restore on the REMAPPED (4,2) mesh; print the
                              same CTRL line (bit-exact vs ctrl_save's),
                              assert the cooldown/window phase re-derives
                              from the restored step, run to step 14 and
                              check the remaining gated jumps fire; CTRL_OK
  resident_save <dir>         ARENA-RESIDENT fit (adam, arena_native on) on
                              (2,2) for 6 steps with a sharded bucket;
                              prints the params checksum
  resident_restore <dir>      restore on the REMAPPED (4,2) mesh: the
                              leaf-wise checkpoint re-places per-leaf
                              against the new mesh, re-residentizes into
                              the new mesh's buckets, and one more fit
                              step runs on the resident state; RESIDENT_OK
"""
import os
import sys

n_dev = os.environ.get("TEST_NDEV", "8")
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.configs.base import DMDConfig, OptimizerConfig, TrainConfig
from repro.data.tokens import batch_for_step
from repro.distributed.sharding import mesh_context, set_mesh
from repro.models.transformer import LanguageModel
from repro.train import Trainer
from repro.train.state import TrainState


def small_acfg(hetero=False, controller=False):
    from repro.configs.base import DMDControllerConfig
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=4, n_kv_heads=2, head_dim=8)
    groups = ()
    if hetero:
        # Two schedule groups with DIFFERENT windows: norm scales (both the
        # unstacked final_norm and the scan-stacked ln1/ln2) get m=3,
        # everything else the default m=4. Both windows complete at step 13
        # (default jumps at 5, 9, 13; norms at 4, 7, 10, 13).
        from repro.core.schedule import DMDGroupRule
        groups = (DMDGroupRule(name="norms", path_regex="norm|/ln", m=3),)
    return dataclasses.replace(
        acfg, model=mc,
        dmd=DMDConfig(enabled=True, m=4, s=8, tol=1e-4, warmup_steps=2,
                      cooldown_steps=0, groups=groups,
                      controller=DMDControllerConfig(enabled=controller)),
        optimizer=OptimizerConfig(name="adam", lr=1e-3, schedule="constant"),
        parallel=dataclasses.replace(acfg.parallel, grad_accum=2,
                                     remat="none"),
        train=TrainConfig(global_batch=8, seq_len=16))


def checksum(tree):
    return float(sum(jnp.sum(jnp.abs(l.astype(jnp.float32)))
                     for l in jax.tree_util.tree_leaves(tree)))


def run_train(mesh_shape, axis_names, steps=6):
    acfg = small_acfg()
    mesh = jax.make_mesh(mesh_shape, axis_names)
    model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
    with mesh_context(mesh):
        trainer = Trainer(model, acfg, mesh=mesh)
        state = trainer.init_state()
        losses = []
        for step in range(steps):
            batch = batch_for_step(0, step, 8, 16, acfg.model.vocab_size)
            state, m = trainer.train_step(state, batch,
                                          jnp.asarray(step, jnp.int32))
            groups = trainer.acc.apply_groups(step)
            if groups:
                relax = jnp.asarray(trainer.acc.relax_vector(step))
                state, _ = trainer.dmd_step(state, relax, groups=groups)
            losses.append(float(m["loss"]))
        return losses, checksum(state.params)


# The largest-all-gather scan is the shared static-audit primitive since
# ISSUE 6 (repro.audit.hlo — one regex, one dtype map for both shard_map
# workers here AND the collective-budget pass the CLI runs).
from repro.audit.hlo import max_allgather_bytes  # noqa: E402


def run_sharded_kernels():
    """pallas_shard_map route == dot_general oracle on an 8-device mesh.

    Leaves cover the shapes the flat kernels could never serve under GSPMD:
    a 2-D fsdp+tp-sharded matrix, a tp-sharded vector, a bf16 fsdp+tp leaf
    (gram_upcast=False semantics: fp32 accumulation happens in-kernel), and
    a stacked (scan-over-layers) leaf. The Pallas bodies run through the
    interpreter (forced backend) inside shard_map. Also audits the lowered
    update_grams HLO: the whole point of the route is that NO buffer-sized
    all-gather appears (DESIGN.md §3.4).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import dmd as dmd_mod, leafplan
    from repro.core import snapshots as snap
    from repro.kernels import ops, sharded

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    m = 5
    cfg = DMDConfig(m=m, s=8, tol=1e-4, anchor="first", warmup_steps=0,
                    cooldown_steps=0)
    rng = np.random.default_rng(0)

    def mk(shape, dtype=jnp.float32):
        return jnp.asarray(rng.normal(size=shape), dtype)

    params = {
        "wqkv": mk((64, 32)),                    # ("data", "model"): fsdp+tp
        "A_log": mk((32,)),                      # ("model",): tp vector
        "w_gate": mk((64, 32), jnp.bfloat16),    # bf16 fsdp+tp leaf
        "seg0": {"attn": {"wqkv": mk((6, 64, 32))}},   # stacked
    }
    stack_dims = {"wqkv": 0, "A_log": 0, "w_gate": 0,
                  "seg0": {"attn": {"wqkv": 1}}}
    plans = leafplan.build_plans(params, cfg, mesh, stack_dims)
    flat_plans = leafplan.plan_entries(plans)
    assert all(p.route == "pallas_shard_map" for p in flat_plans), \
        [(p.path, p.route, p.sharded) for p in flat_plans]
    assert {p.path: p.psum_axes() for p in flat_plans} == {
        "/wqkv": ("data", "model"), "/A_log": ("model",),
        "/w_gate": ("data", "model"),
        "/seg0/attn/wqkv": ("data", "model")}

    place = lambda t, specs: jax.tree_util.tree_map(
        lambda l, s: jax.device_put(l, NamedSharding(mesh, s)), t, specs)
    params = place(params, jax.tree_util.tree_map(
        lambda pl: pl.param_spec, plans, is_leaf=leafplan.is_plan_leaf))

    ops.set_backend("pallas")                    # interpret-mode Pallas bodies
    try:
        with set_mesh(mesh):
            bufs = snap.init_buffers(params, cfg, plans)
            grams = snap.init_grams(bufs, cfg, plans)

            def upd(g, b, p, slot):
                b = snap.record(b, p, slot, plans)
                return b, snap.update_grams(g, b, p, slot, cfg, plans)
            upd_jit = jax.jit(upd)

            for window in range(2):              # across a full cyclic wrap
                for slot in range(m):
                    params = jax.tree_util.tree_map(
                        lambda p: (p + (0.03 * jnp.asarray(
                            rng.normal(size=p.shape), jnp.float32)
                        ).astype(p.dtype)), params)
                    bufs, grams = upd_jit(grams, bufs, params, slot)
                # window-complete: streaming == oracle (DESIGN.md §2)
                err = 0.0
                for key, pl in ((("wqkv",), plans["wqkv"]),
                                (("A_log",), plans["A_log"]),
                                (("w_gate",), plans["w_gate"]),
                                (("seg0", "attn", "wqkv"),
                                 plans["seg0"]["attn"]["wqkv"])):
                    b = bufs; g = grams
                    for k in key:
                        b, g = b[k], g[k]
                    oracle = dmd_mod.gram_matrix(
                        b, anchor=cfg.anchor, stack_dims=pl.stack_dims,
                        upcast=cfg.gram_upcast)
                    scale = max(float(jnp.max(jnp.abs(oracle))), 1.0)
                    tol = 3e-2 if b.dtype == jnp.bfloat16 else 1e-5
                    e = float(jnp.max(jnp.abs(g - oracle))) / scale
                    assert e < tol, (key, window, e)
                    err = max(err, e)
            print("STREAM_ERR", f"{err:.2e}")

            # gram_upcast=False + bf16 snapshot storage: the kernel's fused
            # in-VMEM upcast must match the bf16-accumulation oracle
            import dataclasses as _dc
            cfg_bf = _dc.replace(cfg, snapshot_dtype="bfloat16",
                                 gram_upcast=False)
            plans_bf = leafplan.build_plans(params, cfg_bf, mesh, stack_dims)
            bufs_bf = snap.init_buffers(params, cfg_bf, plans_bf)
            grams_bf = snap.init_grams(bufs_bf, cfg_bf, plans_bf)
            upd_bf = jax.jit(lambda g, b, p, slot: (
                lambda nb: (nb, snap.update_grams(g, nb, p, slot, cfg_bf,
                                                  plans_bf)))(
                snap.record(b, p, slot, plans_bf)))
            pp = params
            for slot in range(m):
                pp = jax.tree_util.tree_map(
                    lambda p: (p + (0.03 * jnp.asarray(
                        rng.normal(size=p.shape), jnp.float32)
                    ).astype(p.dtype)), pp)
                bufs_bf, grams_bf = upd_bf(grams_bf, bufs_bf, pp, slot)
            b = bufs_bf["seg0"]["attn"]["wqkv"]
            assert b.dtype == jnp.bfloat16
            oracle = dmd_mod.gram_matrix(b, anchor=cfg_bf.anchor,
                                         stack_dims=1, upcast=False)
            scale = max(float(jnp.max(jnp.abs(oracle))), 1.0)
            e_bf = float(jnp.max(jnp.abs(
                grams_bf["seg0"]["attn"]["wqkv"] - oracle))) / scale
            assert e_bf < 3e-2, e_bf
            print("BF16_STREAM_ERR", f"{e_bf:.2e}")

            # combine from the shard_map route == the dot_general oracle
            errc = 0.0
            for key, pl in ((("wqkv",), plans["wqkv"]),
                            (("seg0", "attn", "wqkv"),
                             plans["seg0"]["attn"]["wqkv"])):
                b = bufs
                for k in key:
                    b = b[k]
                cshape = pl.stack_shape + (m,)
                c = jnp.asarray(rng.normal(size=cshape), jnp.float32)
                w = jax.jit(lambda b, c, pl=pl: sharded.combine(b, c, pl))(
                    b, c)
                w_ref = dmd_mod.combine_snapshots(
                    b, c, stack_dims=pl.stack_dims)
                errc = max(errc, float(jnp.max(jnp.abs(w - w_ref)))
                           / max(float(jnp.max(jnp.abs(w_ref))), 1.0))
            assert errc < 1e-5, errc
            print("COMBINE_ERR", f"{errc:.2e}")

            # HLO audit: no all-gather of a buffer-sized operand anywhere in
            # the lowered update_grams (the psum'd row pass is all-reduce
            # O(stack*m), never a gather of the O(m*n) buffer)
            hlo = upd_jit.lower(grams, bufs, params, 2).compile().as_text()
            max_ag = max_allgather_bytes(hlo)
            smallest_buf = min(
                4 * b.size for b in jax.tree_util.tree_leaves(bufs))
            assert max_ag < smallest_buf, (max_ag, smallest_buf)
            print("AG_MAX_BYTES", max_ag, "SMALLEST_BUF", smallest_buf)
    finally:
        ops.set_backend(None)
    print("SHARDED_KERNELS_OK")


def run_arena_sharded():
    """Sharded arena buckets (core/arena.py, DESIGN.md §7) on an 8-device
    mesh: leaves sharded over the SAME contracted-dim axes bucket together,
    the bucket's (m, N) ring buffer is lane-sharded, the segmented kernels
    run per shard under shard_map with one O(n_sys*m)/O(n_sys*m^2) psum,
    and the whole route matches the per-leaf (arena=False) oracle. Also
    audits the lowered record+update HLO for buffer-sized all-gathers
    (there must be none — lane sharding keeps every pass local)."""
    import dataclasses as _dc
    from jax.sharding import NamedSharding
    from repro.core import DMDAccelerator, arena as arena_mod, leafplan

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    m = 5
    cfg = DMDConfig(m=m, s=8, tol=1e-3, anchor="first", warmup_steps=0,
                    cooldown_steps=0)
    rng = np.random.default_rng(0)

    def mk(shape, dtype=jnp.float32):
        return jnp.asarray(rng.normal(size=shape), dtype)

    params = {
        "wqkv": mk((64, 32)),                    # ("data", "model"): fsdp+tp
        "A_log": mk((32,)),                      # ("model",): tp vector
        "w_gate": mk((64, 32)),                  # same axes as wqkv
        "seg0": {"attn": {"wqkv": mk((6, 64, 32))}},   # stacked, sharded
        "bias": mk((40,)),                       # replicated -> local bucket
    }
    stack_dims = {"wqkv": 0, "A_log": 0, "w_gate": 0, "bias": 0,
                  "seg0": {"attn": {"wqkv": 1}}}

    with set_mesh(mesh):
        acc = DMDAccelerator(cfg, mesh=mesh, stack_dims=stack_dims)
        plans = acc.plans_for(params)
        table = acc.arena_for(params)
        keys = sorted(table)
        # fsdp+tp leaves share one lane-sharded bucket; the tp vector and
        # the replicated vector land in their own sharding classes
        lane_axes = {k: table[k].lane_axes for k in keys}
        assert ("data", "model") in lane_axes.values(), lane_axes
        assert ("model",) in lane_axes.values(), lane_axes
        assert () in lane_axes.values(), lane_axes
        dm_key = next(k for k, v in lane_axes.items() if v == ("data",
                                                               "model"))
        assert {s.path for s in table[dm_key].segments} >= {
            "/wqkv", "/w_gate", "/seg0/attn/wqkv"}, table[dm_key].segments

        place = lambda t, specs: jax.tree_util.tree_map(
            lambda l, s: jax.device_put(l, NamedSharding(mesh, s)), t, specs)
        params = place(params, jax.tree_util.tree_map(
            lambda pl: pl.param_spec, plans, is_leaf=leafplan.is_plan_leaf))

        def run(acc_):
            bufs = acc_.init(params)
            grams = acc_.init_grams(bufs)
            rec = jax.jit(lambda b, g, p, t: acc_.record(b, p, t, g))
            p = params
            rr = np.random.default_rng(1)
            for t in range(m):
                p = jax.tree_util.tree_map(
                    lambda x: x + (0.03 * jnp.asarray(
                        rr.normal(size=x.shape), jnp.float32)
                    ).astype(x.dtype), p)
                bufs, grams = rec(bufs, grams, p,
                                  jnp.asarray(acc_.slots(t)))
            newp, _ = acc_.apply(p, bufs, grams=grams, step=m - 1)
            return bufs, grams, newp, rec

        bufs, grams, newp, rec = run(acc)
        assert arena_mod.is_arena_state(bufs)
        acc_o = DMDAccelerator(_dc.replace(cfg, arena=False), mesh=mesh,
                               stack_dims=stack_dims)
        bufs_o, grams_o, newp_o, _ = run(acc_o)

        from repro.train.state import TrainState
        lw = acc.state_leafwise(TrainState(
            params, None, jnp.zeros((), jnp.int32), bufs, grams))
        err_b = err_g = err_p = 0.0
        flat_lw = jax.tree_util.tree_flatten_with_path(
            lw.dmd_buffers, is_leaf=lambda x: x is None)[0]
        flat_o = {jax.tree_util.keystr(kp): l
                  for kp, l in jax.tree_util.tree_flatten_with_path(
                      bufs_o, is_leaf=lambda x: x is None)[0]}
        for kp, l in flat_lw:
            o = flat_o[jax.tree_util.keystr(kp)]
            err_b = max(err_b, float(jnp.max(jnp.abs(l - o))))
        for x, y in zip(jax.tree_util.tree_leaves(lw.dmd_gram),
                        jax.tree_util.tree_leaves(grams_o)):
            err_g = max(err_g, float(jnp.max(jnp.abs(x - y)))
                        / max(float(jnp.max(jnp.abs(y))), 1.0))
        for x, y in zip(jax.tree_util.tree_leaves(newp),
                        jax.tree_util.tree_leaves(newp_o)):
            err_p = max(err_p, float(jnp.max(jnp.abs(x - y)))
                        / max(float(jnp.max(jnp.abs(y))), 1.0))
        print("ARENA_BUF_ERR", f"{err_b:.2e}")
        print("ARENA_GRAM_ERR", f"{err_g:.2e}")
        print("ARENA_JUMP_ERR", f"{err_p:.2e}")
        assert err_b == 0.0                     # recording is a pure copy
        assert err_g < 1e-5
        assert err_p < 1e-3                     # eigensolve noise floor

        # HLO audit: the packed record+update emits no buffer-sized
        # all-gather (lane sharding keeps the data passes local)
        hlo = jax.jit(lambda b, g, p, t: acc.record(b, p, t, g)).lower(
            bufs, grams, params,
            jnp.asarray(acc.slots(2))).compile().as_text()
        max_ag = max_allgather_bytes(hlo)
        smallest = min(4 * b.size
                       for b in jax.tree_util.tree_leaves(bufs["__arena__"]))
        assert max_ag < smallest, (max_ag, smallest)
        print("ARENA_AG_MAX_BYTES", max_ag, "SMALLEST_BUF", smallest)

        # Bucket scope on LANE-SHARDED buckets (DESIGN.md §9): the same
        # trajectory under scope="bucket" — each lane-sharded bucket's
        # (1, m, m) Gram must equal the leaf-scope Gram stack summed over
        # systems (the segment-sum identity, with the shard-local partial
        # rows psum'd over the SAME lane axes), and the jump stays finite.
        # The record+update HLO keeps the no-buffer-sized-all-gather ban.
        acc_bk = DMDAccelerator(_dc.replace(cfg, scope="bucket"), mesh=mesh,
                                stack_dims=stack_dims)
        bufs_bk, grams_bk, newp_bk, _ = run(acc_bk)
        err_bg = 0.0
        for key in sorted(table):
            b_ = table[key]
            gb = grams_bk["__arena__"][key]
            gl = grams["__arena__"][key]
            if b_.bucket_scoped("bucket"):
                assert gb.shape == (1, m, m), (key, gb.shape)
                ref = jnp.sum(gl, axis=0, keepdims=True)
            else:                       # sys-sharded carve-out: per-system
                assert gb.shape == gl.shape, (key, gb.shape)
                ref = gl
            err_bg = max(err_bg, float(jnp.max(jnp.abs(gb - ref)))
                         / max(float(jnp.max(jnp.abs(ref))), 1.0))
        for x in jax.tree_util.tree_leaves(newp_bk):
            assert bool(jnp.isfinite(x).all())
        hlo_bk = jax.jit(
            lambda b, g, p, t: acc_bk.record(b, p, t, g)).lower(
            bufs_bk, grams_bk, params,
            jnp.asarray(acc_bk.slots(2))).compile().as_text()
        max_ag_bk = max_allgather_bytes(hlo_bk)
        assert max_ag_bk < smallest, (max_ag_bk, smallest)
        print("ARENA_BUCKET_GRAM_ERR", f"{err_bg:.2e}")
        print("ARENA_BUCKET_AG_MAX_BYTES", max_ag_bk,
              "SMALLEST_BUF", smallest)
    print("ARENA_SHARDED_OK")


def _ctrl_line(state, acc):
    """Canonical render of the controller + schedule phase at a step:
    printed by ctrl_save and ctrl_restore, compared VERBATIM by the test —
    counters, s_eff/relax_eff (full fp32 precision), and the per-group slot
    vector re-derived from the step (cooldown/window phase)."""
    c = state.controller
    step = int(state.step)
    slots = acc.slots(step)
    fields = [
        "step=" + str(step),
        "acc=" + ",".join(map(str, np.asarray(c.accepts))),
        "scl=" + ",".join(map(str, np.asarray(c.scaled))),
        "rej=" + ",".join(map(str, np.asarray(c.rejects))),
        "stk=" + ",".join(map(str, np.asarray(c.streak))),
        "s=" + ",".join(f"{v:.9e}" for v in np.asarray(c.s_eff)),
        "rx=" + ",".join(f"{v:.9e}" for v in np.asarray(c.relax_eff)),
        "ema=" + ",".join(f"{v:.9e}" for v in np.asarray(c.gain_ema)),
        "slots=" + ",".join(map(str, slots)),
    ]
    return "CTRL " + " ".join(fields)


def run_controller_preempt(mode, argv):
    """SIGTERM fault injection with the controller on, across a mesh remap
    (ISSUE 4 satellite): save on (2,2) — preempted on the exact jump step
    or mid-window — then restore on (4,2) and verify counters, s_eff, and
    the cooldown phase resume bit-exactly, and the remaining gated jumps
    still fire. Schedule (m=4, warmup=2, cooldown=0): jumps at 5, 9, 13."""
    import signal
    ckpt = argv[0]
    eval_batch = batch_for_step(0, 10 ** 6, 8, 16, 128)   # step-independent
    if mode == "ctrl_save":
        variant = argv[1]
        preempt_at = 5 if variant == "jump" else 7
        acfg = small_acfg(controller=True)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(100))

            def bomb(step, metrics):
                if step == preempt_at:
                    signal.raise_signal(signal.SIGTERM)
            state = trainer.fit(batches, steps=14, on_metrics=bomb,
                                eval_batch=eval_batch)
            assert int(state.step) == preempt_at + 1
            print(_ctrl_line(state, trainer.acc))
        print("SAVED", preempt_at + 1)
    else:
        expected_step = int(argv[1])
        acfg = small_acfg(controller=True)
        mesh = jax.make_mesh((4, 2), ("data", "model"))   # REMAPPED topology
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            state = trainer.restore()
            assert state is not None and int(state.step) == expected_step
            print(_ctrl_line(state, trainer.acc))
            # the cooldown/window phase is pure step arithmetic: pin it
            g = trainer.acc.groups[0]
            assert trainer.acc.slots(expected_step)[0] == g.slot(
                expected_step)
            # finish the run: the remaining jump steps must gate + count
            jumps_before = sum(
                bool(trainer.acc.apply_groups(t))
                for t in range(expected_step))
            jumps_total = sum(bool(trainer.acc.apply_groups(t))
                              for t in range(14))
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(expected_step, 100))
            final = trainer.fit(batches, steps=14, state=state,
                                eval_batch=eval_batch)
            c = final.controller
            assert int(np.asarray(c.accepts).sum()
                       + np.asarray(c.scaled).sum()
                       + np.asarray(c.rejects).sum()) == jumps_total, \
                (jumps_before, jumps_total)
            assert np.isfinite(checksum(final.params))
        print("CTRL_OK", jumps_total)


def main():
    mode = sys.argv[1]
    if mode == "train":
        shape = sys.argv[2]
        if shape == "2x4":
            losses, cs = run_train((2, 4), ("data", "model"))
        elif shape == "1x1":
            losses, cs = run_train((1, 1), ("data", "model"))
        elif shape == "2x2x2":
            losses, cs = run_train((2, 2, 2), ("pod", "data", "model"))
        print("LOSSES", " ".join(f"{l:.6f}" for l in losses))
        print("CHECKSUM", f"{cs:.4f}")
    elif mode == "gram":
        from repro.core.dmd import gram_matrix
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        S = rng.normal(size=(6, 64, 32)).astype(np.float32)
        from jax.sharding import NamedSharding, PartitionSpec as P
        sharded = jax.device_put(
            S, NamedSharding(mesh, P(None, "data", "model")))
        with set_mesh(mesh):
            g = jax.jit(lambda s: gram_matrix(s, anchor="first"))(sharded)
        flat = S.reshape(6, -1)
        flat = flat - flat[:1]
        ref = flat @ flat.T
        err = float(np.abs(np.asarray(g) - ref).max() / np.abs(ref).max())
        print("GRAM_ERR", f"{err:.2e}")
        assert err < 1e-5
    elif mode == "gradsync":
        from repro.distributed.gradsync import int8_psum_grads
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
        with set_mesh(mesh):
            synced = jax.jit(lambda t: int8_psum_grads(t, mesh))(g)
        # replicated input: mean over pods == input (up to int8 quantization)
        err = float(jnp.max(jnp.abs(synced["w"] - g["w"])))
        scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
        print("GRADSYNC_ERR", f"{err:.4f}", "TOL", f"{scale:.4f}")
        assert err <= scale * 1.01 + 1e-6
    elif mode == "elastic_save":
        ckpt = sys.argv[2]
        acfg = small_acfg()
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(100))
            state = trainer.fit(batches, steps=2)
            trainer.save(state, 2)
        print("SAVED", checksum(state.params))
    elif mode == "gram_save":
        ckpt, variant = sys.argv[2], sys.argv[3]
        hetero = variant == "hetero"
        acfg = small_acfg(hetero)          # m=4 (+ norms m=3), warmup=2
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(100))
            # single group: steps 0..5 record slots 0..3, jump at step 5 —
            # the window completes, so the streaming Gram == oracle.
            # hetero: run through step 13, where BOTH groups' windows
            # complete (m=4 jumps at 5,9,13; m=3 at 4,7,10,13).
            steps = 14 if hetero else 6
            state = trainer.fit(batches, steps=steps)
            assert state.dmd_gram is not None
            if variant == "zero":
                state = state._replace(dmd_gram=None)   # pre-streaming format
            trainer.save(state, steps)
        print("SAVED", checksum(state.params))
    elif mode == "gram_restore":
        ckpt = sys.argv[2]
        hetero = len(sys.argv) > 3 and sys.argv[3] == "hetero"
        from repro.core import dmd as dmd_mod
        from repro.core.leafplan import is_plan_leaf
        acfg = small_acfg(hetero)
        mesh = jax.make_mesh((4, 2), ("data", "model"))   # REMAPPED topology
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            state = trainer.restore()
            assert state is not None
            assert int(state.step) == (14 if hetero else 6)
            # the run carries packed arenas (DESIGN.md §7); audit the
            # equivalent per-leaf view
            state = trainer.acc.state_leafwise(state)
            plans = trainer.acc.plans_for(state.params)
            n_checked = 0
            n_small = 0

            def chk(plan, buf, g):
                nonlocal n_checked, n_small
                if plan is None or buf is None:
                    return None
                assert g is not None
                # heterogeneous windows restore heterogeneous shapes
                assert buf.shape[0] == plan.m and g.shape[-1] == plan.m
                n_small += plan.m != acfg.dmd.m
                oracle = dmd_mod.gram_matrix(buf, anchor=acfg.dmd.anchor,
                                             stack_dims=plan.stack_dims)
                np.testing.assert_allclose(np.asarray(g), np.asarray(oracle),
                                           rtol=1e-4, atol=1e-4)
                n_checked += 1
                return None
            jax.tree_util.tree_map(chk, plans, state.dmd_buffers,
                                   state.dmd_gram, is_leaf=is_plan_leaf)
            assert n_checked > 0
            if hetero:
                assert n_small > 0          # the m=3 group really exists
        print("GRAMS_OK", n_checked)
    elif mode == "resident_save":
        from repro.core import arena as arena_mod
        from repro.train.step import resident_enabled, state_resident
        ckpt = sys.argv[2]
        acfg = small_acfg()                       # adam: resident-capable
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            assert resident_enabled(trainer.acc, acfg)
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(100))
            state = trainer.fit(batches, steps=6)
            # fit de-residentizes at return; the bucket table it trained
            # on contains at least one SHARDED bucket
            assert not arena_mod.is_arena_state(state.params)
            table = trainer.acc.arena_for(state.params)
            assert any(b.lane_axes or b.sys_axes for b in table.values()), \
                {k: (b.lane_axes, b.sys_axes) for k, b in table.items()}
            # the resident layout really was live: re-residentize and pin
            # bucket count + bit-exact round trip through the wrapper
            res = state_resident(trainer.acc, acfg, state)
            assert arena_mod.is_arena_state(res.params)
            trainer.save(state, 6)
        print("SAVED", f"{checksum(state.params):.6f}")
    elif mode == "resident_restore":
        from repro.core import arena as arena_mod
        from repro.train.step import state_resident
        ckpt = sys.argv[2]
        acfg = small_acfg()
        mesh = jax.make_mesh((4, 2), ("data", "model"))   # REMAPPED topology
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            state = trainer.restore()
            assert state is not None and int(state.step) == 6
            print("RESTORED", f"{checksum(state.params):.6f}")
            # the new mesh's bucket table also carries a sharded bucket,
            # and the restored per-leaf state re-residentizes into it
            table = trainer.acc.arena_for(state.params)
            assert any(b.lane_axes or b.sys_axes for b in table.values())
            res = state_resident(trainer.acc, acfg, state)
            assert arena_mod.is_arena_state(res.params)
            assert arena_mod.is_arena_state(res.opt_state.m)
            back = trainer.acc.state_leafwise(res)
            assert abs(checksum(back.params)
                       - checksum(state.params)) < 1e-3
            # one more fit step runs ON the resident layout
            batches = (batch_for_step(0, s, 8, 16, acfg.model.vocab_size)
                       for s in range(6, 100))
            final = trainer.fit(batches, steps=7, state=state)
            assert int(final.step) == 7
            assert np.isfinite(checksum(final.params))
        print("RESIDENT_OK", f"{checksum(final.params):.6f}")
    elif mode in ("ctrl_save", "ctrl_restore"):
        run_controller_preempt(mode, sys.argv[2:])
    elif mode == "sharded_kernels":
        run_sharded_kernels()
    elif mode == "arena_sharded":
        run_arena_sharded()
    elif mode == "elastic_restore":
        ckpt = sys.argv[2]
        acfg = small_acfg()
        mesh = jax.make_mesh((4, 2), ("data", "model"))   # DIFFERENT topology
        model = LanguageModel(acfg.model, head_tp=True, chunk_k=16)
        with mesh_context(mesh):
            trainer = Trainer(model, acfg, mesh=mesh, checkpoint_dir=ckpt)
            state = trainer.restore()
            assert state is not None and int(state.step) == 2
            batch = batch_for_step(0, 2, 8, 16, acfg.model.vocab_size)
            state, m = trainer.train_step(state, batch,
                                          jnp.asarray(2, jnp.int32))
            assert np.isfinite(float(m["loss"]))
        print("RESTORED", checksum(state.params), f"{float(m['loss']):.6f}")
    else:
        raise SystemExit(f"unknown mode {mode}")


if __name__ == "__main__":
    main()
