"""pallas_shard_map kernel route vs the dot_general oracle (8 virtual host
devices, interpret-mode Pallas bodies inside shard_map).

The heavy lifting happens in the subprocess worker (dist_worker.py mode
``sharded_kernels``): streaming Gram + combine equality across window wraps
for fsdp- and tp-sharded leaves (incl. bf16 / gram_upcast=False storage), and
the lowered-HLO audit that `update_grams` emits NO all-gather of a
buffer-sized operand — the whole point of the shard_map route (DESIGN.md
§3.4). Since ISSUE 6 the worker's HLO scan is the shared audit primitive
(repro.audit.hlo.max_allgather_bytes — the same byte accounting the
collective-budget pass applies in ``python -m repro.audit``).
"""
import os
import subprocess
import sys
from pathlib import Path

WORKER = str(Path(__file__).parent / "dist_worker.py")


def run_worker(*args, ndev="8", timeout=600):
    env = dict(os.environ)
    env["TEST_NDEV"] = ndev
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, WORKER, *args],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"worker failed:\n{out.stdout}\n{out.stderr}"
    return out.stdout


def test_sharded_arena_buckets_match_perleaf_oracle():
    """Packed arenas for SHARDED buckets (DESIGN.md §7): leaves sharded
    over the same contracted-dim axes pack into one lane-sharded (m, N)
    ring buffer; buffers/Grams/jump match the per-leaf route and the
    record+update HLO contains no buffer-sized all-gather."""
    out = run_worker("arena_sharded")
    assert "ARENA_SHARDED_OK" in out
    assert float(next(l.split()[1] for l in out.splitlines()
                      if l.startswith("ARENA_BUF_ERR"))) == 0.0
    assert float(next(l.split()[1] for l in out.splitlines()
                      if l.startswith("ARENA_GRAM_ERR"))) < 1e-5
    ag = next(l.split() for l in out.splitlines()
              if l.startswith("ARENA_AG_MAX_BYTES"))
    assert int(ag[1]) < int(ag[3])
    # bucket scope on the same sharded build (DESIGN.md §9): segment-sum
    # Gram identity across shards + the all-gather ban
    assert float(next(l.split()[1] for l in out.splitlines()
                      if l.startswith("ARENA_BUCKET_GRAM_ERR"))) < 1e-5
    bag = next(l.split() for l in out.splitlines()
               if l.startswith("ARENA_BUCKET_AG_MAX_BYTES"))
    assert int(bag[1]) < int(bag[3])


def test_shard_map_kernels_match_oracle_and_no_allgather():
    out = run_worker("sharded_kernels")
    assert "SHARDED_KERNELS_OK" in out
    # fp32 path is near-exact; bf16 storage within bf16 rounding
    stream_err = float(next(l.split()[1] for l in out.splitlines()
                            if l.startswith("STREAM_ERR")))
    assert stream_err < 1e-5
    bf_err = float(next(l.split()[1] for l in out.splitlines()
                        if l.startswith("BF16_STREAM_ERR")))
    assert bf_err < 3e-2
    combine_err = float(next(l.split()[1] for l in out.splitlines()
                             if l.startswith("COMBINE_ERR")))
    assert combine_err < 1e-5
    ag = next(l.split() for l in out.splitlines()
              if l.startswith("AG_MAX_BYTES"))
    assert int(ag[1]) < int(ag[3])        # no buffer-sized all-gather
