"""Serving-path donation audit (ISSUE 6 satellite): the KV caches are the
serving loop's hot donated state — ``launch/serve.py::serve_fns`` jits
prefill and decode_step with ``donate_argnums=(2,)`` so the per-token
cache update is in-place. A dropped donation doubles the serving HBM
footprint and shows up as cache-shaped copy ops in the compiled HLO.

Both programs route through the SAME shared passes the train programs use
(repro.audit.passes::donation_alias / collective_budget via an adhoc
context) — no standalone HLO-regex logic here either."""
import jax
import jax.numpy as jnp

from repro.audit.passes import collective_budget, donation_alias
from repro.audit.targets import adhoc_context, serve_target
from repro.configs import get_config, reduced
from repro.launch.serve import serve_fns
from repro.models.transformer import LanguageModel


def _setup(donate=True):
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    # scan_layers=False is the serving build (launch/serve.py): a layer
    # scan double-buffers the stacked cache by construction and would
    # read as cache-shaped copies here.
    model = LanguageModel(mc, head_tp=False, chunk_k=16, scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    B, P, N = 2, 8, 4
    caches = model.init_cache(B, P + N)
    fns = serve_fns(model, donate=donate)
    prompt = {"tokens": jnp.zeros((B, P), jnp.int32)}
    tok = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    return acfg, fns, params, caches, prompt, tok


def _targets(donate=True):
    acfg, fns, params, caches, prompt, tok = _setup(donate)
    return acfg, caches, {
        "prefill": serve_target("prefill", fns["prefill"],
                                (params, prompt, caches), caches,
                                donated=donate),
        "decode_step": serve_target("decode_step", fns["decode_step"],
                                    (params, tok, caches), caches,
                                    donated=donate),
    }


def test_serve_programs_donate_kv_caches():
    """Every cache leaf aliases input->output in BOTH serving programs,
    and zero KV-cache-shaped copies survive compilation."""
    acfg, caches, targets = _targets()
    ctx = adhoc_context("tinyllama-1.1b-reduced", acfg, targets)
    violations, info = donation_alias(ctx)
    errors = [v for v in violations if v.severity == "error"]
    assert errors == [], errors
    n_cache = len(jax.tree_util.tree_leaves(caches))
    assert n_cache > 0
    for name in ("prefill", "decode_step"):
        assert info[f"{name}.alias_count"] >= n_cache, (name, info)
        assert info[f"{name}.dmd_copies"] == 0, (name, info)


def test_serve_programs_within_collective_budget():
    """Single-host serving lowers no collectives at all — in particular no
    cache-sized all-gather (the reshard-to-replicated failure mode)."""
    acfg, _, targets = _targets()
    ctx = adhoc_context("tinyllama-1.1b-reduced", acfg, targets)
    violations, info = collective_budget(ctx)
    errors = [v for v in violations if v.severity == "error"]
    assert errors == [], errors
    assert info["prefill.collectives"] == {}
    assert info["decode_step.collectives"] == {}


def test_undonated_serve_build_is_caught():
    """Mutation check: serve_fns(donate=False) must flip the pass."""
    acfg, _, targets = _targets(donate=False)
    ctx = adhoc_context("tinyllama-1.1b-reduced", acfg, targets)
    violations, _ = donation_alias(ctx)
    errors = [v for v in violations if v.severity == "error"]
    assert errors, "donation pass failed to flag undonated serving jits"


# ---------------------------------------------------------------------------
# serve-compile (ISSUE 10): the engine's program registry stays within the
# analytic bucket ceiling with zero steady-state recompiles, and its decode
# keeps the slot-stacked caches donated copy-free.
# ---------------------------------------------------------------------------

def _engine_ctx(mutate=None):
    import dataclasses

    from repro.audit.passes import serve_compile
    from repro.serve.audit import attach_serve

    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                 n_heads=2, n_kv_heads=1, head_dim=16)
    ctx = adhoc_context("tinyllama-1.1b-reduced",
                        dataclasses.replace(acfg, model=mc), {})
    attach_serve(ctx, mutate=mutate)
    return serve_compile(ctx), ctx


def test_serve_compile_pass_clean():
    """Warmup covers every bucket; a steady wave with different in-bucket
    lengths compiles NOTHING new, and the decode program keeps every
    slot-stacked cache leaf aliased with zero cache-shaped copies."""
    (violations, info), ctx = _engine_ctx()
    errors = [v for v in violations if v.severity == "error"]
    assert errors == [], errors
    assert info["steady_compiles"] == 0
    assert info["n_programs"] <= info["max_programs"]
    assert info["decode_cache_copies"] == 0
    assert info["dropped"] == 0
    assert "serve_decode" in ctx.targets
    # the engine decode honours the same donation contract serve_fns pins
    violations, dinfo = donation_alias(ctx)
    assert [v for v in violations if v.severity == "error"] == []
    assert dinfo["serve_decode.dmd_copies"] == 0


def test_force_recompile_mutation_bites():
    """Exact-length prompt "buckets" (the force-recompile mutation seam)
    must trip BOTH pins: compiles after warmup and a registry above the
    analytic bucket ceiling."""
    from repro.audit.mutations import get as get_mutation

    m = get_mutation("force-recompile")
    assert m.serve and m.expect_fail == "serve-compile"
    (violations, info), _ = _engine_ctx(mutate=m.serve_cfg)
    details = " ".join(v.detail for v in violations)
    assert info["steady_compiles"] > 0
    assert "AFTER warmup" in details
    assert "bucket ceiling" in details
