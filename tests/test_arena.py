"""Packed leaf arenas (core/arena.py + kernels/arena.py, DESIGN.md §7).

Covers the ISSUE 5 satellite edge cases: leaf sizes that are not 128
multiples, a single-leaf bucket, an excluded-group-only config (empty
arena), a bf16 bucket under gram_upcast=False, and arena-vs-per-leaf
bit-exactness across full jump cycles (assert_array_equal on
integer-valued trajectories, where every fp32 sum is exact and any
segmentation/offset/masking slip would change bits).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import DMDConfig
from repro.core import DMDAccelerator
from repro.core import arena as arena_mod
from repro.core.schedule import DMDGroupRule
from repro.kernels import arena as ka
from repro.kernels import ops


def _cfg(**kw):
    kw.setdefault("m", 4)
    kw.setdefault("s", 5)
    kw.setdefault("warmup_steps", 0)
    kw.setdefault("cooldown_steps", 0)
    kw.setdefault("tol", 1e-6)
    return DMDConfig(**kw)


def _int_params(rng, sizes):
    """Integer-valued fp32 leaves (exact in any summation order)."""
    return {k: jnp.asarray(rng.integers(-8, 9, size=s), jnp.float32)
            for k, s in sizes.items()}


# ---------------------------------------------------------------------------
# Bucketing / layout
# ---------------------------------------------------------------------------

def test_bucket_layout_alignment_and_offsets():
    """Every segment starts on a block_n boundary, segments are disjoint
    and in pytree order, and the block->system table walks them in order."""
    rng = np.random.default_rng(0)
    params = _int_params(rng, {"a": (7,), "b": (10, 13), "c": (333,),
                               "d": (128,)})
    acc = DMDAccelerator(_cfg())
    table = acc.arena_for(params)
    assert len(table) == 1
    b = next(iter(table.values()))
    assert b.block_n % 128 == 0
    lane = 0
    for seg in b.segments:
        assert seg.lane_start == lane
        assert seg.lane_start % b.block_n == 0
        assert seg.seg_lanes % b.block_n == 0
        assert seg.seg_lanes >= seg.flat_local
        lane += seg.lanes
    assert b.n_lanes == lane
    bs = b.block_sys()
    assert bs.shape == (b.n_lanes // b.block_n,)
    assert (np.diff(bs) >= 0).all()          # sorted: systems consecutive
    assert bs[-1] == b.n_sys - 1


def test_single_leaf_bucket():
    params = {"w": jnp.arange(200, dtype=jnp.float32).reshape(8, 25)}
    acc = DMDAccelerator(_cfg())
    table = acc.arena_for(params)
    assert len(table) == 1
    (b,) = table.values()
    assert b.n_sys == 1 and len(b.segments) == 1
    bufs = acc.init(params)
    assert arena_mod.is_arena_state(bufs)
    assert all(l is None for l in jax.tree_util.tree_leaves(
        bufs["leaf"], is_leaf=lambda x: x is None))


def test_excluded_only_config_has_empty_arena():
    """Every leaf excluded by a group rule -> no buckets, no buffers; the
    state is NOT the arena wrapper (nothing to pack)."""
    cfg = _cfg(groups=(DMDGroupRule(name="none", path_regex=".",
                                    exclude=True),))
    params = {"w": jnp.ones((16, 16)), "b": jnp.ones((16,))}
    acc = DMDAccelerator(cfg)
    assert acc.arena_for(params) == {}
    bufs = acc.init(params)
    assert not arena_mod.is_arena_state(bufs)
    assert all(l is None for l in jax.tree_util.tree_leaves(
        bufs, is_leaf=lambda x: x is None))


def test_dot_general_route_keeps_per_leaf():
    cfg = _cfg(kernel_route="dot_general")
    params = {"w": jnp.ones((16, 16))}
    acc = DMDAccelerator(cfg)
    assert acc.arena_for(params) == {}
    assert not arena_mod.is_arena_state(acc.init(params))


def test_two_groups_two_buckets():
    cfg = _cfg(groups=(DMDGroupRule(name="vecs", max_ndim=1, m=3,
                                    phase=1),))
    params = {"w": jnp.ones((16, 16)), "b": jnp.ones((48,))}
    acc = DMDAccelerator(cfg)
    table = acc.arena_for(params)
    assert len(table) == 2
    ms = sorted(b.m for b in table.values())
    assert ms == [3, 4]


# ---------------------------------------------------------------------------
# Kernel contract: segmented Pallas (interpret) vs reference vs per-leaf
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("anchor_first", [False, True])
def test_segmented_kernels_match_reference(anchor_first):
    rng = np.random.default_rng(1)
    m, block_n = 5, 128
    sizes = [7, 130, 333, 128]                 # none except 128 lane-aligned
    segs = [-(-s // block_n) * block_n for s in sizes]
    n = sum(segs)
    x = np.zeros((m, n), np.float32)
    q = np.zeros((n,), np.float32)
    lane = 0
    block_sys = []
    for i, (s, p) in enumerate(zip(sizes, segs)):
        x[:, lane:lane + s] = rng.normal(size=(m, s))
        q[lane:lane + s] = rng.normal(size=s)
        block_sys += [i] * (p // block_n)
        lane += p
    x, q = jnp.asarray(x), jnp.asarray(q)
    bs = np.asarray(block_sys, np.int32)
    # the kernels take BLOCK-MAJOR inputs; the flat x/q stay around for the
    # per-leaf oracle slices below (blocking is a pure relayout)
    xb = x.reshape(m, n // block_n, block_n).transpose(1, 0, 2)
    qb = q.reshape(n // block_n, block_n)

    ref_row = ka.gram_row_ref(xb, qb, bs, 4, anchor_first=anchor_first,
                              block_n=block_n)
    pal_row = ka.gram_row_pallas(xb, qb, bs, 4, anchor_first=anchor_first,
                                 block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_row), np.asarray(ref_row),
                               rtol=1e-6, atol=1e-5)

    ref_g = ka.gram_ref(xb, bs, 4, anchor_first=anchor_first,
                        block_n=block_n)
    pal_g = ka.gram_pallas(xb, bs, 4, anchor_first=anchor_first,
                           block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_g), np.asarray(ref_g),
                               rtol=1e-6, atol=1e-5)

    c = jnp.asarray(rng.normal(size=(4, m)), jnp.float32)
    ref_c = ka.combine_ref(xb, c, bs, block_n=block_n)
    pal_c = ka.combine_pallas(xb, c, bs, block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(pal_c), np.asarray(ref_c),
                               rtol=1e-6, atol=1e-5)

    # per-leaf oracle: each segment's row/gram/combine equals the flat
    # kernels applied to that segment alone
    lane = 0
    for i, (s, p) in enumerate(zip(sizes, segs)):
        xs = x[:, lane:lane + s]
        qs = q[lane:lane + s]
        np.testing.assert_allclose(
            np.asarray(ref_row[i]),
            np.asarray(ops.gram_row(xs, qs, anchor_first=anchor_first,
                                    interpret=None)), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            np.asarray(ref_c[lane:lane + s]),
            np.asarray(ops.combine(xs, c[i], interpret=None)),
            rtol=1e-5, atol=1e-4)
        lane += p


# ---------------------------------------------------------------------------
# Arena vs per-leaf: bit-exact full jump cycles on integer trajectories
# ---------------------------------------------------------------------------

def _run_cycles(cfg, params, deltas, steps, quantize=False):
    """record/update/jump `steps` steps through the accelerator API;
    returns (params_after, buffers, grams). ``quantize`` rounds the params
    after every jump so SNAPSHOT VALUES stay integer across windows — the
    exactness precondition of the bit-exact route contract (the streaming
    row kernel contracts the RAW ring buffer via the part-anchor identity,
    so integer per-step drifts alone no longer guarantee exact sums once a
    jump emits full-mantissa params)."""
    acc = DMDAccelerator(cfg)
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    p = params
    for t in range(steps):
        p = jax.tree_util.tree_map(lambda x, d: x + d, p, deltas)
        bufs, grams = acc.record(bufs, p, acc.slots(t), grams)
        if acc.should_apply(t):
            p, _ = acc.apply(p, bufs, grams=grams, step=t)
            if quantize:
                p = jax.tree_util.tree_map(jnp.round, p)
    return acc, p, bufs, grams


def test_arena_vs_perleaf_bitexact_full_cycles():
    """Two full jump cycles (window wrap + second jump) on integer-valued
    trajectories: with ``quantize`` keeping the post-jump params integer,
    every snapshot VALUE is integer, all Gram sums are exact in any
    summation order (including the arena's part-anchor identity on the raw
    buffer), and the two routes must agree BIT-EXACTLY on every leaf — any
    offset/masking/segmentation slip changes bits. Covers sizes off the
    128-lane grid and a stacked leaf. The unquantized cross-route bound
    lives in the float-trajectory test below."""
    rng = np.random.default_rng(7)
    sizes = {"a": (7,), "b": (10, 13), "c": (333,), "d": (2, 5, 6)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg()
    acc_a, p_arena, bufs_a, grams_a = _run_cycles(cfg, params, deltas, 9,
                                                  quantize=True)
    cfg_o = dataclasses.replace(cfg, arena=False)
    acc_o, p_leaf, bufs_o, grams_o = _run_cycles(cfg_o, params, deltas, 9,
                                                 quantize=True)

    for k in sizes:
        np.testing.assert_array_equal(np.asarray(p_arena[k]),
                                      np.asarray(p_leaf[k]), err_msg=k)

    # buffers and Grams agree bit-exactly through the leaf-wise view
    from repro.train.state import TrainState
    st = TrainState(p_arena, None, jnp.zeros((), jnp.int32), bufs_a, grams_a)
    lw = acc_a.state_leafwise(st)
    flat_o = {k: v for k, v in zip(sizes, jax.tree_util.tree_leaves(bufs_o))}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(lw.dmd_buffers)[0]:
        k = jax.tree_util.keystr(kp).strip("[']").split("'")[0]
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(flat_o[k]), err_msg=k)
    flat_g = {k: v for k, v in zip(sizes, jax.tree_util.tree_leaves(grams_o))}
    for kp, leaf in jax.tree_util.tree_flatten_with_path(lw.dmd_gram)[0]:
        k = jax.tree_util.keystr(kp).strip("[']").split("'")[0]
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(flat_g[k]), err_msg=k)


def test_arena_vs_perleaf_close_on_float_trajectories():
    """Real-valued trajectories: the DATA passes (buffers bit-exact, Grams
    at fp32 summation-order noise) must agree tightly. The post-jump params
    only get a loose bound: with the fp32 noise floor unmasked (tol below
    it) the eigensolve legitimately amplifies last-ulp Gram differences on
    a near-rank-deficient window — the integer-trajectory test above is
    the exact-equality guarantee; this one pins the passes feeding it."""
    rng = np.random.default_rng(3)
    sizes = {"a": (40,), "b": (10, 13), "c": (333,)}
    params = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    deltas = {k: jnp.asarray(0.01 * rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg(tol=1e-3)                      # mask the fp32 noise tail
    acc_a, p_arena, bufs_a, grams_a = _run_cycles(cfg, params, deltas, 4)
    acc_o, p_leaf, bufs_o, grams_o = _run_cycles(
        dataclasses.replace(cfg, arena=False), params, deltas, 4)

    from repro.train.state import TrainState
    lw = acc_a.state_leafwise(TrainState(
        p_arena, None, jnp.zeros((), jnp.int32), bufs_a, grams_a))
    order = sorted(sizes)
    for k, b_a, b_o, g_a, g_o in zip(
            order, jax.tree_util.tree_leaves(lw.dmd_buffers),
            jax.tree_util.tree_leaves(bufs_o),
            jax.tree_util.tree_leaves(lw.dmd_gram),
            jax.tree_util.tree_leaves(grams_o)):
        np.testing.assert_array_equal(np.asarray(b_a), np.asarray(b_o),
                                      err_msg=k)
        np.testing.assert_allclose(np.asarray(g_a), np.asarray(g_o),
                                   rtol=1e-5, atol=1e-4, err_msg=k)
    for k in sizes:
        np.testing.assert_allclose(np.asarray(p_arena[k]),
                                   np.asarray(p_leaf[k]),
                                   rtol=0.05, atol=0.05, err_msg=k)


def test_bf16_bucket_gram_upcast_false():
    """bf16 snapshot storage + gram_upcast=False: the bucket stores bf16,
    Grams still come out fp32, and — the route contract — the arena agrees
    with the per-leaf route AT THE SAME CONFIG (both kernel routes upcast
    per block/tile in fp32; regression: an early arena ref downcast the
    combine coefficients to bf16, a 1.8% divergence this same-config
    oracle catches and the fp32-route comparison below never would).
    tol=1e-3 masks the fp32-ordering noise tail of the eigensolve."""
    rng = np.random.default_rng(5)
    sizes = {"w": (24, 9), "v": (130,)}
    params = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    deltas = {k: jnp.asarray(0.05 * rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg(snapshot_dtype="bfloat16", gram_upcast=False, anchor="first",
               tol=1e-3)
    acc, p_b, bufs, grams = _run_cycles(cfg, params, deltas, 4)
    for key, buf in bufs["__arena__"].items():
        assert buf.dtype == jnp.bfloat16, key
    for key, g in grams["__arena__"].items():
        assert g.dtype == jnp.float32, key
    # same-config per-leaf oracle: buffers bit-exact, params at fp32 noise
    acc_o, p_o, bufs_o, grams_o = _run_cycles(
        dataclasses.replace(cfg, arena=False), params, deltas, 4)
    from repro.train.state import TrainState
    lw = acc.state_leafwise(TrainState(
        p_b, None, jnp.zeros((), jnp.int32), bufs, grams))
    for k, b_o in zip(sorted(sizes), jax.tree_util.tree_leaves(bufs_o)):
        np.testing.assert_array_equal(
            np.asarray(lw.dmd_buffers[k].astype(jnp.float32)),
            np.asarray(b_o.astype(jnp.float32)), err_msg=k)
    for k in sizes:
        np.testing.assert_allclose(np.asarray(p_b[k]), np.asarray(p_o[k]),
                                   rtol=2e-3, atol=2e-3, err_msg=k)
    # and the bf16 storage stays close to the fp32-storage route
    _, p_f, _, _ = _run_cycles(
        dataclasses.replace(cfg, snapshot_dtype="float32", gram_upcast=True),
        params, deltas, 4)
    for k in sizes:
        np.testing.assert_allclose(np.asarray(p_b[k]), np.asarray(p_f[k]),
                                   rtol=0.15, atol=0.05, err_msg=k)


# ---------------------------------------------------------------------------
# Streaming vs recompute + leaf-wise checkpoint interop
# ---------------------------------------------------------------------------

def test_arena_streaming_gram_equals_recompute():
    """The per-bucket streaming rows reproduce the one-launch full Gram
    recompute at the window-complete point (the §2 invariant, arena'd)."""
    rng = np.random.default_rng(11)
    sizes = {"a": (40,), "b": (10, 13)}
    params = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg(anchor="first")
    acc = DMDAccelerator(cfg)
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    p = params
    for t in range(4):
        p = jax.tree_util.tree_map(
            lambda x: x + 0.01 * jnp.ones_like(x) * (t + 1), p)
        bufs, grams = acc.record(bufs, p, acc.slots(t), grams)
    table = acc.arena_for(params)
    for key, b in table.items():
        full = ka.gram(bufs["__arena__"][key], b.block_sys(), b.n_sys,
                       anchor_first=True, block_n=b.block_n)
        np.testing.assert_allclose(np.asarray(grams["__arena__"][key]),
                                   np.asarray(full), rtol=1e-5, atol=1e-5)


def test_checkpoint_interop_arena_and_perleaf(tmp_path):
    """A checkpoint written by an arena run restores bit-exactly into a
    per-leaf run and vice versa: the on-disk format is the leaf-wise
    layout either way."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.state import TrainState

    rng = np.random.default_rng(13)
    sizes = {"a": (40,), "b": (10, 13), "c": (333,)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg()
    acc_a, p_a, bufs_a, grams_a = _run_cycles(cfg, params, deltas, 6)
    st_a = TrainState(p_a, None, jnp.asarray(6, jnp.int32), bufs_a, grams_a)
    save_checkpoint(tmp_path / "arena", acc_a.state_leafwise(st_a), 6)

    # restore into a per-leaf run: template = per-leaf layout
    cfg_o = dataclasses.replace(cfg, arena=False)
    acc_o = DMDAccelerator(cfg_o)
    bufs_t = acc_o.init(params)
    st_t = TrainState(params, None, jnp.asarray(0, jnp.int32), bufs_t,
                      acc_o.init_grams(bufs_t))
    back = restore_checkpoint(tmp_path / "arena", st_t)
    oracle = acc_a.state_leafwise(st_a)
    for x, y in zip(jax.tree_util.tree_leaves(back.dmd_buffers),
                    jax.tree_util.tree_leaves(oracle.dmd_buffers)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # and back the other way: per-leaf checkpoint -> arena run
    save_checkpoint(tmp_path / "leaf", back, 6)
    acc_b = DMDAccelerator(cfg)
    bufs_b = acc_b.init(params)
    st_b = TrainState(params, None, jnp.asarray(0, jnp.int32), bufs_b,
                      acc_b.init_grams(bufs_b))
    restored = restore_checkpoint(tmp_path / "leaf",
                                  acc_b.state_leafwise(st_b))
    packed = acc_b.state_arenaize(restored)
    assert arena_mod.is_arena_state(packed.dmd_buffers)
    for key in bufs_a["__arena__"]:
        np.testing.assert_array_equal(
            np.asarray(packed.dmd_buffers["__arena__"][key]),
            np.asarray(bufs_a["__arena__"][key]), err_msg=key)
        np.testing.assert_array_equal(
            np.asarray(packed.dmd_gram["__arena__"][key]),
            np.asarray(grams_a["__arena__"][key]), err_msg=key)


def test_jump_tree_requires_bucket_table_for_packed_buffers():
    """jump_tree on arena-packed buffers without the bucket table must
    raise, not silently leave every packed leaf unjumped."""
    from repro.core.accelerator import _none_like, jump_tree
    params = {"w": jnp.ones((16, 16))}
    acc = DMDAccelerator(_cfg())
    bufs = acc.init(params)
    plans = acc.plans_for(params)
    with pytest.raises(ValueError, match="bucket table"):
        jump_tree(acc.cfg, plans, params, bufs, _none_like(bufs), 1.0)


def test_state_specs_requires_bucket_table_for_packed_state():
    """Passing an arena-layout state to state_specs without the bucket
    table must raise, not silently mark lane-sharded ring buffers
    replicated (a multi-GiB-per-device cliff on real meshes)."""
    from repro.launch.inputs import state_specs
    from repro.train.state import TrainState
    params = {"w": jnp.ones((16, 16))}
    acc = DMDAccelerator(_cfg())
    bufs = acc.init(params)
    st = TrainState(params, None, jnp.zeros((), jnp.int32), bufs,
                    acc.init_grams(bufs))
    with pytest.raises(ValueError, match="bucket table"):
        state_specs(st, None)
    specs = state_specs(st, None, plans=acc.plans_for(params),
                        arena=acc.arena_for(params))
    assert jax.tree_util.tree_leaves(specs)


def test_plan_table_shows_arena_columns():
    params = {"w": jnp.ones((16, 16)), "b": jnp.ones((48,))}
    acc = DMDAccelerator(_cfg())
    table = acc.plan_table(params)
    assert "arena" in table and "g0-float32" in table
    acc2 = DMDAccelerator(_cfg(arena=False))
    table2 = acc2.plan_table(params)
    assert "g0-float32" not in table2


# ---------------------------------------------------------------------------
# Bucket-scope Koopman DMD (ISSUE 8 tentpole, DESIGN.md §9)
# ---------------------------------------------------------------------------

def test_bucket_scope_single_system_bucket_bitexact_leaf():
    """A single-segment single-system bucket is the degenerate case where
    the two scopes are the SAME program: the collapsed block->system table
    is already all zeros and n_sys is already 1, so bucket scope must be
    bit-exact with leaf scope — params, buffers, and Grams."""
    rng = np.random.default_rng(23)
    sizes = {"w": (8, 25)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg()
    acc_l, p_l, bufs_l, grams_l = _run_cycles(cfg, params, deltas, 9,
                                              quantize=True)
    acc_b, p_b, bufs_b, grams_b = _run_cycles(
        dataclasses.replace(cfg, scope="bucket"), params, deltas, 9,
        quantize=True)
    (b,) = acc_b.arena_for(params).values()
    assert b.bucket_scoped("bucket") and b.n_sys == 1
    np.testing.assert_array_equal(np.asarray(p_b["w"]), np.asarray(p_l["w"]))
    for key in bufs_l["__arena__"]:
        np.testing.assert_array_equal(
            np.asarray(bufs_b["__arena__"][key]),
            np.asarray(bufs_l["__arena__"][key]), err_msg=key)
        np.testing.assert_array_equal(
            np.asarray(grams_b["__arena__"][key]),
            np.asarray(grams_l["__arena__"][key]), err_msg=key)


def test_bucket_scope_gram_is_segment_sum_across_wraps():
    """The streaming bucket Gram under scope="bucket" IS the segment-sum
    of the per-segment Grams (pad lanes are zero and all segments share
    one slot schedule, DESIGN.md §9): after the ring wraps, the (1, m, m)
    bucket Gram equals both (a) the leaf-scope run's Gram stack summed
    over systems and (b) a dot_general oracle on the anchored leaf-wise
    snapshots. Integer trajectories make every fp32 sum exact in any
    association order, so (a) is bit-exact. 8 steps with m=4 wraps the
    ring once and ends at a window-complete point, where the streaming
    Gram equals the full anchored recompute (the §2 invariant) and the
    oracle (b) is well-defined."""
    rng = np.random.default_rng(29)
    sizes = {"a": (7,), "b": (10, 13), "c": (333,), "d": (2, 5, 6)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg()
    acc_l, p_l, bufs_l, grams_l = _run_cycles(cfg, params, deltas, 8,
                                              quantize=True)
    acc_b, p_b, bufs_b, grams_b = _run_cycles(
        dataclasses.replace(cfg, scope="bucket"), params, deltas, 8,
        quantize=True)

    (key,) = grams_b["__arena__"]
    gb = np.asarray(grams_b["__arena__"][key])
    assert gb.shape == (1, cfg.m, cfg.m)
    # (a) segment-sum of the leaf-scope Gram stack, bit-exact
    gl = np.asarray(grams_l["__arena__"][key]).sum(axis=0, keepdims=True)
    np.testing.assert_array_equal(gb, gl)

    # (b) dot_general oracle over the anchored leaf-wise snapshots: the
    # concatenated-bucket-state Gram. Buffers are scope-independent, so
    # the bucket run's leaf-wise view supplies the snapshot matrix.
    from repro.train.state import TrainState
    lw = acc_b.state_leafwise(TrainState(
        p_b, None, jnp.zeros((), jnp.int32), bufs_b, grams_b))
    rows = []
    for k in sorted(sizes):
        x = np.asarray(lw.dmd_buffers[k], np.float32)
        x = x.reshape(cfg.m, -1)                  # (m, flat leaf)
        rows.append(x - x[0])                     # anchor="first"
    d = np.concatenate(rows, axis=1)              # (m, sum of lanes)
    np.testing.assert_array_equal(gb[0], d @ d.T)


def test_bucket_scope_bf16_gram_upcast_false_segment_sum():
    """bf16 snapshot storage with gram_upcast=False under bucket scope:
    the (1, m, m) Gram stays fp32 and still equals the segment-sum of the
    leaf-scope Gram stack (same f32-accumulating block kernels, the only
    change is the collapsed segment reduction) at fp32 ordering noise."""
    rng = np.random.default_rng(31)
    sizes = {"w": (24, 9), "v": (130,)}
    params = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    deltas = {k: jnp.asarray(0.05 * rng.normal(size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg = _cfg(snapshot_dtype="bfloat16", gram_upcast=False, anchor="first",
               tol=1e-3)
    _, _, bufs_l, grams_l = _run_cycles(cfg, params, deltas, 4)
    acc_b, p_b, bufs_b, grams_b = _run_cycles(
        dataclasses.replace(cfg, scope="bucket"), params, deltas, 4)
    for key, g in grams_b["__arena__"].items():
        assert g.dtype == jnp.float32, key
        assert g.shape[0] == 1, key
        gl = np.asarray(grams_l["__arena__"][key], np.float32)
        np.testing.assert_allclose(np.asarray(g)[0], gl.sum(axis=0),
                                   rtol=1e-5, atol=1e-4, err_msg=key)
    for k in sizes:
        assert np.isfinite(np.asarray(p_b[k])).all(), k


def test_bucket_scope_tables_and_spectrum():
    """plan_table / layout_table grow a scope column, the bucket's solve
    count collapses to 1, and spectrum_table renders one Koopman
    eigenvalue row per bucket from the shared operator's Gram."""
    rng = np.random.default_rng(37)
    sizes = {"w": (16, 16), "b": (48,)}
    params = _int_params(rng, sizes)
    cfg = _cfg(scope="bucket")
    acc = DMDAccelerator(cfg)
    table = acc.arena_for(params)
    (b,) = table.values()
    assert b.gram_lead("bucket") == 1 and b.gram_lead("leaf") == b.n_sys
    assert (b.scope_block_sys("bucket") == 0).all()
    (rec,) = arena_mod.layout_table(table, scope="bucket")
    assert rec["scope"] == "bucket" and rec["n_solve"] == 1
    (rec_l,) = arena_mod.layout_table(table)          # default: leaf
    assert rec_l["scope"] == "leaf" and rec_l["n_solve"] == b.n_sys
    assert "scope" in acc.plan_table(params)

    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    _, _, bufs, grams = _run_cycles(cfg, params, deltas, 4)
    spec = acc.spectrum_table(bufs, grams)
    assert "|lam|max" in spec and "decay/step" in spec
    # leaf scope renders the SAME bucket-summed diagnostic (comparable)
    acc_l = DMDAccelerator(_cfg())
    acc_l.plans_for(params)
    _, _, bufs_l, grams_l = _run_cycles(_cfg(), params, deltas, 4)
    spec_l = acc_l.spectrum_table(bufs_l, grams_l)
    assert "|lam|max" in spec_l

    with pytest.raises(ValueError):
        DMDAccelerator(_cfg()).spectrum_table(bufs)


def test_bucket_scope_unknown_scope_raises():
    params = {"w": jnp.ones((16, 16))}
    acc = DMDAccelerator(_cfg())
    (b,) = acc.arena_for(params).values()
    with pytest.raises(ValueError, match="scope"):
        b.bucket_scoped("global")


def test_checkpoint_interop_bucket_and_leaf_scope(tmp_path):
    """Checkpoints stay leaf-wise on disk in BOTH scopes (DESIGN.md §9):
    a bucket-scope run's checkpoint restores bit-exactly into a leaf-scope
    run (per-leaf Grams recomputed from the buffers at save), and a
    leaf-scope checkpoint restores into a bucket-scope run (leaf Grams
    segment-summed at arenaize) — integer trajectories, exact sums. Runs
    to a window-complete point (8 steps, m=4): the bucket-scope save
    RECOMPUTES the leaf-wise Grams from the buffers, which matches the
    streaming Gram exactly there (mid-window the streaming rows carry the
    previous window's products and the Trainer rebuilds Grams on restore
    anyway — snapshots.recompute_grams)."""
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.train.state import TrainState

    rng = np.random.default_rng(41)
    sizes = {"a": (40,), "b": (10, 13), "c": (333,)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    cfg_b = _cfg(scope="bucket")
    cfg_l = _cfg()
    acc_b, p_b, bufs_b, grams_b = _run_cycles(cfg_b, params, deltas, 8,
                                              quantize=True)
    acc_l, p_l, bufs_l, grams_l = _run_cycles(cfg_l, params, deltas, 8,
                                              quantize=True)

    # bucket-scope save -> leaf-scope restore: the leaf-wise Grams on disk
    # must equal the leaf-scope run's (buffers are scope-independent and
    # the integer sums are exact)
    st_b = TrainState(p_b, None, jnp.asarray(8, jnp.int32), bufs_b, grams_b)
    save_checkpoint(tmp_path / "bucket", acc_b.state_leafwise(st_b), 8)
    acc_t = DMDAccelerator(cfg_l)
    bufs_t = acc_t.init(params)
    st_t = TrainState(params, None, jnp.asarray(0, jnp.int32), bufs_t,
                      acc_t.init_grams(bufs_t))
    back = restore_checkpoint(tmp_path / "bucket",
                              acc_t.state_leafwise(st_t))
    packed = acc_t.state_arenaize(back)
    for key in grams_l["__arena__"]:
        np.testing.assert_array_equal(
            np.asarray(packed.dmd_gram["__arena__"][key]),
            np.asarray(grams_l["__arena__"][key]), err_msg=key)
        np.testing.assert_array_equal(
            np.asarray(packed.dmd_buffers["__arena__"][key]),
            np.asarray(bufs_l["__arena__"][key]), err_msg=key)

    # leaf-scope save -> bucket-scope restore: arenaize segment-sums the
    # leaf-wise Grams into the (1, m, m) bucket stack
    st_l = TrainState(p_l, None, jnp.asarray(8, jnp.int32), bufs_l, grams_l)
    save_checkpoint(tmp_path / "leaf", acc_l.state_leafwise(st_l), 8)
    acc_r = DMDAccelerator(cfg_b)
    bufs_r = acc_r.init(params)
    st_r = TrainState(params, None, jnp.asarray(0, jnp.int32), bufs_r,
                      acc_r.init_grams(bufs_r))
    rback = restore_checkpoint(tmp_path / "leaf",
                               acc_r.state_leafwise(st_r))
    rpacked = acc_r.state_arenaize(rback)
    for key in grams_b["__arena__"]:
        g = rpacked.dmd_gram["__arena__"][key]
        assert g.shape[0] == 1, key
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(grams_b["__arena__"][key]),
            err_msg=key)


def test_bucket_scope_sys_sharded_bucket_stays_per_system():
    """The carve-out: a system-sharded bucket (sys_axes nonempty) keeps
    per-system operators even under scope="bucket" — collapsing it would
    need a cross-shard psum over the sys axes the kernels never emit."""
    import numpy as _np
    from repro.distributed.sharding import set_rule_overrides

    class _FakeMesh:
        axis_names = ("data", "model")
        devices = _np.empty((2, 4))

    set_rule_overrides([(r"stacked", ("fsdp", None, "tp"))])
    try:
        cfg = _cfg(scope="bucket")
        params = {"stacked": jnp.ones((4, 64, 128)),
                  "w": jnp.ones((64, 128))}
        acc = DMDAccelerator(cfg, mesh=_FakeMesh(),
                             stack_dims={"stacked": 1, "w": 0})
        table = acc.arena_for(params)
        sys_b = [b for b in table.values() if b.sys_axes]
        lane_b = [b for b in table.values() if not b.sys_axes]
        assert sys_b and lane_b
        for b in sys_b:
            assert not b.bucket_scoped("bucket")
            assert b.gram_lead("bucket") == b.n_sys_global
            np.testing.assert_array_equal(b.scope_block_sys("bucket"),
                                          b.block_sys())
        for b in lane_b:
            assert b.bucket_scoped("bucket")
            assert b.gram_lead("bucket") == 1
    finally:
        set_rule_overrides(None)


# ---------------------------------------------------------------------------
# Eligibility (ISSUE 7 tentpole): mean-anchor and sharded-stack buckets
# ---------------------------------------------------------------------------

def _audit_arena(cfg, acc, params, mesh=None):
    """Run the shared arena-layout audit pass over one accelerator build."""
    import types
    from repro.audit.passes import arena_layout
    from repro.audit.targets import adhoc_context
    ctx = adhoc_context("test-arena", types.SimpleNamespace(dmd=cfg), {},
                        mesh=mesh, plans=acc.plans_for(params),
                        arena=acc.arena_for(params))
    violations, info = arena_layout(ctx)
    return [v for v in violations if v.severity == "error"], info


def test_mean_anchor_leaves_pack_and_match_perleaf():
    """anchor=mean leaves PACK (ISSUE 7): the full-recompute arena Gram
    kernel fuses the mean subtraction, so there is no per-leaf carve-out
    anymore (streaming stays structurally off — the anchor moves every
    record). The packed route must agree bit-exactly with the per-leaf
    route on integer trajectories, and the layout audit stays clean."""
    from repro.core import leafplan
    from repro.core.arena import arena_eligible, arena_paths

    cfg = _cfg(anchor="mean")
    rng = np.random.default_rng(17)
    sizes = {"w": (16, 16), "b": (48,)}
    params = _int_params(rng, sizes)
    deltas = {k: jnp.asarray(rng.integers(-2, 3, size=s), jnp.float32)
              for k, s in sizes.items()}
    acc = DMDAccelerator(cfg)
    assert not acc.streaming                     # mean: no one-pass row
    table = acc.arena_for(params)
    assert arena_paths(table) == frozenset({"/w", "/b"})
    for p in leafplan.plan_entries(acc.plans_for(params)):
        assert arena_eligible(p, cfg, None), p.path
    errors, info = _audit_arena(cfg, acc, params)
    assert errors == [], errors
    assert info["n_packed"] == 2 and info["n_leaves"] == 2

    acc_a, p_arena, _, _ = _run_cycles(cfg, params, deltas, 9)
    _, p_leaf, _, _ = _run_cycles(
        dataclasses.replace(cfg, arena=False), params, deltas, 9)
    for k in sizes:
        np.testing.assert_array_equal(np.asarray(p_arena[k]),
                                      np.asarray(p_leaf[k]), err_msg=k)


def test_sharded_stack_leaf_gets_single_segment_sys_bucket():
    """A leaf whose LEADING stack axis is device-sharded packs into its
    own single-segment bucket (ISSUE 7): each device owns whole systems
    (sys_axes), the Gram stack stays sharded over them, and shard-local
    accounting (n_sys vs n_sys_global) is consistent. A NON-leading
    sharded stack axis stays excluded (shard-major packing would
    interleave the global system order). The mesh here is structural
    (axis names + sizes are all the layout code reads)."""
    import numpy as _np
    from repro.core import leafplan
    from repro.core.arena import arena_eligible, arena_paths
    from repro.distributed.sharding import set_rule_overrides

    class _FakeMesh:
        axis_names = ("data", "model")
        devices = _np.empty((2, 4))

    mesh = _FakeMesh()
    set_rule_overrides([(r"stacked", ("fsdp", None, "tp"))])
    try:
        cfg = _cfg()
        params = {"stacked": jnp.ones((4, 64, 128)),
                  "w": jnp.ones((64, 128))}
        acc = DMDAccelerator(cfg, mesh=mesh,
                             stack_dims={"stacked": 1, "w": 0})
        table = acc.arena_for(params)
        packed = arena_paths(table)
        assert "/stacked" in packed              # leading-dim shard packs
        assert "/w" in packed
        plans = acc.plans_for(params)
        by_path = {p.path: p for p in leafplan.plan_entries(plans)}
        st = by_path["/stacked"]
        assert arena_eligible(st, cfg, mesh)
        assert st.param_spec[0] is not None      # the stack axis IS sharded
        sys_buckets = [b for b in table.values() if b.sys_axes]
        assert len(sys_buckets) == 1
        (b,) = sys_buckets
        assert len(b.segments) == 1              # own single-segment bucket
        assert b.sys_axes == ("data",) and b.sys_factor == 2
        assert b.segments[0].n_sys == 2          # shard-LOCAL systems (4/2)
        assert b.n_sys_global == 4
        assert b.gram_spec() == __import__("jax").sharding.PartitionSpec(
            "data", None, None)
        errors, info = _audit_arena(cfg, acc, params, mesh=mesh)
        assert errors == [], errors
        assert info["n_packed"] == 2
    finally:
        set_rule_overrides(None)

    # non-leading sharded stack dim: still excluded
    set_rule_overrides([(r"deep", (None, "fsdp", None, "tp"))])
    try:
        cfg = _cfg()
        params = {"deep": jnp.ones((3, 4, 16, 128))}
        acc = DMDAccelerator(cfg, mesh=mesh, stack_dims={"deep": 2})
        assert acc.arena_for(params) == {}
        (pl,) = leafplan.plan_entries(acc.plans_for(params))
        assert not arena_eligible(pl, cfg, mesh)
    finally:
        set_rule_overrides(None)
