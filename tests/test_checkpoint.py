"""Checkpointing: roundtrip, pruning, atomicity, bit-exact resume."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (latest_step, list_checkpoints,
                              restore_checkpoint, save_checkpoint)
from repro.train.state import TrainState


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"emb": jax.random.normal(k, (8, 4)),
              "blk": {"w": jax.random.normal(k, (4, 4)),
                      "b": jnp.zeros(4)}}
    opt = {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
           "v": jax.tree_util.tree_map(jnp.ones_like, params)}
    return TrainState(params, opt, jnp.asarray(7, jnp.int32), None)


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 7)
    back = restore_checkpoint(tmp_path, _state(seed=1))
    assert int(back.step) == 7
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(back.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_none_leaves_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 1)
    back = restore_checkpoint(tmp_path, st)
    assert back.dmd_buffers is None


def test_keep_prunes_old(tmp_path):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, st, s, keep=2)
    assert list_checkpoints(tmp_path) == [4, 5]
    assert latest_step(tmp_path) == 5


def test_no_partial_dirs_on_disk(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 3)
    entries = [p for p in os.listdir(tmp_path) if p.startswith(".tmp_")]
    assert entries == []


def test_restore_missing_returns_none(tmp_path):
    assert restore_checkpoint(tmp_path / "nothing", _state()) is None
