"""Checkpointing: roundtrip, pruning, atomicity, bit-exact resume — plus
SIGTERM fault injection through Trainer._install_preempt_handler with the
jump controller on (mid-window AND on the exact jump step): the saved-and-
resumed run must match an uninterrupted run bit-exactly, including the
controller's counters / s_eff / relax and the schedule's cooldown phase
(re-derived from the restored step index)."""
import os
import signal

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import (latest_step, list_checkpoints,
                              restore_checkpoint, save_checkpoint)
from repro.train.state import TrainState


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    params = {"emb": jax.random.normal(k, (8, 4)),
              "blk": {"w": jax.random.normal(k, (4, 4)),
                      "b": jnp.zeros(4)}}
    opt = {"m": jax.tree_util.tree_map(jnp.zeros_like, params),
           "v": jax.tree_util.tree_map(jnp.ones_like, params)}
    return TrainState(params, opt, jnp.asarray(7, jnp.int32), None)


def test_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 7)
    back = restore_checkpoint(tmp_path, _state(seed=1))
    assert int(back.step) == 7
    for a, b in zip(jax.tree_util.tree_leaves(st.params),
                    jax.tree_util.tree_leaves(back.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_none_leaves_roundtrip(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 1)
    back = restore_checkpoint(tmp_path, st)
    assert back.dmd_buffers is None


def test_keep_prunes_old(tmp_path):
    st = _state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(tmp_path, st, s, keep=2)
    assert list_checkpoints(tmp_path) == [4, 5]
    assert latest_step(tmp_path) == 5


def test_no_partial_dirs_on_disk(tmp_path):
    st = _state()
    save_checkpoint(tmp_path, st, 3)
    entries = [p for p in os.listdir(tmp_path) if p.startswith(".tmp_")]
    assert entries == []


def test_restore_missing_returns_none(tmp_path):
    assert restore_checkpoint(tmp_path / "nothing", _state()) is None


def test_controller_state_roundtrip(tmp_path):
    """ControllerState arrays ride in TrainState and round-trip; a
    pre-controller checkpoint (no controller leaves in the manifest)
    restores the template's FRESH controller instead of dying."""
    from repro.core import controller as C
    from repro.core.schedule import GroupSchedule
    g = (GroupSchedule(index=0, name="default", m=4, s=10, warmup_steps=0,
                       cooldown_steps=0, phase=0, relax=1.0, anneal=1.0),)
    ctrl = C.init_state(g)._replace(
        accepts=jnp.asarray([3], jnp.int32),
        s_eff=jnp.asarray([2.5], jnp.float32))
    st = _state()._replace(controller=ctrl)
    save_checkpoint(tmp_path, st, 5)
    back = restore_checkpoint(tmp_path, _state()._replace(
        controller=C.init_state(g)))
    assert int(back.controller.accepts[0]) == 3
    assert float(back.controller.s_eff[0]) == 2.5
    # pre-controller manifest -> template's fresh state survives
    save_checkpoint(tmp_path, _state(), 6)
    back2 = restore_checkpoint(tmp_path, _state()._replace(
        controller=C.init_state(g)))
    assert int(back2.controller.accepts[0]) == 0
    assert float(back2.controller.s_eff[0]) == 10.0


# ---------------------------------------------------------------------------
# SIGTERM fault injection (ISSUE 4 satellite): preemption mid-window and on
# the exact jump step, controller enabled.
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("preempt_at", [12, 15])   # mid-window / jump step
def test_sigterm_preempt_resumes_controller_bitexact(tmp_path, preempt_at):
    """Schedule (warmup 4, cooldown 2, m 4): jumps at 9, 15, 21. SIGTERM
    delivered during step 12 (mid-window) or step 15 (the exact jump step —
    the save then carries that jump's fresh gate outcome). The preempt
    handler saves at step+1 and exits; a new trainer resumes and must land
    bit-exactly on the uninterrupted run: params, moments, buffers, Grams,
    AND every controller field. The eval batch is pinned step-independent,
    so the gate decisions replay identically across the restore."""
    from test_trainer import _tiny_setup, _ctrl_cfg, _eval_batch_for
    from repro.data.tokens import synthetic_lm_batches
    steps = 24

    try:
        # uninterrupted reference
        tr_a, batches_a = _tiny_setup(dmd=True, controller=_ctrl_cfg())
        eval_batch = _eval_batch_for(tr_a)
        final_a = tr_a.fit(batches_a, steps=steps, eval_batch=eval_batch)

        # preempted run: SIGTERM lands inside on_metrics at `preempt_at`;
        # the handler flips the flag and fit checkpoints step+1 and breaks
        tr_b, batches_b = _tiny_setup(tmp_path, dmd=True,
                                      controller=_ctrl_cfg())

        def bomb(step, metrics):
            if step == preempt_at:
                signal.raise_signal(signal.SIGTERM)
        state_b = tr_b.fit(batches_b, steps=steps, on_metrics=bomb,
                           eval_batch=eval_batch)
        assert int(state_b.step) == preempt_at + 1
        assert latest_step(tmp_path) == preempt_at + 1

        # resume in a fresh trainer from the checkpoint
        tr_c, _ = _tiny_setup(tmp_path, dmd=True, controller=_ctrl_cfg())
        vocab = tr_c.model.cfg.vocab_size
        batches_c = synthetic_lm_batches(0, 4, 16, vocab,
                                         start_step=preempt_at + 1)
        final_c = tr_c.fit(batches_c, steps=steps, eval_batch=eval_batch)
    finally:
        signal.signal(signal.SIGTERM, signal.SIG_DFL)

    for name in ("params", "opt_state", "dmd_buffers", "dmd_gram",
                 "controller"):
        for x, y in zip(
                jax.tree_util.tree_leaves(getattr(final_a, name)),
                jax.tree_util.tree_leaves(getattr(final_c, name))):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                          err_msg=name)
