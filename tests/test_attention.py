"""Attention: blockwise core vs naive oracle; prefill/decode consistency."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.kernels.ref import flash_attention_ref
from repro.models.attention import (blockwise_attention, init_kv_cache,
                                    init_ring_cache)
from repro.models.transformer import LanguageModel


@pytest.mark.parametrize("causal,window,chunk", [
    (True, 0, 16), (True, 0, 64), (False, 0, 32), (True, 8, 16),
])
def test_blockwise_matches_naive(causal, window, chunk):
    rng = np.random.default_rng(0)
    B, S, H, K, d = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, d)), jnp.float32)
    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              chunk_k=chunk)
    kr = jnp.repeat(k, H // K, axis=2)
    vr = jnp.repeat(v, H // K, axis=2)
    ref = flash_attention_ref(q, kr, vr, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_consistency_with_forward():
    """prefill + N decode steps must equal the one-shot forward logits."""
    acfg = get_config("tinyllama-1.1b")
    mc = reduced(acfg.model, n_layers=2)
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 24
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                mc.vocab_size)

    logits_full, _ = model.forward(params, {"tokens": tokens})

    n_pre = 16
    caches = model.init_cache(B, S + 8)
    logits_pre, caches = model.prefill(params, {"tokens": tokens[:, :n_pre]},
                                       caches)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1]),
                               np.asarray(logits_full[:, n_pre - 1]),
                               atol=2e-2, rtol=2e-2)
    for t in range(n_pre, S):
        logits_t, caches = model.decode_step(
            params, {"tokens": tokens[:, t:t + 1]}, caches)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=2e-2, rtol=2e-2)


def test_ring_cache_decode_matches_full_cache():
    """Sliding-window decode via O(W) ring cache == full cache + window mask."""
    acfg = get_config("gemma3-27b")
    mc = reduced(acfg.model, n_layers=6, sliding_window=8)
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 20
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                mc.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": tokens})

    caches = model.init_cache(B, S + 4)     # local layers get W=8 ring caches
    n_pre = 12
    _, caches = model.prefill(params, {"tokens": tokens[:, :n_pre]}, caches)
    for t in range(n_pre, S):
        logits_t, caches = model.decode_step(
            params, {"tokens": tokens[:, t:t + 1]}, caches)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=3e-2, rtol=3e-2)


def test_kv_cache_append():
    cache = init_kv_cache(1, 8, 2, 4, jnp.float32)
    k = jnp.ones((1, 3, 2, 4))
    kc = jax.lax.dynamic_update_slice_in_dim(cache.k, k, cache.length, axis=1)
    assert float(kc[0, 2, 0, 0]) == 1.0 and float(kc[0, 3, 0, 0]) == 0.0


def test_ring_cache_positions():
    cache = init_ring_cache(1, 4, 2, 4, jnp.float32)
    assert cache.pos.shape == (4,)
    assert int(cache.pos[0]) == -1
