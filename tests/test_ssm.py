"""Mamba-2 SSD: chunked scan vs the naive per-step recurrence oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, reduced
from repro.models.ssm import ssd_chunked
from repro.models.transformer import LanguageModel


def naive_ssd(xh, dt, A, Bm, Cm, h0=None):
    """Token-by-token recurrence: h <- exp(dt A) h + dt B x; y = C h."""
    xh, dt, Bm, Cm = map(np.asarray, (xh, dt, Bm, Cm))
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    h = np.zeros((B, H, P, N)) if h0 is None else np.asarray(h0).copy()
    ys = []
    for t in range(S):
        dA = np.exp(dt[:, t, :] * np.asarray(A))            # (B,H)
        Bg = np.repeat(Bm[:, t], rep, axis=1) if rep > 1 else Bm[:, t]
        Cg = np.repeat(Cm[:, t], rep, axis=1) if rep > 1 else Cm[:, t]
        xdt = xh[:, t] * dt[:, t, :, None]                  # (B,H,P)
        h = h * dA[:, :, None, None] + np.einsum("bhs,bhp->bhps", Bg, xdt)
        ys.append(np.einsum("bhs,bhps->bhp", Cg, h))
    return np.stack(ys, axis=1), h


@pytest.mark.parametrize("S,chunk", [(32, 8), (32, 32), (64, 16)])
def test_ssd_chunked_matches_recurrence(S, chunk):
    rng = np.random.default_rng(0)
    B, H, P, G, N = 2, 4, 8, 1, 8
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.3, size=(B, S, H)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, 1.0, size=(H,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    y, h = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    y_ref, h_ref = naive_ssd(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), y_ref, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), h_ref, atol=2e-4, rtol=1e-3)


def test_ssd_carried_state():
    """Splitting a sequence across two ssd calls == one call."""
    rng = np.random.default_rng(1)
    B, S, H, P, G, N = 1, 32, 2, 4, 1, 4
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.05, 0.2, size=(B, S, H)), jnp.float32)
    A = jnp.asarray([-0.5, -1.0], jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, S, G, N)), jnp.float32)
    y_all, h_all = ssd_chunked(xh, dt, A, Bm, Cm, 8)
    y1, h1 = ssd_chunked(xh[:, :16], dt[:, :16], A, Bm[:, :16], Cm[:, :16], 8)
    y2, h2 = ssd_chunked(xh[:, 16:], dt[:, 16:], A, Bm[:, 16:], Cm[:, 16:],
                         8, h0=h1)
    np.testing.assert_allclose(np.asarray(y_all[:, 16:]), np.asarray(y2),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(h2), atol=1e-4,
                               rtol=1e-3)


def test_mamba_decode_matches_forward():
    """Per-token decode with SSMState tracks the full forward pass."""
    acfg = get_config("mamba2-2.7b")
    mc = reduced(acfg.model, n_layers=2)
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                mc.vocab_size)
    logits_full, _ = model.forward(params, {"tokens": tokens})

    caches = model.init_cache(B, S)
    n_pre = 8
    _, caches = model.prefill(params, {"tokens": tokens[:, :n_pre]}, caches)
    for t in range(n_pre, S):
        logits_t, caches = model.decode_step(
            params, {"tokens": tokens[:, t:t + 1]}, caches)
        np.testing.assert_allclose(np.asarray(logits_t[:, 0]),
                                   np.asarray(logits_full[:, t]),
                                   atol=3e-2, rtol=3e-2)
