"""Per-arch smoke tests (REQUIRED: reduced same-family config, one forward /
train step on CPU, shape + finiteness asserts) + full-config param counts."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models.transformer import (LanguageModel, cross_entropy,
                                      segment_plan)
from repro.optim import apply_updates, make_optimizer

ARCHS = list_archs()


def _smoke_batch(mc, B=2, S=32, key=jax.random.PRNGKey(0)):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, mc.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, axis=1)
    if mc.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, mc.encoder_seq_len, mc.d_model))
    if mc.mrope_sections:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None, :], (B, 3, S))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    acfg = get_config(arch)
    mc = reduced(acfg.model)
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(mc)

    logits, aux = jax.jit(model.forward)(params, batch)
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, mc.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    # one optimizer step decreases nothing catastrophic + stays finite
    opt = make_optimizer(dataclasses.replace(acfg.optimizer, name="adam",
                                             lr=1e-3, schedule="constant",
                                             warmup_steps=0))
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(lambda pp: model.loss(pp, batch)[0])(p)
        u, s = opt.update(grads, s, p, jnp.asarray(0))
        return apply_updates(p, u), s, loss

    params2, state, loss = step(params, state)
    assert bool(jnp.isfinite(loss))
    for leaf in jax.tree_util.tree_leaves(params2):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    acfg = get_config(arch)
    mc = reduced(acfg.model)
    model = LanguageModel(mc, head_tp=False, chunk_k=16)
    params = model.init(jax.random.PRNGKey(0))
    caches = model.init_cache(2, 64)
    batch = {"tokens": jnp.zeros((2, 1), jnp.int32)}
    if mc.mrope_sections:
        batch["positions"] = jnp.zeros((2, 3, 1), jnp.int32)
    logits, new_caches = jax.jit(model.decode_step)(params, batch, caches)
    assert logits.shape == (2, 1, mc.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


EXPECTED_PARAMS = {
    "minicpm-2b": (2.73e9, 0.05),        # 2.4B non-emb + tied 122k vocab emb
    "granite-20b": (20.3e9, 0.05),
    "gemma3-27b": (27.0e9, 0.05),
    "tinyllama-1.1b": (1.10e9, 0.05),
    "whisper-base": (88e6, 0.08),        # +16.8M pos_emb for decode_32k cells
    "qwen2-vl-7b": (7.6e9, 0.05),        # LM backbone of the 8.3B total
    "zamba2-2.7b": (2.34e9, 0.10),       # single shared block simplification
    "mamba2-2.7b": (2.70e9, 0.05),
    "llama4-maverick-400b-a17b": (400.7e9, 0.03),
    "qwen3-moe-30b-a3b": (30.5e9, 0.03),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    acfg = get_config(arch)
    model = LanguageModel(acfg.model)
    params = model.init(abstract=True)
    n = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(params))
    target, tol = EXPECTED_PARAMS[arch]
    assert abs(n - target) / target < tol, f"{arch}: {n/1e9:.2f}B"


def test_segment_plans():
    assert segment_plan(get_config("gemma3-27b").model)[0].kind == "gemma"
    plan = segment_plan(get_config("gemma3-27b").model)
    assert plan[0].count == 10 and plan[1].count == 2            # 62 layers
    plan = segment_plan(get_config("llama4-maverick-400b-a17b").model)
    assert plan == [("moe_pair", 24)] or (plan[0].kind, plan[0].count) == \
        ("moe_pair", 24)
    plan = segment_plan(get_config("zamba2-2.7b").model)
    assert plan[0].kind == "zamba" and plan[0].count == 9        # 54 = 9x6
    plan = segment_plan(get_config("whisper-base").model)
    assert [s.kind for s in plan] == ["enc", "dec"]


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 2, 8))
    labels = jnp.asarray([[1, 2]])
    ce = cross_entropy(logits, labels)
    np.testing.assert_allclose(float(ce), np.log(8), rtol=1e-5)


def test_vocab_padding():
    mc = get_config("minicpm-2b").model
    assert mc.padded_vocab % 16 == 0
    assert 0 <= mc.padded_vocab - mc.vocab_size < 16
