"""Jump-controller unit tests: state init, gate outcome math, per-group
adaptation, energy resolution, accelerator/plan-table integration
(core/controller.py, DESIGN.md §5). End-to-end gating lives in
tests/test_trainer.py; fault-injection in tests/test_checkpoint.py and
tests/dist_worker.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import DMDConfig, DMDControllerConfig
from repro.core import DMDAccelerator
from repro.core import controller as C
from repro.core import schedule as sched
from repro.core.schedule import DMDGroupRule


def _groups(**cfg_kw):
    cfg = DMDConfig(m=6, s=20, warmup_steps=0, cooldown_steps=0,
                    groups=(DMDGroupRule(name="small", max_ndim=1, m=4,
                                         s=8, phase=3),), **cfg_kw)
    return sched.resolve_groups(cfg), cfg


def test_init_state_caps_and_zeros():
    groups, _ = _groups()
    st = C.init_state(groups)
    np.testing.assert_array_equal(np.asarray(st.s_eff), [20.0, 8.0])
    np.testing.assert_array_equal(np.asarray(st.relax_eff), [1.0, 1.0])
    for f in (st.accepts, st.scaled, st.rejects, st.streak):
        np.testing.assert_array_equal(np.asarray(f), [0, 0])
    # donated TrainStates may not alias buffers: every field distinct
    ids = [id(l) for l in jax.tree_util.tree_leaves(st)]
    assert len(ids) == len(set(ids))


def test_init_state_abstract_allocates_nothing():
    groups, _ = _groups()
    st = C.init_state(groups, abstract=True)
    for leaf in jax.tree_util.tree_leaves(st):
        assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_gate_outcome_predicate():
    ok = C.gate_outcome(jnp.float32(1.0), jnp.float32(0.99), 0.0)
    assert bool(ok)
    assert not bool(C.gate_outcome(jnp.float32(1.0), jnp.float32(1.01), 0.0))
    # accept_tol widens the band
    assert bool(C.gate_outcome(jnp.float32(1.0), jnp.float32(1.01), 0.02))
    # non-finite candidates always fail
    assert not bool(C.gate_outcome(jnp.float32(1.0), jnp.float32(np.nan),
                                   0.0))
    assert not bool(C.gate_outcome(jnp.float32(1.0), jnp.float32(np.inf),
                                   0.0))
    # adversarial threshold: a negative tol below -1 is unsatisfiable for
    # positive losses (the forced-reject fixture in test_trainer.py)
    assert not bool(C.gate_outcome(jnp.float32(1.0), jnp.float32(1e-9),
                                   -1.0))


def test_update_accept_reject_scaled_semantics():
    groups, _ = _groups()
    ccfg = DMDControllerConfig(enabled=True, grow=1.5, shrink=0.5, s_min=2.0,
                               relax_floor=0.25, gain_ema=0.5)
    st = C.init_state(groups)

    # reject on group 0: counter, streak reset, s_eff shrinks; group 1 idle
    st = C.update_on_jump(st, (0,), jnp.int32(C.REJECT), jnp.float32(0.0),
                          ccfg, groups)
    assert int(st.rejects[0]) == 1 and int(st.rejects[1]) == 0
    assert float(st.s_eff[0]) == 10.0 and float(st.s_eff[1]) == 8.0

    # single full accept: streak 1, NO growth yet (growth needs consecutive)
    st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT), jnp.float32(0.1),
                          ccfg, groups)
    assert int(st.accepts[0]) == 1 and int(st.streak[0]) == 1
    assert float(st.s_eff[0]) == 10.0

    # second consecutive accept: multiplicative growth, capped at s later
    st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT), jnp.float32(0.1),
                          ccfg, groups)
    assert int(st.streak[0]) == 2
    assert float(st.s_eff[0]) == pytest.approx(15.0)
    for _ in range(6):
        st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT),
                              jnp.float32(0.1), ccfg, groups)
    assert float(st.s_eff[0]) == 20.0          # bounded by configured s

    # scale-back: halves relax_eff (floored), breaks the streak, counts
    st = C.update_on_jump(st, (0,), jnp.int32(C.SCALED), jnp.float32(0.02),
                          ccfg, groups)
    assert int(st.scaled[0]) == 1 and int(st.streak[0]) == 0
    assert float(st.relax_eff[0]) == 0.5
    st = C.update_on_jump(st, (0,), jnp.int32(C.SCALED), jnp.float32(0.0),
                          ccfg, groups)
    st = C.update_on_jump(st, (0,), jnp.int32(C.SCALED), jnp.float32(0.0),
                          ccfg, groups)
    assert float(st.relax_eff[0]) == 0.25      # floor

    # full accept recovers relax toward 1
    st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT), jnp.float32(0.1),
                          ccfg, groups)
    assert float(st.relax_eff[0]) == 0.5

    # shrink floor: rejects never push s_eff below s_min
    st2 = C.init_state(groups)
    for _ in range(10):
        st2 = C.update_on_jump(st2, (0,), jnp.int32(C.REJECT),
                               jnp.float32(0.0), ccfg, groups)
    assert float(st2.s_eff[0]) == 2.0

    # group 1 untouched throughout
    assert float(st.s_eff[1]) == 8.0 and float(st.relax_eff[1]) == 1.0
    assert int(st.accepts[1] + st.scaled[1] + st.rejects[1]) == 0


def test_gain_ema_update():
    groups, _ = _groups()
    ccfg = DMDControllerConfig(enabled=True, gain_ema=0.8)
    st = C.init_state(groups)
    st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT), jnp.float32(0.5),
                          ccfg, groups)
    assert float(st.gain_ema[0]) == pytest.approx(0.1)
    st = C.update_on_jump(st, (0,), jnp.int32(C.ACCEPT), jnp.float32(0.5),
                          ccfg, groups)
    assert float(st.gain_ema[0]) == pytest.approx(0.18)
    assert float(st.gain_ema[1]) == 0.0


def test_effective_s_rounds_and_clamps():
    groups, _ = _groups()
    ccfg = DMDControllerConfig(enabled=True, s_min=2.0)
    st = C.init_state(groups)._replace(
        s_eff=jnp.asarray([7.6, 0.3], jnp.float32))
    sv = C.effective_s(st, groups, ccfg)
    np.testing.assert_array_equal(np.asarray(sv), [8, 2])
    assert sv.dtype == jnp.int32
    # host-side audit agrees with the trace
    np.testing.assert_array_equal(
        sched.effective_s_array(groups, st.s_eff, s_floor=ccfg.s_min),
        np.asarray(sv))


def test_resolve_groups_energy_gating():
    """Energy targets resolve ONLY in controller mode (off -> 0.0 = tol
    mask, the bit-exact legacy path), with per-rule overrides."""
    off, _ = _groups()
    assert all(g.energy == 0.0 for g in off)
    cfg = DMDConfig(m=6, s=20, controller=DMDControllerConfig(
        enabled=True, energy=0.99),
        groups=(DMDGroupRule(name="small", max_ndim=1, energy=0.9),))
    on = sched.resolve_groups(cfg)
    assert on[0].energy == pytest.approx(0.99)
    assert on[1].energy == pytest.approx(0.9)
    # controller ON with a zero DEFAULT energy: a per-rule override must
    # still apply (regression: the gate used to key off energy_default > 0)
    mixed = sched.resolve_groups(DMDConfig(
        m=6, s=20, controller=DMDControllerConfig(enabled=True, energy=0.0),
        groups=(DMDGroupRule(name="small", max_ndim=1, energy=0.9),)))
    assert mixed[0].energy == 0.0
    assert mixed[1].energy == pytest.approx(0.9)
    with pytest.raises(ValueError, match="energy"):
        sched.resolve_groups(DMDConfig(
            m=6, controller=DMDControllerConfig(enabled=True, energy=1.5)))


def test_accelerator_controller_integration():
    cfg = DMDConfig(m=6, s=20, warmup_steps=0, cooldown_steps=0)
    acc = DMDAccelerator(cfg)
    assert not acc.controller_on and acc.init_controller() is None

    cfg_on = DMDConfig(m=6, s=20, warmup_steps=0, cooldown_steps=0,
                       controller=DMDControllerConfig(enabled=True))
    acc_on = DMDAccelerator(cfg_on)
    assert acc_on.controller_on
    st = acc_on.init_controller()
    assert isinstance(st, C.ControllerState)
    assert st.s_eff.shape == (acc_on.n_groups,)

    # plan_table exposes the per-group horizon and energy columns
    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    table_on = acc_on.plan_table(params)
    assert " s " in table_on.splitlines()[0] or "s" in \
        table_on.splitlines()[0].split()
    assert "0.995" in table_on                  # controller energy target
    table_off = DMDAccelerator(cfg).plan_table(params)
    assert "0.995" not in table_off             # tol mask rules when off


def test_summary_renders():
    groups, _ = _groups()
    st = C.init_state(groups)
    out = C.summary(st, groups)
    assert "default" in out and "small" in out and "s_eff" in out


# -- ISSUE 9: ridge knob, line-search level, meta-tuning ---------------------

def _ridge_groups(ridge=0.02, rule_ridge=0.07, **ctrl_kw):
    cfg = DMDConfig(m=6, s=20, warmup_steps=0, cooldown_steps=0,
                    controller=DMDControllerConfig(enabled=True, ridge=ridge,
                                                   **ctrl_kw),
                    groups=(DMDGroupRule(name="small", max_ndim=1, m=4,
                                         s=8, phase=3, ridge=rule_ridge),))
    return sched.resolve_groups(cfg), cfg


def test_init_state_ridge_eff_from_schedule():
    """ridge_eff starts at each group's resolved schedule ridge (controller
    default + per-rule override), and stays 0 when the controller is off."""
    groups, _ = _ridge_groups()
    st = C.init_state(groups)
    np.testing.assert_allclose(np.asarray(st.ridge_eff), [0.02, 0.07])
    off, _ = _groups()
    np.testing.assert_array_equal(np.asarray(C.init_state(off).ridge_eff),
                                  [0.0, 0.0])
    # abstract state grew the matching 8th leaf
    ab = C.init_state(groups, abstract=True)
    assert ab.ridge_eff.shape == (2,)


def test_update_on_jump_level_is_realized_shrinkage():
    """SCALED folds the WINNING line-search rung into relax_eff — not a
    hard-coded halving — and the default level reproduces the legacy 0.5."""
    groups, _ = _groups()
    ccfg = DMDControllerConfig(enabled=True, relax_floor=0.1)
    st = C.init_state(groups)
    st_q = C.update_on_jump(st, (0,), jnp.int32(C.SCALED), jnp.float32(0.0),
                            ccfg, groups, level=jnp.float32(0.25))
    assert float(st_q.relax_eff[0]) == pytest.approx(0.25)
    st_d = C.update_on_jump(st, (0,), jnp.int32(C.SCALED), jnp.float32(0.0),
                            ccfg, groups)
    assert float(st_d.relax_eff[0]) == pytest.approx(0.5)
    # floor still binds under a deep rung
    st_f = C.update_on_jump(st_q, (0,), jnp.int32(C.SCALED),
                            jnp.float32(0.0), ccfg, groups,
                            level=jnp.float32(0.25))
    assert float(st_f.relax_eff[0]) == pytest.approx(0.1)
    # ridge_eff rides through update_on_jump untouched
    np.testing.assert_array_equal(np.asarray(st_f.ridge_eff),
                                  np.asarray(st.ridge_eff))


def test_meta_update_sign_directions():
    """The sign-only EMA rule: g_relax > 0 (more jump hurts the gate loss)
    pulls relax toward the floor, g_relax < 0 toward 1; g_ridge < 0 (more
    shrinkage helps) pulls ridge toward ridge_max, g_ridge > 0 toward 0."""
    groups, _ = _ridge_groups(meta_lr=0.5, ridge_max=0.1, relax_floor=0.25)
    ccfg = DMDControllerConfig(enabled=True, meta_lr=0.5, ridge_max=0.1,
                               relax_floor=0.25, ridge=0.02)
    st = C.init_state(groups)._replace(
        relax_eff=jnp.asarray([0.8, 0.8], jnp.float32),
        ridge_eff=jnp.asarray([0.02, 0.02], jnp.float32))

    up = C.meta_update(st, (0,), jnp.asarray([1.0, 1.0], jnp.float32),
                       jnp.asarray([1.0, 1.0], jnp.float32), ccfg, groups)
    # relax: (1-lr)*0.8 + lr*0.25 ; ridge: (1-lr)*0.02 + lr*0.0
    assert float(up.relax_eff[0]) == pytest.approx(0.525)
    assert float(up.ridge_eff[0]) == pytest.approx(0.01)

    dn = C.meta_update(st, (0,), jnp.asarray([-1.0, -1.0], jnp.float32),
                       jnp.asarray([-1.0, -1.0], jnp.float32), ccfg, groups)
    # relax toward 1.0 ; ridge toward ridge_max
    assert float(dn.relax_eff[0]) == pytest.approx(0.9)
    assert float(dn.ridge_eff[0]) == pytest.approx(0.06)

    # non-jumped group 1 untouched in BOTH directions
    for out in (up, dn):
        assert float(out.relax_eff[1]) == pytest.approx(0.8)
        assert float(out.ridge_eff[1]) == pytest.approx(0.02)


def test_meta_update_finite_guard_and_clip():
    """Non-finite gradients (eigh's degenerate-eigenvalue JVP) leave the
    knobs untouched per-knob, and ridge never escapes [0, ridge_max]."""
    groups, _ = _ridge_groups(meta_lr=1.0, ridge_max=0.1)
    ccfg = DMDControllerConfig(enabled=True, meta_lr=1.0, ridge_max=0.1,
                               relax_floor=0.25, ridge=0.02)
    st = C.init_state(groups)._replace(
        relax_eff=jnp.asarray([0.8, 0.8], jnp.float32),
        ridge_eff=jnp.asarray([0.02, 0.02], jnp.float32))
    out = C.meta_update(st, (0, 1),
                        jnp.asarray([np.nan, -1.0], jnp.float32),
                        jnp.asarray([-1.0, np.inf], jnp.float32),
                        ccfg, groups)
    # group 0: relax grad NaN -> untouched; ridge grad fine -> ridge_max
    assert float(out.relax_eff[0]) == pytest.approx(0.8)
    assert float(out.ridge_eff[0]) == pytest.approx(0.1)
    # group 1: relax fine -> 1.0 (lr=1); ridge inf -> untouched
    assert float(out.relax_eff[1]) == pytest.approx(1.0)
    assert float(out.ridge_eff[1]) == pytest.approx(0.02)
    # clip: a huge starting ridge is pulled back inside the band
    st_hi = st._replace(ridge_eff=jnp.asarray([5.0, 5.0], jnp.float32))
    hi = C.meta_update(st_hi, (0,), jnp.asarray([0.0, 0.0], jnp.float32),
                       jnp.asarray([-1.0, -1.0], jnp.float32), ccfg, groups)
    assert float(hi.ridge_eff[0]) <= 0.1 + 1e-7


def test_summary_renders_ridge_column():
    groups, _ = _ridge_groups()
    out = C.summary(C.init_state(groups), groups)
    assert "ridge_eff" in out and "0.0700" in out
