"""End-to-end behaviour of the paper's system.

The headline claim: DMD-accelerated training reaches lower loss than plain
training at equal optimizer-step budget, on a slow smooth regression (the
paper's regime). Uses a reduced pollutant-style problem so it runs in
seconds on CPU.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import DMDConfig, OptimizerConfig
from repro.core import DMDAccelerator
from repro.models.mlp_net import init_mlp, mlp_forward, mse_loss
from repro.optim import apply_updates, make_optimizer


def _problem(seed=0, n=400, n_out=200):
    rng = np.random.default_rng(seed)
    X = rng.uniform(-1, 1, size=(n, 6)).astype(np.float32)
    A1 = rng.normal(size=(6, n_out)).astype(np.float32)
    A2 = rng.normal(size=(6, n_out)).astype(np.float32)
    Y = np.tanh(X @ A1) * np.exp(-0.5 * (X @ A2) ** 2)
    return jnp.asarray(X), jnp.asarray(Y.astype(np.float32))


def _train(dmd_cfg, steps=400, seed=0, reset_opt=True):
    X, Y = _problem()
    n_out = Y.shape[1]
    params = init_mlp(jax.random.PRNGKey(seed), (6, 32, 64, n_out))
    opt = make_optimizer(OptimizerConfig(name="adam", lr=2e-3))
    state = opt.init(params)
    acc = DMDAccelerator(dmd_cfg)
    bufs = acc.init(params)

    @jax.jit
    def step(p, s, t):
        loss, g = jax.value_and_grad(
            lambda pp: mse_loss(pp, X, Y))(p)
        u, s = opt.update(g, s, p, t)
        return apply_updates(p, u), s, loss

    for t in range(steps):
        params, state, loss = step(params, state, jnp.asarray(t))
        if dmd_cfg.enabled and acc.should_record(t):
            # per-group slot vector: the group-aware standalone idiom
            # (identical to the legacy scalar path for single-group cfgs)
            bufs, _ = acc.record(bufs, params, acc.slots(t))
        if dmd_cfg.enabled and acc.should_apply(t):
            params, _ = acc.apply(params, bufs, step=t)
            if reset_opt:
                # group-masked moment reset, like the jitted dmd_step: only
                # the jumped (non-exempt) groups' moments restart
                from repro.train.step import reset_opt_state_after_jump
                reset = acc.reset_groups(acc.apply_groups(t))
                if reset:
                    state = reset_opt_state_after_jump(
                        opt, state, params, acc.plans_for(params), reset,
                        acc.n_groups)
    return float(mse_loss(params, X, Y))


@pytest.mark.slow
def test_dmd_beats_baseline_at_equal_steps():
    base = _train(DMDConfig(enabled=False))
    dmd = _train(DMDConfig(enabled=True, m=10, s=40, tol=1e-4,
                           warmup_steps=100, cooldown_steps=10))
    assert dmd < base, (dmd, base)


@pytest.mark.slow
def test_two_group_staggered_matches_global_schedule_loss():
    """Acceptance (ISSUE 3): the issue's example two-group config —
    matrices on the paper's m=14 window, biases/1-D leaves on m=6 windows
    phase-shifted by 7 so the groups NEVER jump on the same step — trains
    the pollutant-style MLP to the same loss tolerance as the single global
    schedule, and both beat the no-DMD baseline. The bias group takes a
    cooldown (so its short windows measure clean dynamics, cycle matched to
    the matrices'), a proportional horizon, and opts out of the moment
    reset (its jumps barely move the weights — zeroing Adam's moments for
    them every cycle costs more than the teleport justifies)."""
    from repro.core.schedule import DMDGroupRule

    base = _train(DMDConfig(enabled=False))
    common = dict(enabled=True, m=14, s=55, tol=1e-4, warmup_steps=100,
                  cooldown_steps=0)
    global_sched = _train(DMDConfig(**common))
    staggered = _train(DMDConfig(
        groups=(DMDGroupRule(name="biases", max_ndim=1, m=6, phase=7,
                             cooldown_steps=8, s=24, reset_opt=False),),
        **common))
    assert np.isfinite(staggered) and np.isfinite(global_sched)
    # same tolerance: within 2x of the global schedule's final MSE ...
    assert staggered < global_sched * 2.0, (staggered, global_sched)
    # ... and still an acceleration over plain Adam
    assert staggered < base, (staggered, base)


@pytest.mark.slow
def test_dmd_never_nans_with_guards():
    final = _train(DMDConfig(enabled=True, m=8, s=80, tol=1e-4,
                             warmup_steps=40, cooldown_steps=5,
                             trust_region=2.0))
    assert np.isfinite(final)


def test_paper_mlp_shapes():
    from repro.models.mlp_net import PAPER_SIZES
    params = init_mlp(jax.random.PRNGKey(0), PAPER_SIZES)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(params))
    assert abs(n - 2.9e6) / 2.9e6 < 0.08        # paper: ~2.9M trainable
    x = jnp.zeros((3, 6))
    y = mlp_forward(params, x)
    assert y.shape == (3, 2670)
