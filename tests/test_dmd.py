"""DMD core vs the float64 oracle + mathematical properties."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.dmd import (combine_snapshots, dmd_coefficients,
                            dmd_eigenvalues, dmd_extrapolate, gram_matrix,
                            gram_row_matrix, set_gram_row)
from repro.core.ref import dmd_extrapolate_ref


def make_linear_traj(n=64, m=10, rank=4, seed=0, noise=0.0, spectrum=None):
    rng = np.random.default_rng(seed)
    Q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    eigs = np.zeros(n)
    eigs[:rank] = spectrum if spectrum is not None else \
        np.linspace(0.95, 0.7, rank)
    A = (Q * eigs) @ Q.T
    w = rng.normal(size=n)
    snaps = []
    for _ in range(m):
        w = A @ w
        snaps.append(w.copy())
    S = np.stack(snaps)
    if noise:
        S = S + rng.normal(size=S.shape) * noise
    return S, A


@pytest.mark.parametrize("mode", ["matpow", "eig"])
@pytest.mark.parametrize("anchor,affine", [("none", False), ("first", True)])
def test_matches_oracle(mode, anchor, affine):
    S, _ = make_linear_traj()
    for s in (5, 20):
        w_jax, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=s, tol=1e-6,
                                   mode=mode, anchor=anchor, affine=affine)
        w_ref = dmd_extrapolate_ref(S, s, tol=1e-6, mode=mode, anchor=anchor,
                                    affine=affine)
        np.testing.assert_allclose(np.asarray(w_jax), w_ref, rtol=2e-2,
                                   atol=2e-2)


def test_exact_on_linear_system():
    """Noise-free linear dynamics: DMD prediction == ground truth."""
    S, A = make_linear_traj(rank=4)
    s = 15
    w_true = S[-1].copy()
    for _ in range(s):
        w_true = A @ w_true
    w, info = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=s, tol=1e-5)
    np.testing.assert_allclose(np.asarray(w), w_true, atol=5e-3)
    assert int(info["rank"]) >= 4


def test_exact_on_drift():
    """Affine-anchored DMD reproduces a pure drift exactly (Jordan case)."""
    rng = np.random.default_rng(1)
    w0, v = rng.normal(size=64), rng.normal(size=64) * 0.1
    S = np.stack([w0 + t * v for t in range(10)])
    # tol must sit above the fp32 Gram noise floor (~3e-4 singular ratio):
    # finer tolerances admit noise modes whose lambda^100 explodes.
    for s in (10, 100):
        w, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=s, tol=1e-3,
                               anchor="first", affine=True)
        truth = S[-1] + s * v
        np.testing.assert_allclose(np.asarray(w), truth,
                                   atol=1e-2 * max(1, s / 10))


def test_eigenvalue_recovery():
    spectrum = np.array([0.95, 0.9, 0.85, 0.8])
    S, _ = make_linear_traj(rank=4, spectrum=spectrum, m=12)
    ev = dmd_eigenvalues(jnp.asarray(S), tol=1e-8)
    mags = sorted(np.abs(ev), reverse=True)[:4]
    np.testing.assert_allclose(mags, sorted(spectrum, reverse=True),
                               atol=1e-3)


def test_relax_folds_into_coefficients():
    S, _ = make_linear_traj()
    Sj = jnp.asarray(S, jnp.float32)
    w_full, _ = dmd_extrapolate(Sj, s=7, tol=1e-6, relax=1.0)
    w_half, _ = dmd_extrapolate(Sj, s=7, tol=1e-6, relax=0.5)
    expect = 0.5 * np.asarray(w_full) + 0.5 * S[-1]
    np.testing.assert_allclose(np.asarray(w_half), expect, rtol=1e-4,
                               atol=1e-5)


def test_trust_region_caps_jump():
    """Spurious growth modes cannot jump farther than the trust radius."""
    rng = np.random.default_rng(2)
    S = np.cumsum(rng.normal(size=(10, 64)), axis=0)  # random walk: noisy
    tr = 1.0
    s = 50
    w, info = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=s, tol=1e-6,
                              anchor="first", affine=True, trust_region=tr)
    steps = np.linalg.norm(np.diff(S, axis=0), axis=1)
    radius = tr * s * np.sqrt(np.mean(steps ** 2))
    jump = np.linalg.norm(np.asarray(w) - S[-1])
    assert jump <= radius * 1.05


def test_translation_invariance_of_anchored_affine():
    """anchor=first + affine: w(S + const) == w(S) + const."""
    S, _ = make_linear_traj()
    shift = np.full(S.shape[1], 37.5)
    w1, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=9, tol=1e-5,
                            anchor="first", affine=True)
    w2, _ = dmd_extrapolate(jnp.asarray(S + shift, jnp.float32), s=9,
                            tol=1e-5, anchor="first", affine=True)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w1) + shift,
                               rtol=1e-3, atol=1e-2)


@settings(max_examples=20, deadline=None)
@given(scale=st.floats(0.1, 10.0), s=st.integers(1, 40),
       m=st.integers(4, 12))
def test_scale_equivariance(scale, s, m):
    """w(a*S) == a*w(S) for every variant (DMD is homogeneous)."""
    S, _ = make_linear_traj(m=m, seed=3)
    Sj = jnp.asarray(S, jnp.float32)
    w1, _ = dmd_extrapolate(Sj, s=s, tol=1e-3, anchor="first", affine=True,
                            trust_region=2.0)
    w2, _ = dmd_extrapolate(Sj * scale, s=s, tol=1e-3, anchor="first",
                            affine=True, trust_region=2.0)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w1) * scale,
                               rtol=5e-2, atol=5e-2 * scale)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 1000))
def test_coefficients_finite_on_noise(seed):
    """Pure-noise snapshots never produce non-finite extrapolations when the
    trust region is on."""
    rng = np.random.default_rng(seed)
    S = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
    w, _ = dmd_extrapolate(S, s=50, tol=1e-4, anchor="first", affine=True,
                           trust_region=2.0)
    assert bool(jnp.all(jnp.isfinite(w)))


def test_nan_poisoned_gram_falls_back_to_identity():
    """Regression (ISSUE 1): a non-finite Gram must never leak NaN into the
    coefficients — the guard falls back to c = e_last (keep w_last), with and
    without the trust region."""
    S, _ = make_linear_traj(m=8)
    g_ok = np.array(gram_matrix(jnp.asarray(S, jnp.float32), anchor="first"))
    e_last = np.zeros(8, np.float32)
    e_last[-1] = 1.0
    for poison in (np.nan, np.inf, -np.inf):
        g = g_ok.copy()
        g[0, 0] = poison
        for tr in (2.0, 0.0):
            c, info = dmd_coefficients(jnp.asarray(g), s=30, tol=1e-4,
                                       anchor="first", affine=True,
                                       trust_region=tr)
            assert bool(jnp.all(jnp.isfinite(c))), (poison, tr)
            np.testing.assert_allclose(np.asarray(c), e_last)
        # and the combination itself stays finite == w_last
        w = combine_snapshots(jnp.asarray(S, jnp.float32),
                              dmd_coefficients(jnp.asarray(g), s=30, tol=1e-4,
                                               anchor="first", affine=True,
                                               trust_region=2.0)[0])
        np.testing.assert_allclose(np.asarray(w), S[-1], rtol=1e-5, atol=1e-5)


def test_inf_snapshot_never_poisons_extrapolation():
    """Even with the c = e_last guard, a non-finite BUFFER would NaN the
    combine (0 * inf); the elementwise fallback must keep w at w_last."""
    rng = np.random.default_rng(0)
    S = np.asarray(rng.normal(size=(8, 16)), np.float32)
    S[3, 5] = np.inf
    w, _ = dmd_extrapolate(jnp.asarray(S), s=50, tol=1e-4, anchor="first",
                           affine=True, trust_region=2.0)
    assert bool(jnp.all(jnp.isfinite(w)))
    np.testing.assert_allclose(np.asarray(w), S[-1], rtol=1e-6)


def test_huge_coefficients_trust_region_no_overflow_nan():
    """A finite-but-huge jump overflows the fp32 quadratic form (inf-inf ->
    NaN in jump2); the guard must zero the jump instead of emitting NaN."""
    gram = jnp.asarray(np.diag([1e30, 1e30, 1e30, 1e30, 1e30, 1e38]),
                       jnp.float32)
    c, info = dmd_coefficients(gram, s=50, tol=1e-10, trust_region=1.0)
    assert bool(jnp.all(jnp.isfinite(c)))
    assert bool(jnp.isfinite(info["jump_scale"]))


@pytest.mark.parametrize("anchor", ["none", "first"])
@pytest.mark.parametrize("seed", [0, 1])
def test_streaming_gram_matches_oracle_across_wraps(anchor, seed):
    """Tentpole contract: the incrementally maintained Gram (one row/col
    refresh per record) equals the full gram_matrix recompute at every
    window-complete point, across >= 2 full cyclic wraps of the buffer."""
    m, n = 6, 40
    rng = np.random.default_rng(seed)
    buf = jnp.zeros((m, n), jnp.float32)
    gram = jnp.zeros((m, m), jnp.float32)
    w = jnp.asarray(rng.normal(size=n), jnp.float32)
    for window in range(3):
        for slot in range(m):
            w = w + 0.05 * jnp.asarray(rng.normal(size=n), jnp.float32)
            buf = buf.at[slot].set(w)
            row = gram_row_matrix(buf, w, anchor=anchor)
            gram = set_gram_row(gram, row, slot)
            if anchor == "none":
                # raw streaming Gram is exact at EVERY step
                oracle = gram_matrix(buf, anchor=anchor)
                np.testing.assert_allclose(np.asarray(gram),
                                           np.asarray(oracle), rtol=1e-5,
                                           atol=1e-5)
        # anchored streaming is exact whenever the window is complete (slot 0
        # is rewritten first in each window, so every entry was refreshed
        # against the new anchor by the time slot m-1 lands) — DESIGN.md §2
        oracle = gram_matrix(buf, anchor=anchor)
        scale = float(jnp.max(jnp.abs(oracle))) or 1.0
        np.testing.assert_allclose(np.asarray(gram) / scale,
                                   np.asarray(oracle) / scale, atol=1e-5)


def test_streaming_gram_stacked_matches_oracle():
    """Same contract for stacked (per-layer batched) buffers."""
    m, L, n = 5, 3, 24
    rng = np.random.default_rng(7)
    buf = jnp.zeros((m, L, n), jnp.float32)
    gram = jnp.zeros((L, m, m), jnp.float32)
    w = jnp.asarray(rng.normal(size=(L, n)), jnp.float32)
    for window in range(2):
        for slot in range(m):
            w = w + 0.1 * jnp.asarray(rng.normal(size=(L, n)), jnp.float32)
            buf = buf.at[slot].set(w)
            row = gram_row_matrix(buf, w, anchor="first", stack_dims=1)
            gram = set_gram_row(gram, row, slot)
        oracle = gram_matrix(buf, anchor="first", stack_dims=1)
        np.testing.assert_allclose(np.asarray(gram), np.asarray(oracle),
                                   rtol=1e-5, atol=1e-5)


def test_streaming_accelerator_apply_matches_recompute():
    """DMDAccelerator.apply(grams=...) == apply with the full recompute."""
    from repro.configs.base import DMDConfig
    from repro.core import DMDAccelerator

    cfg = DMDConfig(m=5, s=9, tol=1e-4, warmup_steps=0, cooldown_steps=0)
    acc = DMDAccelerator(cfg)
    assert acc.streaming
    rng = np.random.default_rng(3)
    params = {"a": jnp.asarray(rng.normal(size=(6, 4)), jnp.float32),
              "b": jnp.asarray(rng.normal(size=(10,)), jnp.float32)}
    bufs = acc.init(params)
    grams = acc.init_grams(bufs)
    for window in range(2):
        for slot in range(cfg.m):
            params = jax.tree_util.tree_map(
                lambda p: p + 0.02 * jnp.asarray(
                    rng.normal(size=p.shape), jnp.float32), params)
            bufs, grams = acc.record(bufs, params, slot, grams)
    # apply() donates params: give each call its own leaf copies
    fresh = lambda: jax.tree_util.tree_map(jnp.copy, params)
    p_stream, _ = acc.apply(fresh(), bufs, 0, grams=grams)
    p_oracle, _ = acc.apply(fresh(), bufs, 0)
    for k in params:
        np.testing.assert_allclose(np.asarray(p_stream[k]),
                                   np.asarray(p_oracle[k]), rtol=1e-4,
                                   atol=1e-5)


def test_gram_matches_dense():
    rng = np.random.default_rng(0)
    S = jnp.asarray(rng.normal(size=(6, 50)), jnp.float32)
    g = gram_matrix(S)
    np.testing.assert_allclose(np.asarray(g),
                               np.asarray(S) @ np.asarray(S).T, rtol=1e-5)
    ga = gram_matrix(S, anchor="first")
    D = np.asarray(S) - np.asarray(S)[0]
    np.testing.assert_allclose(np.asarray(ga), D @ D.T, rtol=1e-5,
                               atol=1e-5)


def test_keep_residual_matches_oracle():
    S, _ = make_linear_traj()
    w_j, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=7, tol=1e-6,
                             keep_residual=True)
    w_r = dmd_extrapolate_ref(S, 7, tol=1e-6, keep_residual=True)
    np.testing.assert_allclose(np.asarray(w_j), w_r, rtol=2e-2, atol=2e-2)


def test_multidim_leaf_combine():
    """gram/combine contract all trailing axes (no flatten copies)."""
    rng = np.random.default_rng(0)
    S4 = jnp.asarray(rng.normal(size=(6, 4, 5, 3)), jnp.float32)
    g = gram_matrix(S4)
    flat = np.asarray(S4).reshape(6, -1)
    np.testing.assert_allclose(np.asarray(g), flat @ flat.T, rtol=1e-5)
    c = jnp.asarray(rng.normal(size=(6,)), jnp.float32)
    w = combine_snapshots(S4, c)
    assert w.shape == (4, 5, 3)
    np.testing.assert_allclose(np.asarray(w).reshape(-1),
                               np.asarray(c) @ flat, rtol=1e-5)


# ---------------------------------------------------------------------------
# Diagnostics property tests (ISSUE 4 satellite): randomized snapshot
# matrices across mode x anchor, via the hypothesis shim.
# ---------------------------------------------------------------------------

def _random_gram(seed, m=8, n=40, anchor="none"):
    rng = np.random.default_rng(seed)
    kind = seed % 3
    if kind == 0:                       # random walk (noisy drift)
        S = np.cumsum(rng.normal(size=(m, n)), axis=0)
    elif kind == 1:                     # low-rank linear dynamics
        S, _ = make_linear_traj(n=n, m=m, rank=4, seed=seed)
    else:                               # pure noise
        S = rng.normal(size=(m, n))
    S = S.astype(np.float32)
    return S, gram_matrix(jnp.asarray(S), anchor=anchor)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       mode=st.sampled_from(["matpow", "eig"]),
       anchor=st.sampled_from(["none", "first", "mean"]))
def test_rank_monotone_nonincreasing_in_tol(seed, mode, anchor):
    """Reported rank never grows as the singular-value filter tightens."""
    _, g = _random_gram(seed, anchor=anchor)
    ranks = []
    for tol in (1e-8, 1e-5, 1e-3, 1e-1, 0.5):
        _, info = dmd_coefficients(g, s=9, tol=tol, mode=mode, anchor=anchor)
        ranks.append(int(info["rank"]))
    assert ranks == sorted(ranks, reverse=True), ranks


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       mode=st.sampled_from(["matpow", "eig"]),
       anchor=st.sampled_from(["none", "first", "mean"]))
def test_jump_scale_finite_on_finite_gram(seed, mode, anchor):
    """jump_scale (and the new jump_norm/step_rms telemetry) is finite
    whenever the Gram is finite — trust region on AND off."""
    _, g = _random_gram(seed, anchor=anchor)
    assert bool(jnp.all(jnp.isfinite(g)))
    for tr in (0.0, 1.5):
        _, info = dmd_coefficients(g, s=25, tol=1e-4, mode=mode,
                                   anchor=anchor, trust_region=tr)
        for key in ("jump_scale", "jump_norm", "step_rms"):
            assert bool(jnp.isfinite(info[key])), (key, tr)


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 10 ** 6), s=st.integers(1, 60),
       anchor=st.sampled_from(["none", "first", "mean"]),
       mode=st.sampled_from(["matpow", "eig"]))
def test_trust_region_cap_exact_invariant(seed, s, anchor, mode):
    """||w_new - w_last|| <= tr * s * rms_step + eps for EVERY randomized
    snapshot matrix: the cap is an invariant of the returned coefficients,
    not a statistical tendency. rms_step is computed exactly the way the
    guard computes it (from the Gram's diagonal band)."""
    tr = 1.5
    S, g = _random_gram(seed, anchor=anchor)
    c, info = dmd_coefficients(g, s=int(s), tol=1e-4, mode=mode,
                               anchor=anchor, trust_region=tr)
    w = np.asarray(c, np.float64) @ np.asarray(S, np.float64)
    jump = np.linalg.norm(w - S[-1])
    gd = np.asarray(g, np.float64)
    diag, sup = np.diag(gd), np.diag(gd, 1)
    rms_step = np.sqrt(max(np.mean(diag[1:] + diag[:-1] - 2 * sup), 0.0))
    radius = tr * s * rms_step
    assert jump <= radius * (1 + 1e-3) + 1e-4 * max(np.abs(S).max(), 1.0), \
        (jump, radius, seed, anchor, mode)


def test_energy_rank_monotone_and_bounded():
    """Controller-mode truncation: rank grows with the energy target and is
    always >= 1; energy=0 falls back to the tol mask bit-exactly."""
    _, g = _random_gram(3, anchor="first")
    ranks = []
    for e in (0.5, 0.9, 0.99, 0.9999):
        _, info = dmd_coefficients(g, s=9, tol=1e-4, anchor="first",
                                   energy=e)
        ranks.append(int(info["rank"]))
    assert ranks == sorted(ranks) and ranks[0] >= 1, ranks
    c0, i0 = dmd_coefficients(g, s=9, tol=1e-4, anchor="first")
    c1, i1 = dmd_coefficients(g, s=9, tol=1e-4, anchor="first", energy=0.0)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))
    assert int(i0["rank"]) == int(i1["rank"])


@pytest.mark.parametrize("mode", ["matpow", "eig"])
def test_dynamic_horizon_matches_static(mode):
    """Controller-mode traced s (s_dyn + static s_max) reproduces the
    static-s coefficients for every horizon in range."""
    _, g = _random_gram(5, anchor="first")
    for sv in (1, 3, 7, 12):
        cs, _ = dmd_coefficients(g, s=sv, tol=1e-4, anchor="first",
                                 mode=mode, affine=True, trust_region=2.0)
        cd, _ = dmd_coefficients(g, s=12, s_max=12,
                                 s_dyn=jnp.asarray(sv, jnp.int32),
                                 tol=1e-4, anchor="first", mode=mode,
                                 affine=True, trust_region=2.0)
        np.testing.assert_allclose(np.asarray(cs), np.asarray(cd),
                                   rtol=1e-5, atol=1e-6)


def test_eig_clamp_on_defective_jordan_matches_matpow():
    """Regression (ISSUE 4 satellite): an unanchored drift trajectory
    produces a DEFECTIVE reduced operator (Jordan block, eigenvalue 1,
    multiplicity 2). The docstring always claimed matpow handles it; the
    eig/clamp branch used to return finite garbage — the noise-split pair
    1 +- delta carries huge opposing amplitudes, the near-singular
    eigenvector solve amplifies them, and clamping the upper eigenvalue
    broke their cancellation (measured ~0.5 absolute error at s=5, growing
    with s). Now the clamp skips the near-1 band and the self-validation
    guard falls back to matpow whenever the eigenbasis cannot reproduce the
    unclamped power: eig-vs-matpow agreement is pinned, and both match the
    exact drift extrapolation."""
    rng = np.random.default_rng(0)
    w0, v = rng.normal(size=32), rng.normal(size=32) * 0.1
    S = np.stack([w0 + t * v for t in range(8)]).astype(np.float32)
    for s in (5, 20, 60):
        truth = S[-1] + s * v
        scale = max(np.abs(truth).max(), 1.0)
        w_mp, _ = dmd_extrapolate(jnp.asarray(S), s=s, tol=1e-4,
                                  mode="matpow")
        w_eig, _ = dmd_extrapolate(jnp.asarray(S), s=s, tol=1e-4,
                                   mode="eig", clamp_eigs=True)
        assert np.abs(np.asarray(w_mp) - truth).max() / scale < 1e-3, s
        assert np.abs(np.asarray(w_eig) - truth).max() / scale < 5e-3, s
        assert np.abs(np.asarray(w_eig) - np.asarray(w_mp)).max() / scale \
            < 5e-3, s


def test_eig_clamp_still_stabilizes_genuine_growth():
    """The defective guard must NOT neuter the clamp where it is the whole
    point: a genuine |lambda| = 1.1 growth mode explodes unclamped and
    stays bounded clamped."""
    S, _ = make_linear_traj(rank=3, spectrum=np.array([1.1, 0.9, 0.8]),
                            m=10)
    w_c, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=20, tol=1e-5,
                             mode="eig", clamp_eigs=True)
    w_u, _ = dmd_extrapolate(jnp.asarray(S, jnp.float32), s=20, tol=1e-5,
                             mode="eig", clamp_eigs=False)
    assert np.linalg.norm(np.asarray(w_u)) > 3 * np.linalg.norm(
        np.asarray(w_c))


def test_eig_clamp_survives_fp32_overflow_of_unclamped_power():
    """Guard-of-the-guard regression: with an operator explosive enough
    that the UNCLAMPED power overflows fp32 (|lambda|^s past 3e38 — the
    exact regime clamp_eigs exists for), the self-validation fallback must
    not evict the finite CLAMPED reconstruction in favor of the non-finite
    matpow power. The clamped jump stays finite and bounded."""
    S, _ = make_linear_traj(rank=2, spectrum=np.array([7.0, 0.5]), m=10,
                            seed=4)
    scale = np.abs(S).max()                 # keep snapshots in fp32 range
    Sj = jnp.asarray(S / scale, jnp.float32)
    w_c, _ = dmd_extrapolate(Sj, s=60, tol=1e-5, mode="eig",
                             clamp_eigs=True)     # 7^60 >> fp32 max
    assert bool(jnp.all(jnp.isfinite(w_c)))
    # the clamp really acted: |lambda| <- 1 keeps the jump at trajectory
    # scale instead of the overflowed unclamped power
    assert np.linalg.norm(np.asarray(w_c)) < 10 * np.linalg.norm(
        np.asarray(Sj[-1]))
    # and it is not the keep-w_last collapse: the mode still evolves
    assert np.linalg.norm(np.asarray(w_c) - np.asarray(Sj[-1])) > 0


def test_batched_stack_matches_per_layer_loop():
    """Per-layer DMD over a stacked (m, L, d) buffer == looping layers."""
    from repro.core.dmd import gram_matrix
    rng = np.random.default_rng(5)
    m, L, d = 8, 3, 40
    S = jnp.asarray(rng.normal(size=(m, L, d)).cumsum(axis=0), jnp.float32)
    g = gram_matrix(S, anchor="first", stack_dims=1)
    assert g.shape == (L, m, m)
    c, info = dmd_coefficients(g, s=11, tol=1e-3, anchor="first",
                               affine=True, trust_region=2.0)
    assert c.shape == (L, m)
    w = combine_snapshots(S, c, stack_dims=1)
    assert w.shape == (L, d)
    for l in range(L):
        w_l, _ = dmd_extrapolate(S[:, l], s=11, tol=1e-3, anchor="first",
                                 affine=True, trust_region=2.0)
        np.testing.assert_allclose(np.asarray(w[l]), np.asarray(w_l),
                                   rtol=1e-4, atol=1e-4)


# -- ISSUE 9: ridge-shrunk (Tikhonov) coefficient solve ----------------------

def test_ridge_zero_is_bit_exact_legacy():
    """ridge=0 must reuse the textual legacy expression: coefficients are
    ARRAY-EQUAL (not merely close) to a call without the argument — the
    bit-exactness pin for every pre-ridge run."""
    S, _ = make_linear_traj()
    g = gram_matrix(jnp.asarray(S, jnp.float32), anchor="first")
    c0, i0 = dmd_coefficients(g, s=9, tol=1e-6, anchor="first", affine=True)
    c1, i1 = dmd_coefficients(g, s=9, tol=1e-6, anchor="first", affine=True,
                              ridge=0.0)
    assert np.array_equal(np.asarray(c0), np.asarray(c1))
    assert int(i0["rank"]) == int(i1["rank"])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 100), ridge=st.floats(1e-6, 1e-1))
def test_ridge_dyn_matches_static(seed, ridge):
    """The traced ridge knob (ridge_dyn — the meta-tuned controller path)
    computes the same shrinkage as the static compile-time ridge."""
    S, _ = make_linear_traj(seed=seed)
    g = gram_matrix(jnp.asarray(S, jnp.float32), anchor="first")
    cs, _ = dmd_coefficients(g, s=9, tol=1e-6, anchor="first", affine=True,
                             ridge=float(ridge))
    cd, _ = dmd_coefficients(g, s=9, tol=1e-6, anchor="first", affine=True,
                             ridge_dyn=jnp.float32(ridge))
    np.testing.assert_allclose(np.asarray(cd), np.asarray(cs), rtol=1e-5,
                               atol=1e-6)


def test_ridge_infinity_collapses_onto_anchor():
    """As ridge -> inf the regression factor -> 0, Atilde -> 0, and the
    anchor fold sends c -> e_0: the extrapolation degenerates to "stay at
    the anchor snapshot" instead of blowing up."""
    S, _ = make_linear_traj()
    g = gram_matrix(jnp.asarray(S, jnp.float32), anchor="first")
    m = S.shape[0]
    e0 = np.zeros(m, np.float32)
    e0[0] = 1.0
    c, _ = dmd_coefficients(g, s=9, tol=1e-6, anchor="first", affine=True,
                            ridge=1e8)
    np.testing.assert_allclose(np.asarray(c), e0, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), ridge=st.floats(0.0, 1.0))
def test_ridge_finite_under_defective_grams(seed, ridge):
    """Rank-deficient Grams with REPEATED eigenvalues (duplicated
    snapshots — the defective case that NaNs the eigh JVP) never produce
    non-finite coefficients in the forward ridge solve."""
    rng = np.random.default_rng(seed)
    w = rng.normal(size=32).astype(np.float32)
    S = np.stack([w] * 4 + [2.0 * w] * 4)        # rank 1, eigvals repeat
    g = gram_matrix(jnp.asarray(S), anchor="first")
    c, _ = dmd_coefficients(g, s=20, tol=1e-6, anchor="first", affine=True,
                            ridge=float(ridge))
    assert bool(jnp.all(jnp.isfinite(c)))
    # and the dynamic-knob path survives the same Gram
    cd, _ = dmd_coefficients(g, s=20, tol=1e-6, anchor="first", affine=True,
                             ridge_dyn=jnp.float32(ridge))
    assert bool(jnp.all(jnp.isfinite(cd)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_ridge_ladder_walks_toward_anchor(seed):
    """Increasing ridge pulls the extrapolation toward the anchor snapshot
    (up to 1% fp32 slack per decade), collapsing onto it in the limit —
    this direction is what makes the controller's pre-solved ridge ladder
    a shrinkage line search rather than an arbitrary knob."""
    S, _ = make_linear_traj(noise=0.05, seed=seed)
    Sj = jnp.asarray(S, jnp.float32)
    g = gram_matrix(Sj, anchor="first")
    dists = []
    for ridge in (0.0, 1e-3, 1e-2, 1e-1, 1.0, 10.0):
        c, _ = dmd_coefficients(g, s=9, tol=1e-6, anchor="first",
                                affine=True, ridge=ridge)
        w = np.asarray(combine_snapshots(Sj, c))
        dists.append(float(np.linalg.norm(w - S[0])))
    assert all(b <= a * 1.01 + 1e-6 for a, b in zip(dists, dists[1:])), dists
    assert dists[-1] <= 0.05 * dists[0] + 1e-6   # collapse in the limit


def test_atol_truncation_drops_small_modes():
    """pymor-style absolute floor: modes the relative tol keeps are dropped
    once their sigma sits below atol."""
    g = jnp.asarray(np.diag([1.0, 1e-2, 1e-8, 1e-8, 1e-8, 0.5]), jnp.float32)
    _, info_rel = dmd_coefficients(g, s=5, tol=1e-10)
    _, info_abs = dmd_coefficients(g, s=5, tol=1e-10, atol=1e-3)
    assert int(info_rel["rank"]) == 5            # relative mask keeps all
    assert int(info_abs["rank"]) == 2            # absolute floor bites
