"""Optional-`hypothesis` guard so property-test modules always collect.

`hypothesis` is declared in the `test` extra (pyproject.toml) but is not a
hard runtime dependency; importing it at module scope used to abort
collection of whole test modules with ModuleNotFoundError. Importing from
this shim instead degrades gracefully: with hypothesis installed the real
`given`/`settings`/`st` are re-exported; without it, a deterministic
stand-in runs each property over a small fixed sample grid (strategy
endpoints + midpoints) so the properties still execute — collection never
hard-errors either way (the importorskip-style contract from ISSUE 1).
"""
import itertools

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    _MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _Strategies:
        @staticmethod
        def floats(lo, hi, **kw):
            return _Strategy([lo, (lo + hi) / 2.0, hi])

        @staticmethod
        def integers(lo, hi, **kw):
            mid = (lo + hi) // 2
            return _Strategy(sorted({lo, mid, hi}))

        @staticmethod
        def booleans(**kw):
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(seq, **kw):
            return _Strategy(seq)

    st = _Strategies()

    def given(**strategies):
        names = list(strategies)
        grid = list(itertools.product(*(strategies[n].samples
                                        for n in names)))
        # Evenly strided subsample keeps the endpoints and caps runtime.
        if len(grid) > _MAX_EXAMPLES:
            stride = (len(grid) - 1) / (_MAX_EXAMPLES - 1)
            grid = [grid[round(i * stride)] for i in range(_MAX_EXAMPLES)]

        def deco(fn):
            # No functools.wraps: pytest must see a ZERO-arg signature, or it
            # would try to resolve the strategy parameters as fixtures.
            def wrapper():
                for combo in grid:
                    fn(**dict(zip(names, combo)))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**kw):
        return lambda fn: fn
