"""Jaxpr accounting helpers: ONE sub-jaxpr walker for every trace-size /
launch-count consumer (the BENCH_arena suite and the CI trace-size guard
pin the SAME numbers, so they must count with the same recursion — a
walker fixed in one copy but not another would let the pinned counts and
the reported bench counts silently disagree)."""
from __future__ import annotations

from typing import Callable, Optional

# Data-pass primitives that dispatch at least one kernel on TPU: matmuls /
# Pallas calls / scatters (segment_sum lowers to scatter-add) / buffer row
# writes. The per-leaf DMD route pays O(leaves) of these per recorded
# step, the packed-arena route O(buckets) — DESIGN.md §7.
LAUNCH_PRIMS = ("dot_general", "pallas_call", "scatter-add", "scatter_add",
                "dynamic_update_slice", "conv_general_dilated")


def count_eqns(jaxpr, pred: Optional[Callable] = None) -> int:
    """Number of primitive equations in `jaxpr`, recursing into pjit /
    cond / scan / closed-call sub-jaxprs. `pred(eqn) -> bool` restricts
    the count (None counts everything); recursion always descends."""
    n = 0
    for eqn in jaxpr.eqns:
        if pred is None or pred(eqn):
            n += 1
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):                    # ClosedJaxpr
                n += count_eqns(v.jaxpr, pred)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    if hasattr(vv, "jaxpr"):
                        n += count_eqns(vv.jaxpr, pred)
    return n


def sum_eqns(jaxpr, weight: Callable) -> int:
    """Sum ``weight(eqn) -> int`` over every equation, recursing exactly
    like count_eqns. Used where the budget lives in an aval's BATCH dim,
    not the equation count — e.g. one batched eigh over an (n, m, m) Gram
    stack is one equation but n coefficient solves (DESIGN.md §9)."""
    n = 0
    for eqn in jaxpr.eqns:
        n += int(weight(eqn))
        for v in eqn.params.values():
            if hasattr(v, "jaxpr"):                    # ClosedJaxpr
                n += sum_eqns(v.jaxpr, weight)
            elif isinstance(v, (list, tuple)):
                for vv in v:
                    if hasattr(vv, "jaxpr"):
                        n += sum_eqns(vv.jaxpr, weight)
    return n


def count_launch_ops(jaxpr) -> int:
    """Kernel-launch proxy: equations whose primitive is a data-pass op
    (see LAUNCH_PRIMS)."""
    return count_eqns(
        jaxpr, lambda e: any(p in str(e.primitive) for p in LAUNCH_PRIMS))
