"""Version-stamped double-buffered parameter store + trainer->server bus.

``ParamStore`` owns the serving weights. A publish is two host-cheap
phases:

  * ``stage(params, version)`` — land the incoming pytree in FRESH device
    buffers (one jitted ``tree_map(jnp.copy)`` program; undonated, so XLA
    must materialize new outputs — the trainer keeps mutating its own
    donated buffers without aliasing the server's), then block until the
    copy is done. The staged tree is the standby buffer.
  * ``commit()`` — atomically flip active/standby on the host and bump
    the version stamp. Nothing touches the old active buffers, so any
    in-flight dispatch that read them completes untouched; the old tree
    is simply dropped and freed by the runtime.

Memory accounting: steady state holds exactly ONE param copy; between
``stage`` and ``commit`` there are exactly TWO (active + standby). There
is never a third, and never a torn half-version — readers only ever see
``.params`` flip pointer-atomically.

``WeightsChannel`` is the cross-process bus: the trainer publishes
leaf-wise params through the checkpoint machinery (atomic tmp-dir +
``os.rename``, so a SIGTERM mid-publish leaves the previous version
intact) and a server polls ``latest_version()`` and swaps when it grows.
"""
from __future__ import annotations

from typing import Any, Optional

PyTree = Any


class ParamStore:
    """Double-buffered, version-stamped device residence for weights."""

    #: compiled-program budget this store contributes to the engine's
    #: registry accounting: the single landing-copy program.
    n_programs = 1

    def __init__(self, params: PyTree, *, shardings: Optional[PyTree] = None):
        import jax
        import jax.numpy as jnp
        self._shardings = shardings
        self._copy = jax.jit(
            lambda t: jax.tree_util.tree_map(jnp.copy, t))
        self._version = 0
        self._staged: Optional[PyTree] = None
        self._staged_version: Optional[int] = None
        self._active = self._land(params)

    def _land(self, params: PyTree) -> PyTree:
        import jax
        if self._shardings is not None:
            params = jax.device_put(params, self._shardings)
        out = self._copy(params)
        jax.block_until_ready(out)
        return out

    @property
    def params(self) -> PyTree:
        return self._active

    @property
    def version(self) -> int:
        return self._version

    @property
    def staged_version(self) -> Optional[int]:
        return self._staged_version

    def stage(self, params: PyTree, version: Optional[int] = None) -> int:
        """Land ``params`` in the standby buffer; does NOT serve them yet."""
        v = self._version + 1 if version is None else int(version)
        if v <= self._version:
            raise ValueError(
                f"stale publish: version {v} <= active {self._version}")
        staged = self._land(params)     # blocks: standby fully materialized
        self._staged = staged
        self._staged_version = v
        return v

    def commit(self) -> int:
        """Atomic flip: standby becomes active, version bumps."""
        if self._staged is None:
            raise RuntimeError("commit() with no staged weights")
        self._active = self._staged
        self._version = self._staged_version
        self._staged = None
        self._staged_version = None
        return self._version

    def publish(self, params: PyTree, version: Optional[int] = None) -> int:
        """stage + commit in one call."""
        self.stage(params, version)
        return self.commit()


class WeightsChannel:
    """File-based trainer->server weights bus over the checkpoint layer.

    Publishes are torn-write-safe for free: ``save_checkpoint`` writes to
    a tmp dir and ``os.rename``s it into place, so a publisher killed
    mid-write (SIGTERM fault-injection tests) never exposes a partial
    version — ``latest_version()`` keeps returning the previous one.
    """

    def __init__(self, root):
        self.root = str(root)

    def publish(self, params: PyTree, version: int) -> str:
        from repro.checkpoint import save_checkpoint
        return save_checkpoint(self.root, {"params": params}, int(version),
                               keep=2)

    def latest_version(self) -> Optional[int]:
        from repro.checkpoint import latest_step
        return latest_step(self.root)

    def load(self, template: PyTree,
             version: Optional[int] = None) -> Optional[PyTree]:
        from repro.checkpoint import restore_checkpoint
        out = restore_checkpoint(self.root, {"params": template},
                                 step=version)
        return None if out is None else out["params"]

    def poll(self, engine, template: PyTree) -> Optional[int]:
        """Swap ``engine`` onto the newest published version, if newer."""
        v = self.latest_version()
        if v is None or v <= engine.version:
            return None
        params = self.load(template, v)
        engine.swap_weights(params, version=v)
        return v
