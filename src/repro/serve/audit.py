"""Serve-engine audit build: drive a reduced engine through a warmup +
steady workload and attach its program-registry counts to an
AuditContext for the ``serve-compile`` pass.

The workload is chosen so warmup touches EVERY program the bucket policy
allows (each prompt bucket at each batch bucket, both inserts, the
decode) and the steady wave re-hits every bucket with DIFFERENT
in-bucket prompt lengths — so under correct bucketing nothing recompiles
(``steady_compiles == 0``, ``n_programs <= max_programs``), while the
``force-recompile`` mutation (exact-length "buckets") compiles fresh
prefill programs per novel steady-state length and the pass bites.
"""
from __future__ import annotations

from typing import Callable, Optional

# (prompt lengths, singleton) warmup/steady waves over the audit engine's
# bucket policy below: pairs exercise batch bucket 2, singles bucket 1.
_WARMUP_WAVES = ([3, 3], [7, 7], [2], [5])
_STEADY_WAVES = ([4, 4], [8, 8], [1], [6])


def _serve_config(mutate: Optional[Callable]):
    from repro.serve.engine import ServeConfig
    cfg = ServeConfig(n_slots=4, prompt_buckets=(4, 8), batch_buckets=(1, 2),
                      max_new_tokens=4)
    return mutate(cfg) if mutate is not None else cfg


def attach_serve(ctx, mutate: Optional[Callable] = None) -> None:
    """Build + exercise a serving engine for ``ctx``'s model config and
    attach ``ctx.serve`` (registry counts) and the compiled decode
    program as the ``serve_decode`` target. ``mutate`` is the
    ``Mutation.serve_cfg`` seam (ServeConfig -> ServeConfig)."""
    if ctx.acfg.model.family == "mlp":
        # no autoregressive decode path to serve; the pass reports a note.
        ctx.serve = {"skipped": f"family {ctx.acfg.model.family!r} has no "
                                "serving path"}
        return

    import jax

    from repro.models.transformer import LanguageModel
    from repro.serve.engine import ServeEngine

    cfg = _serve_config(mutate)
    # The serving build of the SAME (possibly reduced) model the rest of
    # the audit traced: scan_layers=False per launch/serve.py — a layer
    # scan double-buffers the stacked caches and would trip the copy ban.
    model = LanguageModel(ctx.acfg.model, head_tp=False,
                          chunk_k=min(16, cfg.prompt_buckets[-1]),
                          scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, cfg)

    for wave in _WARMUP_WAVES:
        for n in wave:
            engine.submit(list(range(1, n + 1)))
        engine.run_until_drained()
    engine.mark_steady()
    for wave in _STEADY_WAVES:
        for n in wave:
            engine.submit(list(range(1, n + 1)))
        engine.run_until_drained()

    ctx.serve = engine.audit_info()
    ctx.serve["dropped"] = engine.stats["dropped"]
    ctx.targets.update(engine.audit_targets())
