"""Continuous-batching serving engine with live DMD weight hot-swap
(DESIGN.md §10): padded shape buckets (one compiled program per bucket,
zero steady-state recompiles), slot-based decode over donated KV/decode
state, in-jit sampling (zero host syncs per token), and version-stamped
double-buffered weight publishes off the trainer's accepted gated jumps.
"""
from repro.serve.engine import Request, Result, ServeConfig, ServeEngine
from repro.serve.store import ParamStore, WeightsChannel

__all__ = ["Request", "Result", "ServeConfig", "ServeEngine",
           "ParamStore", "WeightsChannel"]
