"""Continuous-batching serving engine with live weight hot-swap.

The engine (DESIGN.md §10) turns the seed-era one-shot serving scripts
into a steady-state request loop built from a FIXED set of compiled
programs:

  * ``decode`` — ONE program over the whole slot table: the per-request
    KV caches are stacked on a leading ``(n_slots,)`` axis and the model's
    ``decode_step`` is vmapped over it (inner batch of 1, per-slot scalar
    cache lengths — zero model changes). Sampling (greedy argmax or
    top-k/categorical with a threaded PRNG key) and the per-slot
    active-mask bookkeeping all run IN-JIT, so a decoded token costs
    exactly one program dispatch and zero device->host syncs. The whole
    decode state is donated; the params are NOT (see hot-swap below).
  * ``prefill_b{B}_p{P}`` — one program per (batch-bucket, prompt-bucket)
    pair: prompts are padded to the bucket shape, the program builds its
    own zeroed caches in-trace and returns them filled.
  * ``insert_b{B}`` — one program per batch bucket: scatters the
    prefilled per-request cache rows into free slots (sentinel indices
    are dropped), seeds the decode cursor, and resets the output row.
    The decode state is donated (in-place scatter); the prefill caches
    are not — their rows land transposed, so no aliasing is possible.

Every program is compiled ahead-of-time (``jit.trace().lower().compile()``)
and dispatched through the compiled executable, so a shape drift raises
instead of silently recompiling; ``mark_steady()`` starts the
steady-state compile counter the serve-compile audit pass pins at zero.

Padded prompts stay BIT-EXACT: the insert program sets the slot's cache
length to ``true_len - 1`` and the cursor to the prompt's last token, so
the first decode step recomputes the final prompt position's KV and
logits at the right offset, and the blockwise-attention chunk grid is
absolute — padded key positions contribute exact no-ops to the online
softmax and everything past the cache length is masked.

Hot-swap: ``swap_weights`` lands new params in the double-buffered
``ParamStore`` (device-to-device copy into fresh buffers, version
bumped atomically on the host). The decode program never donates its
params input, so the swap invalidates nothing in flight; host dispatch
is synchronous, so the flip always lands BETWEEN decode steps. With
``adopt="step"`` in-flight sequences pick the new version up at the
next step; with ``adopt="drain"`` the staged version waits (admissions
held) until every active slot finishes, then commits.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.store import ParamStore

PyTree = Any

# Segment kinds the slot-stacked cache layout supports: plain KVCache
# leaves of shape (count, B, s_max, K, hd) with a (count,) length vector.
SERVABLE_KINDS = ("dense", "moe", "moe_pair")


@dataclass(frozen=True)
class ServeConfig:
    """Engine shape policy + sampling + swap-adoption knobs.

    ``force_recompile`` is the audit mutation seam (repro.audit.mutations
    ``force-recompile``): prompt "buckets" degrade to exact lengths, so
    every novel prompt length compiles a fresh prefill program and the
    serve-compile pass's steady-state-compile pin trips.
    """
    n_slots: int = 8
    prompt_buckets: Tuple[int, ...] = (16, 64)
    batch_buckets: Tuple[int, ...] = (1, 4)
    max_new_tokens: int = 32
    s_max: int = 0                  # 0 -> max(prompt_buckets) + max_new
    sampling: str = "greedy"        # "greedy" | "topk"
    top_k: int = 8
    temperature: float = 1.0
    seed: int = 0
    adopt: str = "step"             # "step" | "drain"
    force_recompile: bool = False


@dataclass
class Request:
    uid: int
    tokens: List[int]
    max_new_tokens: int


@dataclass
class Result:
    uid: int
    prompt_len: int
    tokens: List[int]
    last_logits: np.ndarray         # (padded_vocab,) fp32, final step
    version_start: int              # weights version at insert
    version_end: int                # weights version at completion


@dataclass
class _Slot:
    uid: int
    prompt_len: int
    target: int
    emitted: int
    version_start: int


@dataclass
class _Program:
    name: str
    jaxpr: Any
    hlo: str
    compiled: Any


class ServeEngine:
    """Slot-based continuous batching over one model + one ParamStore."""

    def __init__(self, model, params, cfg: Optional[ServeConfig] = None,
                 *, shardings=None):
        cfg = cfg if cfg is not None else ServeConfig()
        kinds = {seg.kind for seg in model.plan}
        bad = sorted(kinds - set(SERVABLE_KINDS))
        if bad:
            raise NotImplementedError(
                f"serve engine supports KV-cache segment kinds "
                f"{SERVABLE_KINDS}; config has {bad} (ring-cache, SSM and "
                "enc-dec families need per-kind insert programs)")
        if model.scan_layers:
            raise ValueError(
                "serve engine needs a scan_layers=False model: a layer "
                "scan double-buffers the stacked caches by construction "
                "(cache-shaped copy per token — launch/serve.py)")
        if getattr(model.cfg, "mrope_sections", None):
            raise NotImplementedError(
                "mrope position batches are not wired into the slot table")
        if tuple(cfg.prompt_buckets) != tuple(sorted(set(
                cfg.prompt_buckets))) or not cfg.prompt_buckets:
            raise ValueError("prompt_buckets must be ascending and unique")
        if tuple(cfg.batch_buckets) != tuple(sorted(set(
                cfg.batch_buckets))) or not cfg.batch_buckets:
            raise ValueError("batch_buckets must be ascending and unique")
        if cfg.batch_buckets[-1] > cfg.n_slots:
            raise ValueError("largest batch bucket exceeds n_slots")
        if cfg.sampling not in ("greedy", "topk"):
            raise ValueError(f"unknown sampling {cfg.sampling!r}")
        if cfg.adopt not in ("step", "drain"):
            raise ValueError(f"unknown adopt policy {cfg.adopt!r}")
        s_need = max(cfg.prompt_buckets) + cfg.max_new_tokens
        if cfg.s_max and cfg.s_max < s_need:
            raise ValueError(f"s_max={cfg.s_max} < longest prompt bucket + "
                             f"max_new_tokens = {s_need}")

        self.model = model
        self.cfg = cfg
        self._s_max = cfg.s_max or s_need
        self._store = ParamStore(params, shardings=shardings)
        self._programs: Dict[str, _Program] = {}
        self._steady = False
        self._queue: deque = deque()
        self._slots: List[Optional[_Slot]] = [None] * cfg.n_slots
        self._pending = False           # drain-adopt: staged, not committed
        self._uid = 0
        self.stats = {"submitted": 0, "completed": 0, "dropped": 0,
                      "swaps": 0, "compiles": 0, "steady_compiles": 0,
                      "decode_dispatches": 0, "prefill_dispatches": 0,
                      "tokens_emitted": 0}
        self._dstate = self._init_dstate()

    # -- device state -------------------------------------------------------
    def _init_dstate(self) -> Dict[str, Any]:
        import jax
        import jax.numpy as jnp
        cfg = self.cfg
        proto = self.model.init_cache(1, self._s_max, abstract=True)
        caches = jax.tree_util.tree_map(
            lambda l: jnp.zeros((cfg.n_slots,) + tuple(l.shape), l.dtype),
            proto)
        V = self.model.cfg.padded_vocab
        return {
            "caches": caches,
            "cur_tok": jnp.zeros((cfg.n_slots, 1, 1), jnp.int32),
            "out_buf": jnp.zeros((cfg.n_slots, cfg.max_new_tokens),
                                 jnp.int32),
            "out_pos": jnp.zeros((cfg.n_slots,), jnp.int32),
            "target": jnp.zeros((cfg.n_slots,), jnp.int32),
            "last_logits": jnp.zeros((cfg.n_slots, V), jnp.float32),
            "key": jax.random.PRNGKey(cfg.seed),
        }

    # -- AOT program registry -----------------------------------------------
    def _program(self, name: str, build, args) -> _Program:
        prog = self._programs.get(name)
        if prog is None:
            jitted = build()
            traced = jitted.trace(*args)
            compiled = traced.lower().compile()
            prog = _Program(name, traced.jaxpr, compiled.as_text(), compiled)
            self._programs[name] = prog
            self.stats["compiles"] += 1
            if self._steady:
                self.stats["steady_compiles"] += 1
        return prog

    def mark_steady(self) -> None:
        """Warmup is over: any compile after this is a steady-state
        recompile — the defect the serve-compile audit pass pins at 0."""
        self._steady = True

    @property
    def n_programs(self) -> int:
        return len(self._programs) + self._store.n_programs

    @property
    def max_programs(self) -> int:
        """Analytic program ceiling: 1 decode + one prefill per
        (batch-bucket x prompt-bucket) + one insert per batch bucket +
        the ParamStore's landing copy."""
        npb = len(self.cfg.prompt_buckets)
        nbb = len(self.cfg.batch_buckets)
        return 1 + npb * nbb + nbb + self._store.n_programs

    @property
    def version(self) -> int:
        return self._store.version

    @property
    def queue_len(self) -> int:
        return len(self._queue)

    @property
    def active_slots(self) -> int:
        return sum(1 for s in self._slots if s is not None)

    # -- program builders ---------------------------------------------------
    def _build_decode(self):
        import jax
        import jax.numpy as jnp
        model, cfg = self.model, self.cfg

        def decode(params, dstate):
            logits, caches = jax.vmap(
                lambda tok, c: model.decode_step(params, {"tokens": tok}, c),
                in_axes=(0, 0))(dstate["cur_tok"], dstate["caches"])
            logits = logits[:, 0, 0, :]              # (n_slots, V) fp32
            key = dstate["key"]
            if cfg.sampling == "greedy":
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                key, sub = jax.random.split(key)
                vals, idx = jax.lax.top_k(
                    logits / jnp.float32(cfg.temperature), cfg.top_k)
                pick = jax.random.categorical(sub, vals, axis=-1)
                tok = jnp.take_along_axis(
                    idx, pick[:, None], axis=-1)[:, 0].astype(jnp.int32)
            # Device-resident completion mask: no per-step host transfer.
            active = dstate["out_pos"] < dstate["target"]
            rows = jnp.arange(cfg.n_slots)
            pos = jnp.clip(dstate["out_pos"], 0, cfg.max_new_tokens - 1)
            out_buf = dstate["out_buf"].at[rows, pos].set(
                jnp.where(active, tok, dstate["out_buf"][rows, pos]))
            return {
                # Inactive slots decode garbage harmlessly: their cache
                # writes clamp at s_max and insert overwrites wholesale.
                "caches": caches,
                "cur_tok": jnp.where(active[:, None, None],
                                     tok[:, None, None], dstate["cur_tok"]),
                "out_buf": out_buf,
                "out_pos": dstate["out_pos"] + active.astype(jnp.int32),
                "target": dstate["target"],
                "last_logits": jnp.where(active[:, None], logits,
                                         dstate["last_logits"]),
                "key": key,
            }

        # Decode state donated; params deliberately NOT — a hot-swap must
        # never invalidate the buffers an in-flight dispatch reads.
        return jax.jit(decode, donate_argnums=(1,))

    def _build_prefill(self, Bb: int):
        import jax
        model, s_max = self.model, self._s_max

        def prefill(params, toks):              # toks (Bb, Pb) i32
            caches = model.init_cache(toks.shape[0], s_max)
            _, filled = model.prefill(params, {"tokens": toks}, caches)
            return filled

        return jax.jit(prefill)

    def _build_insert(self, Bb: int):
        import jax
        import jax.numpy as jnp
        from repro.models.attention import KVCache
        cfg = self.cfg

        def insert(dstate, pre_caches, slots, true_lens, first_toks,
                   targets):
            # slots (Bb,) i32; filler rows carry the out-of-range sentinel
            # n_slots and are DROPPED by the scatters (mode="drop").
            def upd(slot_kv, pre_kv):
                k = jnp.moveaxis(pre_kv.k, 1, 0)[:, :, None]
                v = jnp.moveaxis(pre_kv.v, 1, 0)[:, :, None]
                # length = true_len - 1: the first decode step recomputes
                # the last prompt token's KV/logits at the right position
                # (padded-prompt bit-exactness, module docstring).
                lens = jnp.broadcast_to(
                    (true_lens - 1)[:, None],
                    (Bb, slot_kv.length.shape[1])).astype(jnp.int32)
                return KVCache(
                    slot_kv.k.at[slots].set(k.astype(slot_kv.k.dtype),
                                            mode="drop"),
                    slot_kv.v.at[slots].set(v.astype(slot_kv.v.dtype),
                                            mode="drop"),
                    slot_kv.length.at[slots].set(lens, mode="drop"))

            caches = jax.tree_util.tree_map(
                upd, dstate["caches"], pre_caches,
                is_leaf=lambda x: isinstance(x, KVCache))
            return {
                "caches": caches,
                "cur_tok": dstate["cur_tok"].at[slots].set(
                    first_toks[:, None, None].astype(jnp.int32),
                    mode="drop"),
                "out_buf": dstate["out_buf"].at[slots].set(
                    jnp.zeros((Bb, cfg.max_new_tokens), jnp.int32),
                    mode="drop"),
                "out_pos": dstate["out_pos"].at[slots].set(
                    jnp.zeros((Bb,), jnp.int32), mode="drop"),
                "target": dstate["target"].at[slots].set(
                    targets.astype(jnp.int32), mode="drop"),
                "last_logits": dstate["last_logits"],
                "key": dstate["key"],
            }

        return jax.jit(insert, donate_argnums=(0,))

    # -- bucketing ----------------------------------------------------------
    def _prompt_bucket(self, n: int) -> int:
        if n > self.cfg.prompt_buckets[-1]:
            raise ValueError(
                f"prompt length {n} exceeds the largest prompt bucket "
                f"{self.cfg.prompt_buckets[-1]}")
        if self.cfg.force_recompile:
            return n        # audit seam: exact lengths, fresh compiles
        for b in self.cfg.prompt_buckets:
            if n <= b:
                return b
        raise AssertionError

    def _batch_bucket(self, n: int) -> int:
        for b in self.cfg.batch_buckets:
            if n <= b:
                return b
        raise AssertionError

    # -- request lifecycle --------------------------------------------------
    def submit(self, tokens: Sequence[int],
               max_new_tokens: Optional[int] = None) -> int:
        toks = [int(t) for t in tokens]
        if not toks:
            raise ValueError("empty prompt")
        self._prompt_bucket(len(toks))          # raises for oversize
        mn = int(max_new_tokens if max_new_tokens is not None
                 else self.cfg.max_new_tokens)
        if not 1 <= mn <= self.cfg.max_new_tokens:
            raise ValueError(
                f"max_new_tokens={mn} outside [1, {self.cfg.max_new_tokens}]")
        uid = self._uid
        self._uid += 1
        self._queue.append(Request(uid, toks, mn))
        self.stats["submitted"] += 1
        return uid

    def _admit(self) -> None:
        import jax.numpy as jnp
        if self._pending:                       # drain-adopt holds admission
            return
        free = [i for i, s in enumerate(self._slots) if s is None]
        while free and self._queue:
            pb = self._prompt_bucket(len(self._queue[0].tokens))
            take = min(len(free), self.cfg.batch_buckets[-1])
            reqs: List[Request] = []
            while (self._queue and len(reqs) < take and self._prompt_bucket(
                    len(self._queue[0].tokens)) == pb):
                reqs.append(self._queue.popleft())
            Bb = self._batch_bucket(len(reqs))

            toks = np.zeros((Bb, pb), np.int32)
            slots = np.full((Bb,), self.cfg.n_slots, np.int32)  # sentinel
            true_lens = np.ones((Bb,), np.int32)
            first_toks = np.zeros((Bb,), np.int32)
            targets = np.ones((Bb,), np.int32)
            for r, req in enumerate(reqs):
                n = len(req.tokens)
                toks[r, :n] = req.tokens
                slots[r] = free.pop(0)
                true_lens[r] = n
                first_toks[r] = req.tokens[n - 1]
                targets[r] = req.max_new_tokens
            if len(reqs) < Bb:                  # filler rows: repeat row 0
                toks[len(reqs):] = toks[0]
                true_lens[len(reqs):] = true_lens[0]
                first_toks[len(reqs):] = first_toks[0]

            params = self._store.params
            prefill = self._program(f"prefill_b{Bb}_p{pb}",
                                    lambda: self._build_prefill(Bb),
                                    (params, jnp.asarray(toks)))
            pre_caches = prefill.compiled(params, jnp.asarray(toks))
            self.stats["prefill_dispatches"] += 1
            ins_args = (self._dstate, pre_caches, jnp.asarray(slots),
                        jnp.asarray(true_lens), jnp.asarray(first_toks),
                        jnp.asarray(targets))
            insert = self._program(f"insert_b{Bb}",
                                   lambda: self._build_insert(Bb), ins_args)
            self._dstate = insert.compiled(*ins_args)
            for r, req in enumerate(reqs):
                self._slots[int(slots[r])] = _Slot(
                    uid=req.uid, prompt_len=len(req.tokens),
                    target=req.max_new_tokens, emitted=0,
                    version_start=self.version)

    def step(self) -> List[Result]:
        """One engine tick: commit a pending drain-swap if the table is
        empty, admit queued requests into free slots, dispatch ONE decode
        step, and harvest completions. Returns finished Results."""
        self._maybe_commit_pending()
        self._admit()
        if all(s is None for s in self._slots):
            return []
        n_active = self.active_slots
        prog = self._program("decode", self._build_decode,
                             (self._store.params, self._dstate))
        self._dstate = prog.compiled(self._store.params, self._dstate)
        self.stats["decode_dispatches"] += 1
        self.stats["tokens_emitted"] += n_active
        finished: List[Result] = []
        for i, info in enumerate(self._slots):
            if info is None:
                continue
            # Host mirror of the in-jit active mask: one emitted token per
            # dispatch until the target — no device readback to find out.
            info.emitted += 1
            if info.emitted >= info.target:
                finished.append(self._finish(i))
        return finished

    def _finish(self, slot: int) -> Result:
        info = self._slots[slot]
        toks = np.asarray(self._dstate["out_buf"][slot, :info.target])
        logits = np.asarray(self._dstate["last_logits"][slot])
        self._slots[slot] = None
        self.stats["completed"] += 1
        return Result(uid=info.uid, prompt_len=info.prompt_len,
                      tokens=[int(t) for t in toks], last_logits=logits,
                      version_start=info.version_start,
                      version_end=self.version)

    def run_until_drained(self, max_steps: int = 100_000) -> List[Result]:
        out: List[Result] = []
        steps = 0
        while (self._queue or any(s is not None for s in self._slots)
               or self._pending):
            out.extend(self.step())
            steps += 1
            if steps > max_steps:
                raise RuntimeError(f"drain stalled after {max_steps} steps "
                                   f"({self.queue_len} queued, "
                                   f"{self.active_slots} active)")
        return out

    def sync(self) -> None:
        """Block until the decode state is materialized (bench timing)."""
        import jax
        jax.block_until_ready(self._dstate)

    # -- hot-swap -----------------------------------------------------------
    def swap_weights(self, params, version: Optional[int] = None) -> int:
        """Stage new weights (device-to-device copy into the standby
        buffer) and adopt them per ``cfg.adopt``. Host dispatch is
        synchronous, so the version flip always lands between decode
        steps; the decode program's params are undonated, so nothing in
        flight is invalidated either way. Returns the staged version."""
        self._store.stage(params, version)
        staged = self._store.staged_version
        if self.cfg.adopt == "drain":
            self._pending = True
            self._maybe_commit_pending()
        else:
            self._store.commit()
            self.stats["swaps"] += 1
        return staged

    def _maybe_commit_pending(self) -> None:
        if self._pending and all(s is None for s in self._slots):
            self._store.commit()
            self._pending = False
            self.stats["swaps"] += 1

    # -- audit hooks --------------------------------------------------------
    def audit_info(self) -> Dict[str, Any]:
        return {"n_programs": self.n_programs,
                "max_programs": self.max_programs,
                "compiles": self.stats["compiles"],
                "steady_compiles": self.stats["steady_compiles"],
                "n_prompt_buckets": len(self.cfg.prompt_buckets),
                "n_batch_buckets": len(self.cfg.batch_buckets),
                "programs": sorted(self._programs)}

    def audit_targets(self) -> Dict[str, Any]:
        """The decode program as an AuditTarget (compiled HLO + jaxpr from
        the AOT registry — no re-trace): the slot-stacked caches are the
        donated hot state, same contract as serve_fns' donation audit."""
        import jax
        import jax.numpy as jnp
        from repro.audit import hlo as hlo_mod
        from repro.audit.targets import AuditTarget
        out: Dict[str, Any] = {}
        prog = self._programs.get("decode")
        if prog is None:
            return out
        leaves = jax.tree_util.tree_leaves(self._dstate["caches"])
        shapes = frozenset(hlo_mod.shape_str(l) for l in leaves
                           if jnp.issubdtype(l.dtype, jnp.floating))
        out["serve_decode"] = AuditTarget(
            name="serve_decode", jaxpr=prog.jaxpr, hlo=prog.hlo,
            donated=True,
            n_state_leaves=len(jax.tree_util.tree_leaves(self._dstate)),
            n_dmd_leaves=len(leaves), buffer_shapes=shapes,
            gram_shapes=frozenset())
        return out
