from repro.models.transformer import (
    LanguageModel, init_params, make_model,
)
from repro.models import layers, attention, moe, ssm, mlp_net

__all__ = ["LanguageModel", "init_params", "make_model", "layers",
           "attention", "moe", "ssm", "mlp_net"]
