"""The paper's regression DNN: feed-forward softsign MLP (6 -> 40 -> 200 ->
1000 -> 2670), Xavier init, trained with Adam on MSE — the network of Fig. 1.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp


def init_mlp(key, sizes: Sequence[int], dtype=jnp.float32):
    """sizes: [in, h1, ..., out]. Xavier/Glorot init (paper §2)."""
    params = {}
    keys = jax.random.split(key, len(sizes) - 1)
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        std = jnp.sqrt(2.0 / (fan_in + fan_out))
        params[f"l{i}"] = {
            "w": (jax.random.normal(keys[i], (fan_in, fan_out), jnp.float32)
                  * std).astype(dtype),
            "b": jnp.zeros((fan_out,), dtype),
        }
    return params


def mlp_forward(params, x, activation: str = "softsign"):
    act = {"softsign": jax.nn.soft_sign, "tanh": jnp.tanh,
           "relu": jax.nn.relu}[activation]
    n = len(params)
    h = x
    for i in range(n):
        p = params[f"l{i}"]
        h = h @ p["w"] + p["b"]
        if i < n - 1:
            h = act(h)
    return h


def mse_loss(params, x, y, activation: str = "softsign"):
    pred = mlp_forward(params, x, activation)
    return jnp.mean(jnp.square(pred - y))


PAPER_SIZES: Tuple[int, ...] = (6, 40, 200, 1000, 2670)
