"""Mixture-of-Experts with expert parallelism over the "model" mesh axis.

Layout rationale (DESIGN.md §6): activations under TP are replicated across
"model" (the hidden dim is unsharded between blocks), while expert weights
(E, d, f) shard E over "model". Each shard therefore already HOLDS every
token of its batch rows and OWNS E/tp experts — dispatch is a *local*
capacity-gather, expert compute is a local batched einsum, and the combine is
one (B,S,D) partial-sum all-reduce over "model" (the same bytes a dense TP
MLP pays). No one-hot dispatch einsums (which would inflate HLO FLOPs
~E/topk-fold and poison the roofline), no all_to_all needed.

Grouping: capacity selection happens *per sequence* (group = batch row), so
every top-k/gather/scatter is batched over the data-sharded B dim and stays
local — the GShard grouping trick. Per-expert capacity per group:
C = ceil(S * topk / E * cf); each expert takes its top-C tokens of the group
by routed mass ("expert's choice of its routed tokens"), overflow tokens drop
that expert (GShard-style dropping).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers


# ---------------------------------------------------------------------------
# Sharded dispatch/combine with sharding-aware backward rules.
#
# GSPMD partitions the forward gather/scatter fine, but their TRANSPOSES in
# the autodiff backward (scatter-add of dxe into dx, gather of dout into dye)
# lose the expert sharding and materialize a replicated fp32 (B_global, S, D)
# tensor per MoE layer (measured 8 GiB all-reduce + 8 GiB all-gather per
# layer on qwen3). custom_vjp lets us re-state the constraints inside the
# backward.
# ---------------------------------------------------------------------------

def _vmapped_gather(x, sel_idx):
    """(B,S,D),(B,E,C)->(B,E,C,D) with B as a TRUE batch dim (vmap), so
    GSPMD keeps the batch sharding through the gather/scatter instead of
    treating B as an indexed dim and replicating."""
    return jax.vmap(lambda xb, sb: jnp.take(xb, sb, axis=0))(x, sel_idx)


def _vmapped_scatter_add(ye, sel_idx, seq_len):
    def one(yb, sb):
        return jnp.zeros((seq_len, yb.shape[-1]), yb.dtype).at[sb].add(
            yb, mode="drop")
    return jax.vmap(one)(ye, sel_idx)


@functools.partial(jax.custom_vjp, nondiff_argnums=())
def _dispatch(x, sel_idx):
    return _vmapped_gather(x, sel_idx)


def _dispatch_fwd(x, sel_idx):
    return _dispatch(x, sel_idx), (sel_idx, x.shape)


def _dispatch_bwd(res, g):
    sel_idx, x_shape = res
    g = constrain(g, "batch", "model", None, None)
    dx = _vmapped_scatter_add(g, sel_idx, x_shape[1])
    dx = constrain(dx, "batch", None, None)
    return dx, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _combine(ye, sel_idx, seq_len):
    out = _vmapped_scatter_add(ye, sel_idx, seq_len)
    return constrain(out, "batch", None, None)


def _combine_fwd(ye, sel_idx, seq_len):
    return _combine(ye, sel_idx, seq_len), sel_idx


def _combine_bwd(seq_len, sel_idx, g):
    g = constrain(g, "batch", None, None)
    dye = _vmapped_gather(g, sel_idx)
    dye = constrain(dye, "batch", "model", None, None)
    return dye, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def moe_init(key, cfg, abstract=False):
    m = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    p = {
        "router": layers.dense_init(ks[0], (cfg.d_model, m.n_experts),
                                    jnp.float32, abstract),
        "experts_in": layers.dense_init(
            ks[1], (m.n_experts, cfg.d_model, m.expert_d_ff), dtype, abstract),
        "experts_gate": layers.dense_init(
            ks[2], (m.n_experts, cfg.d_model, m.expert_d_ff), dtype, abstract),
        "experts_out": layers.dense_init(
            ks[3], (m.n_experts, m.expert_d_ff, cfg.d_model), dtype, abstract),
    }
    if m.n_shared_experts > 0:
        p["shared"] = layers.mlp_init(ks[4], cfg, d_ff=m.shared_d_ff,
                                      abstract=abstract)
    return p


def aux_load_balance_loss(probs, top_i, n_experts: int) -> jnp.ndarray:
    """Switch-Transformer load balancing loss (arXiv:2101.03961).

    probs: (B, S, E); top_i: (B, S, k).
    """
    counts = jnp.zeros((n_experts,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    frac_tokens = counts / jnp.maximum(top_i.size, 1)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    return n_experts * jnp.sum(frac_tokens * frac_probs)


def apply_moe(x, p, cfg, *, key=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss). B is the data-sharded group dim."""
    m = cfg.moe
    B, S, D = x.shape
    E, k = m.n_experts, m.top_k
    cap = max(int(S * k / E * m.capacity_factor), 1)
    cap = min(cap, S)

    logits = x.astype(jnp.float32) @ p["router"]              # (B, S, E)
    if m.router_jitter and key is not None:
        logits = logits + m.router_jitter * jax.random.normal(key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                     # (B, S, k)
    aux = aux_load_balance_loss(probs, top_i, E) * m.aux_loss_weight

    # routed mass per (token, expert): probability iff expert in token's
    # top-k. Double-vmapped scatter so (B, S) stay true batch dims (GSPMD
    # would otherwise replicate the (B, S, E) scatter across "data").
    gate = jax.vmap(jax.vmap(
        lambda ti, tp: jnp.zeros((E,), jnp.float32).at[ti].set(tp)))(
            top_i, top_p)                                      # (B, S, E)

    # Expert-side capacity selection within each group (sequence).
    # The E dim must be "model"-sharded BEFORE the token gather: otherwise
    # GSPMD materializes the full (B, E, C, D) dispatch tensor replicated and
    # reshards it afterwards (measured: ~2.6e12 bytes/step on qwen3 —
    # the dominant collective term of the whole cell).
    gate_t = constrain(gate.transpose(0, 2, 1), "batch", "model", None)
    sel_gate, sel_idx = jax.lax.top_k(gate_t, cap)             # (B, E, C)
    sel_gate = jnp.where(sel_gate > 0.0, sel_gate, 0.0)
    sel_gate = constrain(sel_gate, "batch", "model", None)
    sel_idx = constrain(sel_idx, "batch", "model", None)

    # Batched local gather: (B, E, C, D); expert dim sharded over "model".
    xe = _dispatch(x, sel_idx)                                 # (B, E, C, D)
    if m.weight_stationary:
        xe = constrain(xe, "batch", "model", None, None)
        h = jnp.einsum("becd,edf->becf", xe, p["experts_in"])
        g = jnp.einsum("becd,edf->becf", xe, p["experts_gate"])
        act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
        h = constrain(act * h, "batch", "model", None, None)
        ye = jnp.einsum("becf,efd->becd", h, p["experts_out"])  # (B, E, C, D)
    else:
        # activation-stationary: gather the (small) dispatched tokens across
        # "data" instead of the (huge) expert weights; expert ffn dim stays
        # FSDP-sharded through the block, combined by a psum over "data".
        xe = constrain(xe, None, "model", None, None)
        h = jnp.einsum("becd,edf->becf", xe, p["experts_in"])
        g = jnp.einsum("becd,edf->becf", xe, p["experts_gate"])
        act = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
        h = constrain(act * h, None, "model", None, "data")
        ye = jnp.einsum("becf,efd->becd", h, p["experts_out"])  # partial
        ye = constrain(ye, "batch", "model", None, None)
    ye = ye * sel_gate[..., None].astype(ye.dtype)

    # Batched scatter-add back to token positions; E-sharded partials are
    # combined by one all-reduce over "model" (GSPMD-inserted).
    out = _combine(ye, sel_idx, S)

    if "shared" in p:
        out = out + layers.apply_mlp(x, p["shared"], cfg).astype(out.dtype)
    return out.astype(x.dtype), aux
