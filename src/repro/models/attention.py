"""Attention: GQA with RoPE/M-RoPE, sliding windows, KV caches.

One blockwise (online-softmax, kv-chunked) core serves train, prefill and
decode. It is sharding-agnostic jnp: callers set sharding via constraints.

Two distribution layouts (selected per arch by head divisibility; see
DESIGN.md §6):
  * head-TP:    q/k/v sharded on the head dim over "model". Zero attention
                collectives. Requires n_heads % tp == 0 (and kv likewise, or
                kv replicated when n_kv < tp).
  * kv-SP:      heads replicated over "model"; K/V sharded on the SEQUENCE
                dim. The softmax statistics and the PV contraction reduce over
                the sharded dim, so GSPMD emits exactly the flash-decoding
                partial-softmax pattern (two small all-reduces). Works for any
                head count; also the long_500k decode layout.

The Pallas flash-attention kernel (repro.kernels.flash_attention) implements
the same contract for the TPU hot path; this jnp version is its oracle and
the lowering default.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers

NEG_INF = -1e30


def attn_init(key, cfg, n_heads=None, n_kv=None, abstract=False):
    n_heads = n_heads or cfg.n_heads
    n_kv = n_kv or cfg.n_kv_heads
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    hd = cfg.head_dim
    return {
        "wq": layers.dense_init(ks[0], (cfg.d_model, n_heads * hd), dtype, abstract),
        "wk": layers.dense_init(ks[1], (cfg.d_model, n_kv * hd), dtype, abstract),
        "wv": layers.dense_init(ks[2], (cfg.d_model, n_kv * hd), dtype, abstract),
        "wo": layers.dense_init(ks[3], (n_heads * hd, cfg.d_model), dtype, abstract),
    }


def _chunk_mask(q_pos, k_pos, causal: bool, window: int):
    """(Sq, Sk) boolean mask for one (q-chunk, k-chunk) pair."""
    rel = q_pos[:, None] - k_pos[None, :]
    mask = jnp.ones(rel.shape, bool)
    if causal:
        mask &= rel >= 0
    if window and window > 0:
        mask &= rel < window
    return mask


def blockwise_attention(q, k, v, *, causal: bool, window: int = 0,
                        q_offset=0, kv_len: Optional[jnp.ndarray] = None,
                        k_positions: Optional[jnp.ndarray] = None,
                        chunk_k: int = 1024, logit_dtype=jnp.float32):
    """Online-softmax attention, scanning kv in chunks of `chunk_k`.

    q: (B, Sq, H, hd);  k/v: (B, Sk, K, hd) with H % K == 0 (GQA).
    q_offset: absolute position of q[0] (decode: cache length). May be traced.
    kv_len: optional scalar; kv positions >= kv_len are masked (decode with a
      partially-filled cache).
    k_positions: optional (Sk,) absolute positions (ring/window caches store
      out-of-order slots); defaults to arange(Sk). Negative = invalid slot.
    Never materializes (Sq, Sk) for the full sequence: peak is (Sq, chunk_k).
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    rep = H // K
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qf = (q.astype(logit_dtype) * scale)

    n_chunks = max(-(-Sk // chunk_k), 1)
    pad = n_chunks * chunk_k - Sk
    if k_positions is None:
        k_positions = jnp.arange(Sk)
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-1)
    kc = k.reshape(B, n_chunks, chunk_k, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk_k, K, hd).transpose(1, 0, 2, 3, 4)
    pc = k_positions.reshape(n_chunks, chunk_k)

    q_pos = jnp.arange(Sq) + q_offset
    limit = kv_len if kv_len is not None else Sk

    def scan_fn(carry, inp):
        m_prev, l_prev, acc = carry
        k_pos, kb, vb = inp                              # (ck,), (B, ck, K, hd)
        # logits: (B, K, rep, Sq, ck)
        qg = qf.reshape(B, Sq, K, rep, hd)
        s = jnp.einsum("bsgrh,bcgh->bgrsc", qg, kb.astype(logit_dtype))
        mask = _chunk_mask(q_pos, k_pos, causal, window)
        mask &= (k_pos >= 0)[None, :] & (k_pos < limit)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)                      # (B,K,rep,Sq)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrsc,bcgh->bgrsh", p, vb.astype(logit_dtype))
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, K, rep, Sq), NEG_INF, logit_dtype)
    l0 = jnp.zeros((B, K, rep, Sq), logit_dtype)
    a0 = jnp.zeros((B, K, rep, Sq, hd), logit_dtype)
    if n_chunks == 1:
        (m, l, acc), _ = scan_fn((m0, l0, a0), (pc[0], kc[0], vc[0]))
    else:
        (m, l, acc), _ = jax.lax.scan(scan_fn, (m0, l0, a0), (pc, kc, vc))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S_max, K, hd)
    v: jnp.ndarray
    length: jnp.ndarray     # scalar int32: valid prefix


class RingKVCache(NamedTuple):
    """Fixed-window ring buffer for sliding-window layers (gemma3 local):
    O(window) memory at any context length — what makes long_500k decode
    sub-quadratic in memory for the 5:1 local:global archs."""
    k: jnp.ndarray          # (B, W, K, hd)
    v: jnp.ndarray
    pos: jnp.ndarray        # (W,) absolute position per slot; -1 = empty
    length: jnp.ndarray     # total tokens seen


def init_kv_cache(batch, s_max, n_kv, head_dim, dtype, abstract=False):
    shape = (batch, s_max, n_kv, head_dim)
    if abstract:
        z = jax.ShapeDtypeStruct(shape, dtype)
        return KVCache(z, z, jax.ShapeDtypeStruct((), jnp.int32))
    z = jnp.zeros(shape, dtype)
    return KVCache(z, z, jnp.zeros((), jnp.int32))


def init_ring_cache(batch, window, n_kv, head_dim, dtype, abstract=False):
    shape = (batch, window, n_kv, head_dim)
    if abstract:
        z = jax.ShapeDtypeStruct(shape, dtype)
        return RingKVCache(z, z, jax.ShapeDtypeStruct((window,), jnp.int32),
                           jax.ShapeDtypeStruct((), jnp.int32))
    z = jnp.zeros(shape, dtype)
    return RingKVCache(z, z, jnp.full((window,), -1, jnp.int32),
                       jnp.zeros((), jnp.int32))


def pad_heads(t, target_groups_rep):
    """Zero-pad heads per GQA group: (B, S, H, hd) with H = K*rep ->
    (B, S, K*rep_pad, hd), preserving the q-head -> kv-head grouping.

    Padded-head attention is exact: zero q rows produce zero outputs (sliced
    off), zero k/v rows are never created here (kv pads use the same rule
    when K itself is padded, with matching q-group pads)."""
    K, rep, rep_pad = target_groups_rep
    B, S, H, hd = t.shape
    g = t.reshape(B, S, K, rep, hd)
    g = jnp.pad(g, ((0, 0), (0, 0), (0, 0), (0, rep_pad - rep), (0, 0)))
    return g.reshape(B, S, K * rep_pad, hd)


def attend(x, p, cfg, *, positions, causal=True, window=0,
           cache=None, head_tp: bool = True, use_rope: bool = True,
           kv_override=None, chunk_k: int = 1024, pad_heads_to: int = 0):
    """Full attention sub-layer: projections + rope + core + output.

    cache: KVCache (append at cache.length) or RingKVCache (window ring,
      decode only, S==1). kv_override: (k, v) tensors for cross-attention
      (whisper decoder -> encoder states); no cache update, no rope on kv.
    pad_heads_to: §Perf "padded head-TP": transiently zero-pad q (and, for
      MHA, kv) heads to a multiple of the TP degree so the attention core is
      head-sharded — replaces the kv-SP layout's per-layer q/k/v all-gathers
      with one small reshard, at (H_pad/H)x extra core-attention flops.
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)

    kv_len = None
    k_positions = None
    q_offset = 0
    new_cache = None

    if kv_override is not None:
        k, v = kv_override
        K = k.shape[2]
        causal = False
    else:
        k = (x @ p["wk"]).reshape(B, S, K, hd)
        v = (x @ p["wv"]).reshape(B, S, K, hd)
        if use_rope:
            k = layers.apply_rope(k, positions, cfg.rope_theta,
                                  cfg.mrope_sections)

    H_eff, K_eff = H, K
    pad_rep = None
    if pad_heads_to and H % pad_heads_to != 0 and kv_override is None \
            and cache is None:
        H_pad = -(-H // pad_heads_to) * pad_heads_to
        if K == H:
            # MHA: pad q AND k/v heads at the end (one group per head).
            pad_rep = (1, H, H_pad)
            q = pad_heads(q, (1, H, H_pad))
            k = pad_heads(k, (1, H, H_pad))
            v = pad_heads(v, (1, H, H_pad))
            H_eff = K_eff = H_pad
        elif H_pad % K == 0:
            # GQA: pad each group's rep so grouping is preserved.
            rep, rep_pad = H // K, H_pad // K
            pad_rep = (K, rep, rep_pad)
            q = pad_heads(q, pad_rep)
            H_eff = H_pad

    if pad_rep is not None:
        kv_tp = "model" if K_eff % 16 == 0 and K_eff >= 16 else None
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, kv_tp, None)
        v = constrain(v, "batch", None, kv_tp, None)
    elif head_tp:
        kv_tp = "model" if K >= 16 else None
        q = constrain(q, "batch", None, "model", None)
        k = constrain(k, "batch", None, kv_tp, None)
        v = constrain(v, "batch", None, kv_tp, None)
    else:                                   # kv-SP: shard sequence of k/v
        q = constrain(q, "batch", None, None, None)
        k = constrain(k, "batch", "model", None, None)
        v = constrain(v, "batch", "model", None, None)

    if cache is not None and kv_override is None:
        if isinstance(cache, RingKVCache):
            W = cache.k.shape[1]
            if S > 1:
                # prefill: attend over the in-context k/v with the window
                # mask, then build the ring from the LAST W tokens (rolled so
                # slot s holds the token with position % W == s).
                if S >= W:
                    k_last = k[:, S - W:]
                    v_last = v[:, S - W:]
                    shift = S % W
                    kc = jnp.roll(k_last, shift, axis=1).astype(cache.k.dtype)
                    vc = jnp.roll(v_last, shift, axis=1).astype(cache.v.dtype)
                    sl = jnp.arange(W)
                    pos_arr = (S - W + ((sl - S) % W)).astype(jnp.int32)
                else:
                    pad = W - S
                    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).astype(cache.k.dtype)
                    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                                 ).astype(cache.v.dtype)
                    pos_arr = jnp.concatenate(
                        [jnp.arange(S), jnp.full((pad,), -1)]).astype(jnp.int32)
                new_cache = RingKVCache(kc, vc, pos_arr,
                                        jnp.asarray(S, jnp.int32)
                                        + 0 * cache.length)
                # attention itself runs over the full in-context k/v
            else:
                slot = cache.length % W
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, k.astype(cache.k.dtype), slot, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, v.astype(cache.v.dtype), slot, axis=1)
                pos_arr = jax.lax.dynamic_update_slice_in_dim(
                    cache.pos, cache.length[None].astype(jnp.int32), slot,
                    axis=0)
                new_cache = RingKVCache(kc, vc, pos_arr, cache.length + 1)
                k, v = kc, vc
                k_positions = pos_arr
                q_offset = cache.length
                kv_len = cache.length + 1   # slots hold ABSOLUTE positions
        else:
            start = cache.length
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), start, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), start, axis=1)
            new_cache = KVCache(kc, vc, start + S)
            k, v = kc, vc
            kv_len = start + S
            q_offset = start

    out = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset, kv_len=kv_len,
                              k_positions=k_positions, chunk_k=chunk_k)
    if pad_rep is not None:                 # drop the padded q heads
        K_, rep, rep_pad = pad_rep
        out = out.reshape(B, S, K_, rep_pad, hd)[:, :, :, :rep]
        out = out.reshape(B, S, H, hd)
    out = out.reshape(B, S, H * hd)
    if head_tp:
        out = constrain(out, "batch", None, "model")
    y = out @ p["wo"]
    y = constrain(y, "batch", None, None)
    return y, new_cache
