"""Shared layer primitives: norms, rotary embeddings (incl. M-RoPE), MLPs.

Pure functions over explicit param dicts. Initializers take a PRNG key and
return pytrees; `abstract=True` returns ShapeDtypeStructs (for dry-run /
eval_shape use without allocating 400B parameters).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def _make(key, shape, dtype, scale, abstract):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    if scale == 0.0:
        return jnp.zeros(shape, dtype)
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def dense_init(key, shape, dtype, abstract=False, scale=1.0):
    return _make(key, shape, dtype, scale, abstract)


def zeros_init(_key, shape, dtype, abstract=False):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype, abstract=False):
    if abstract:
        return jax.ShapeDtypeStruct(shape, dtype)
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def norm_init(key, cfg, abstract=False):
    if cfg.norm == "rms":
        return {"scale": zeros_init(key, (cfg.d_model,), jnp.float32, abstract)}
    return {"scale": ones_init(key, (cfg.d_model,), jnp.float32, abstract),
            "b": zeros_init(key, (cfg.d_model,), jnp.float32, abstract)}


def apply_norm(x, p, cfg):
    if cfg.norm == "rms":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["b"])


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + qwen2-vl M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Tuple[int, ...] = ()) -> jnp.ndarray:
    """x: (B, S, H, hd). positions: (B, S) or (B, 3, S) for M-RoPE.

    M-RoPE (Qwen2-VL, arXiv:2409.12191): the hd/2 frequency slots are split
    into (t, h, w) sections, each rotated by its own position stream. For pure
    text the three streams coincide and M-RoPE == RoPE.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                     # (hd/2,)
    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (B, 3, S) positions"
        assert sum(mrope_sections) == hd // 2, "M-RoPE sections must cover hd/2"
        sec = jnp.concatenate([
            jnp.full((n,), i, jnp.int32)
            for i, n in enumerate(mrope_sections)])    # (hd/2,) slot -> stream
        pos = positions.astype(jnp.float32)[:, sec, :]  # (B, hd/2, S)
        angles = pos.transpose(0, 2, 1) * freqs[None, None, :]  # (B,S,hd/2)
        angles = angles[:, :, None, :]                 # (B,S,1,hd/2)
    else:
        if positions.ndim == 3:
            positions = positions[:, 0]
        angles = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,hd/2)
        angles = angles[:, :, None, :]                 # (B,S,1,hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg, d_ff: Optional[int] = None, abstract=False):
    d_ff = d_ff or cfg.d_ff
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    p = {"w_in": dense_init(ks[0], (cfg.d_model, d_ff), dtype, abstract),
         "w_out": dense_init(ks[1], (d_ff, cfg.d_model), dtype, abstract)}
    if cfg.act in ("silu", "gelu"):                    # gated variants
        p["w_gate"] = dense_init(ks[2], (cfg.d_model, d_ff), dtype, abstract)
    return p


def apply_mlp(x, p, cfg):
    from repro.distributed.sharding import constrain
    h = x @ p["w_in"]
    if "w_gate" in p:
        g = x @ p["w_gate"]
        g = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g)
        h = g * h
    elif cfg.act == "gelu_mlp":
        h = jax.nn.gelu(h)
    elif cfg.act == "softsign":
        h = jax.nn.soft_sign(h)
    h = constrain(h, "batch", None, "model")
    out = h @ p["w_out"]
    return constrain(out, "batch", None, None)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
