"""Model assembly: segment-scanned layer stacks for all assigned families.

A model is a list of SEGMENTS; each segment is `count` repetitions of one
super-block layout, with params stacked on a leading (count, ...) dim and
applied via lax.scan (small HLO, FSDP-friendly). Super-block kinds:

  dense      attn(+window) + mlp                 (minicpm, granite, tinyllama,
                                                  qwen2-vl, llama4-dense pos)
  gemma      `global_every-1` local-window attn layers + 1 global attn layer
  moe        attn + MoE-ffn                      (qwen3: every layer)
  moe_pair   dense layer then MoE layer          (llama4: 1:1 interleave)
  mamba      Mamba-2 SSD block                   (mamba2)
  zamba      `shared_attn_every` mamba layers + 1 SHARED attn+mlp block
             (weights shared across all invocations — stored once outside the
             scan stack)
  enc / dec  whisper encoder (bidir, no rope) / decoder (self + cross attn)

Caches mirror the segment structure with the same leading stack dims, so
decode scans carry (hidden, per-layer-cache) pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention, layers, moe as moe_mod, ssm as ssm_mod
from repro.models.attention import KVCache, RingKVCache

PyTree = Any


class Segment(NamedTuple):
    kind: str
    count: int


# ---------------------------------------------------------------------------
# Segment plans
# ---------------------------------------------------------------------------

def segment_plan(cfg: ModelConfig) -> List[Segment]:
    if cfg.family == "encdec":
        return [Segment("enc", cfg.n_encoder_layers),
                Segment("dec", cfg.n_layers)]
    if cfg.family == "ssm":
        return [Segment("mamba", cfg.n_layers)]
    if cfg.family == "hybrid":
        k = cfg.shared_attn_every
        n_groups, rem = divmod(cfg.n_layers, k)
        plan = [Segment("zamba", n_groups)]
        if rem:
            plan.append(Segment("mamba", rem))
        return plan
    if cfg.moe.n_experts > 0:
        if cfg.moe.moe_every == 1:
            return [Segment("moe", cfg.n_layers)]
        assert cfg.moe.moe_every == 2
        n_pairs, rem = divmod(cfg.n_layers, 2)
        plan = [Segment("moe_pair", n_pairs)]
        if rem:
            plan.append(Segment("dense", rem))
        return plan
    if cfg.global_every > 0:
        n_groups, rem = divmod(cfg.n_layers, cfg.global_every)
        plan = [Segment("gemma", n_groups)]
        if rem:
            plan.append(Segment("dense_local", rem))
        return plan
    return [Segment("dense", cfg.n_layers)]


# ---------------------------------------------------------------------------
# Per-kind block init
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str, abstract: bool) -> PyTree:
    ks = jax.random.split(key, 12)
    if kind in ("dense", "dense_local"):
        return {"ln1": layers.norm_init(ks[0], cfg, abstract),
                "attn": attention.attn_init(ks[1], cfg, abstract=abstract),
                "ln2": layers.norm_init(ks[2], cfg, abstract),
                "mlp": layers.mlp_init(ks[3], cfg, abstract=abstract)}
    if kind == "gemma":
        k_loc = cfg.global_every - 1
        locals_ = _stacked_init(
            lambda k: _block_init(k, cfg, "dense_local", abstract),
            ks[0], k_loc, abstract)
        glob = _block_init(ks[1], cfg, "dense", abstract)
        return {"local": locals_, "global": glob}
    if kind == "moe":
        return {"ln1": layers.norm_init(ks[0], cfg, abstract),
                "attn": attention.attn_init(ks[1], cfg, abstract=abstract),
                "ln2": layers.norm_init(ks[2], cfg, abstract),
                "moe": moe_mod.moe_init(ks[3], cfg, abstract)}
    if kind == "moe_pair":
        dense_cfg = dataclasses.replace(cfg, d_ff=cfg.d_ff)
        return {"dense": _block_init(ks[0], dense_cfg, "dense", abstract),
                "moe": _block_init(ks[1], cfg, "moe", abstract)}
    if kind == "mamba":
        return {"ln": layers.norm_init(ks[0], cfg, abstract),
                "ssm": ssm_mod.ssm_init(ks[1], cfg, abstract)}
    if kind == "zamba":
        k_m = cfg.shared_attn_every
        return {"mamba": _stacked_init(
            lambda k: _block_init(k, cfg, "mamba", abstract),
            ks[0], k_m, abstract)}
    if kind == "enc":
        return {"ln1": layers.norm_init(ks[0], cfg, abstract),
                "attn": attention.attn_init(ks[1], cfg, abstract=abstract),
                "ln2": layers.norm_init(ks[2], cfg, abstract),
                "mlp": layers.mlp_init(ks[3], cfg, abstract=abstract)}
    if kind == "dec":
        return {"ln1": layers.norm_init(ks[0], cfg, abstract),
                "self_attn": attention.attn_init(ks[1], cfg, abstract=abstract),
                "ln_x": layers.norm_init(ks[2], cfg, abstract),
                "cross_attn": attention.attn_init(ks[3], cfg, abstract=abstract),
                "ln2": layers.norm_init(ks[4], cfg, abstract),
                "mlp": layers.mlp_init(ks[5], cfg, abstract=abstract)}
    raise ValueError(f"unknown block kind {kind!r}")


def _stacked_init(fn, key, count: int, abstract: bool) -> PyTree:
    if abstract:
        one = fn(key)
        return jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct((count,) + tuple(l.shape), l.dtype),
            one)
    keys = jax.random.split(key, count)
    return jax.vmap(fn)(keys)


def init_params(cfg: ModelConfig, key=None, abstract: bool = False) -> PyTree:
    key = key if key is not None else jax.random.PRNGKey(0)
    plan = segment_plan(cfg)
    ks = jax.random.split(key, len(plan) + 4)
    dtype = jnp.dtype(cfg.dtype)
    params: Dict[str, PyTree] = {
        "emb": layers.dense_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype,
                                 abstract),
        "final_norm": layers.norm_init(ks[1], cfg, abstract),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = layers.dense_init(
            ks[2], (cfg.padded_vocab, cfg.d_model), dtype, abstract)
    if cfg.learned_pos_emb:
        params["pos_emb"] = layers.dense_init(
            ks[2], (cfg.max_seq_len, cfg.d_model), dtype, abstract)
        if cfg.family == "encdec":
            params["enc_pos_emb"] = layers.dense_init(
                ks[3], (cfg.encoder_seq_len, cfg.d_model), dtype, abstract)
    if cfg.family == "hybrid":
        params["shared_block"] = _block_init(ks[3], cfg, "dense", abstract)
    for i, seg in enumerate(plan):
        params[f"seg{i}"] = _stacked_init(
            lambda k, kind=seg.kind: _block_init(k, cfg, kind, abstract),
            ks[4 + i], seg.count, abstract)
    return params


def param_stack_dims(cfg: ModelConfig, params: Optional[PyTree] = None
                     ) -> PyTree:
    """Pytree of ints mirroring `init_params`: how many leading stack axes
    each leaf carries. STRUCTURAL — derived from the segment plan + block
    layout (the same source of truth that created the stacking via
    `_stacked_init`), not from substrings of the flattened path. Consumed by
    core/leafplan.py: the paper's DMD is per-LAYER, so these axes are batch
    dims for the Gram/coefficient math.

      * every ``seg{i}`` subtree is scanned -> 1 stack axis;
      * the gemma super-block's ``local`` sub-stack and the zamba
        super-block's ``mamba`` sub-stack add a second;
      * everything outside segments (embeddings, final norm, zamba's shared
        attention block — stored once, re-applied) has none.
    """
    params = params if params is not None else init_params(cfg, abstract=True)
    plan = segment_plan(cfg)

    def const(tree, n):
        return jax.tree_util.tree_map(lambda _: n, tree)

    def seg_dims(kind: str, subtree: PyTree) -> PyTree:
        if kind == "gemma":
            return {"local": const(subtree["local"], 2),
                    "global": const(subtree["global"], 1)}
        if kind == "zamba":
            return {"mamba": const(subtree["mamba"], 2)}
        return const(subtree, 1)

    out = {}
    for key, sub in params.items():
        if key.startswith("seg") and key[3:].isdigit():
            out[key] = seg_dims(plan[int(key[3:])].kind, sub)
        else:
            out[key] = const(sub, 0)
    return out


# ---------------------------------------------------------------------------
# Per-kind block apply (single layer of a segment)
# ---------------------------------------------------------------------------

def _apply_dense(x, p, cfg, *, positions, window, cache, head_tp, chunk_k,
                 causal=True, use_rope=True, moe_ffn=False, key=None,
                 pad_heads_to=0):
    h = layers.apply_norm(x, p["ln1"], cfg)
    a, new_cache = attention.attend(
        h, p["attn"], cfg, positions=positions, causal=causal, window=window,
        cache=cache, head_tp=head_tp, use_rope=use_rope, chunk_k=chunk_k,
        pad_heads_to=pad_heads_to)
    x = x + a
    h = layers.apply_norm(x, p["ln2"], cfg)
    if moe_ffn:
        f, aux = moe_mod.apply_moe(h, p["moe"], cfg, key=key)
    else:
        f, aux = layers.apply_mlp(h, p["mlp"], cfg), 0.0
    return x + f, new_cache, aux


def _apply_mamba(x, p, cfg, *, state):
    h = layers.apply_norm(x, p["ln"], cfg)
    y, new_state = ssm_mod.apply_ssm(h, p["ssm"], cfg, state=state)
    return x + y, new_state


def _maybe_scan(body, carry, xs_tree, count, unroll):
    if not unroll:
        return jax.lax.scan(body, carry, xs_tree)
    ys = []
    for i in range(count):
        xs_i = jax.tree_util.tree_map(lambda l: l[i], xs_tree)
        carry, y = body(carry, xs_i)
        ys.append(y)
    try:
        ys_stacked = jax.tree_util.tree_map(lambda *ls: jnp.stack(ls), *ys)
    except Exception:
        ys_stacked = None
    return carry, ys_stacked


class _Ctx(NamedTuple):
    """Static per-call context threaded through segment scans."""
    cfg: ModelConfig
    head_tp: bool
    chunk_k: int
    mode: str                 # "train" | "prefill" | "decode"
    unroll: bool = False      # unroll inner stacks (roofline accounting)
    pad_heads_to: int = 0     # padded head-TP (see attention.attend)


def _moe_block_apply(x, p, ctx, positions, cache):
    h = layers.apply_norm(x, p["ln1"], ctx.cfg)
    a, new_cache = attention.attend(
        h, p["attn"], ctx.cfg, positions=positions, causal=True, window=0,
        cache=cache, head_tp=ctx.head_tp, chunk_k=ctx.chunk_k,
        pad_heads_to=ctx.pad_heads_to)
    x = x + a
    h = layers.apply_norm(x, p["ln2"], ctx.cfg)
    f, aux = moe_mod.apply_moe(h, p["moe"], ctx.cfg)
    return x + f, new_cache, aux


def _apply_block(kind: str, x, p, ctx: _Ctx, positions, cache,
                 shared_block=None, enc_kv=None):
    """One super-block. Returns (x, new_cache, aux_loss)."""
    cfg = ctx.cfg
    aux = jnp.asarray(0.0, jnp.float32)
    if kind == "dense":
        x, nc, _ = _apply_dense(x, p, cfg, positions=positions, window=0,
                                cache=cache, head_tp=ctx.head_tp,
                                chunk_k=ctx.chunk_k,
                                pad_heads_to=ctx.pad_heads_to)
        return x, nc, aux
    if kind == "dense_local":
        x, nc, _ = _apply_dense(x, p, cfg, positions=positions,
                                window=cfg.sliding_window, cache=cache,
                                head_tp=ctx.head_tp, chunk_k=ctx.chunk_k,
                                pad_heads_to=ctx.pad_heads_to)
        return x, nc, aux
    if kind == "gemma":
        loc_caches = cache["local"] if cache is not None else None
        new_loc = []
        k_loc = cfg.global_every - 1

        def loc_body(carry, xs):
            h = carry
            lp, lc = xs
            h, nc, _ = _apply_dense(h, lp, cfg, positions=positions,
                                    window=cfg.sliding_window, cache=lc,
                                    head_tp=ctx.head_tp, chunk_k=ctx.chunk_k)
            return h, nc

        if loc_caches is None:
            x, _ = _maybe_scan(
                lambda c, lp: (loc_body(c, (lp, None))[0], 0.0),
                x, p["local"], k_loc, ctx.unroll)
            new_cache = None
            x, _, _ = _apply_dense(x, p["global"], cfg, positions=positions,
                                   window=0, cache=None, head_tp=ctx.head_tp,
                                   chunk_k=ctx.chunk_k)
        else:
            x, new_loc = _maybe_scan(loc_body, x, (p["local"], loc_caches),
                                     k_loc, ctx.unroll)
            x, new_glob, _ = _apply_dense(
                x, p["global"], cfg, positions=positions, window=0,
                cache=cache["global"], head_tp=ctx.head_tp, chunk_k=ctx.chunk_k)
            new_cache = {"local": new_loc, "global": new_glob}
        return x, new_cache, aux
    if kind == "moe":
        return _moe_block_apply(x, p, ctx, positions, cache)
    if kind == "moe_pair":
        dc = cache["dense"] if cache is not None else None
        mc = cache["moe"] if cache is not None else None
        x, ndc, _ = _apply_dense(x, p["dense"], cfg, positions=positions,
                                 window=0, cache=dc, head_tp=ctx.head_tp,
                                 chunk_k=ctx.chunk_k)
        x, nmc, aux = _moe_block_apply(x, p["moe"], ctx, positions, mc)
        new_cache = ({"dense": ndc, "moe": nmc}
                     if cache is not None else None)
        return x, new_cache, aux
    if kind == "mamba":
        x, ns = _apply_mamba(x, p, cfg, state=cache)
        return x, ns, aux
    if kind == "zamba":
        m_caches = cache["mamba"] if cache is not None else None

        def m_body(carry, xs):
            h = carry
            mp, mc = xs
            h, ns = _apply_mamba(h, mp, cfg, state=mc)
            return h, ns

        if m_caches is None:
            x, _ = _maybe_scan(lambda c, mp: (m_body(c, (mp, None))[0], 0.0),
                               x, p["mamba"], cfg.shared_attn_every,
                               ctx.unroll)
            new_m = None
        else:
            x, new_m = _maybe_scan(m_body, x, (p["mamba"], m_caches),
                                   cfg.shared_attn_every, ctx.unroll)
        sc = cache["shared"] if cache is not None else None
        x, nsc, _ = _apply_dense(x, shared_block, cfg, positions=positions,
                                 window=0, cache=sc, head_tp=ctx.head_tp,
                                 chunk_k=ctx.chunk_k)
        new_cache = ({"mamba": new_m, "shared": nsc}
                     if cache is not None else None)
        return x, new_cache, aux
    if kind == "enc":
        x, _, _ = _apply_dense(x, p, cfg, positions=positions, window=0,
                               cache=None, head_tp=ctx.head_tp,
                               chunk_k=ctx.chunk_k, causal=False,
                               use_rope=False)
        return x, None, aux
    if kind == "dec":
        h = layers.apply_norm(x, p["ln1"], cfg)
        sc = cache["self"] if cache is not None else None
        a, new_sc = attention.attend(
            h, p["self_attn"], cfg, positions=positions, causal=True,
            cache=sc, head_tp=ctx.head_tp, use_rope=False,
            chunk_k=ctx.chunk_k)
        x = x + a
        h = layers.apply_norm(x, p["ln_x"], cfg)
        if cache is not None and "cross_k" in cache:
            kv = (cache["cross_k"], cache["cross_v"])
        else:
            # per-layer cross KV from the encoder output
            B_, Se, _ = enc_kv.shape
            K_, hd_ = cfg.n_kv_heads, cfg.head_dim
            kv = ((enc_kv @ p["cross_attn"]["wk"]).reshape(B_, Se, K_, hd_),
                  (enc_kv @ p["cross_attn"]["wv"]).reshape(B_, Se, K_, hd_))
        a, _ = attention.attend(
            h, p["cross_attn"], cfg, positions=positions, causal=False,
            cache=None, head_tp=ctx.head_tp, use_rope=False, kv_override=kv,
            chunk_k=ctx.chunk_k)
        x = x + a
        h = layers.apply_norm(x, p["ln2"], cfg)
        x = x + layers.apply_mlp(h, p["mlp"], cfg)
        new_cache = None
        if cache is not None:
            new_cache = dict(cache)
            new_cache["self"] = new_sc
        return x, new_cache, aux
    raise ValueError(f"unknown block kind {kind!r}")


# ---------------------------------------------------------------------------
# Model wrapper
# ---------------------------------------------------------------------------

class LanguageModel:
    """Segment-scanned LM (decoder-only, enc-dec, ssm, hybrid, moe)."""

    def __init__(self, cfg: ModelConfig, *, head_tp: Optional[bool] = None,
                 chunk_k: int = 1024, remat: str = "none",
                 scan_layers: bool = True, pad_heads_to: int = 0):
        self.cfg = cfg
        self.plan = segment_plan(cfg)
        # head-TP needs q heads divisible by TP; kv handled separately.
        tp = 16
        self.head_tp = (cfg.n_heads % tp == 0) if head_tp is None else head_tp
        self.chunk_k = chunk_k
        self.remat = remat
        # scan_layers=False unrolls every layer stack in Python: used by the
        # roofline pass, where cost_analysis must see each layer's ops
        # (scan bodies are counted once regardless of trip count).
        self.scan_layers = scan_layers
        self.pad_heads_to = pad_heads_to

    def _seg_scan(self, body, carry, xs_tree, count: int):
        """lax.scan or Python unroll over a stacked segment."""
        if self.scan_layers:
            return jax.lax.scan(body, carry, xs_tree)
        ys = []
        for i in range(count):
            xs_i = jax.tree_util.tree_map(lambda l: l[i], xs_tree)
            carry, y = body(carry, xs_i)
            ys.append(y)
        try:
            ys_stacked = jax.tree_util.tree_map(
                lambda *ls: jnp.stack(ls), *ys) if ys else None
        except Exception:
            ys_stacked = None
        return carry, ys_stacked

    # -- init ---------------------------------------------------------------
    def init(self, key=None, abstract: bool = False) -> PyTree:
        return init_params(self.cfg, key, abstract)

    def param_stack_dims(self, params: Optional[PyTree] = None) -> PyTree:
        """Structural stack-axis counts per leaf (see module-level fn)."""
        return param_stack_dims(self.cfg, params)

    def param_count(self, params=None) -> int:
        params = params or self.init(abstract=True)
        return sum(int(jnp.prod(jnp.asarray(l.shape)))
                   for l in jax.tree_util.tree_leaves(params)
                   if hasattr(l, "shape"))

    # -- embedding / head -----------------------------------------------------
    def _embed(self, params, batch):
        cfg = self.cfg
        if "embeds" in batch:                     # stubbed frontend (audio/vlm)
            x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        else:
            tokens = batch["tokens"]
            x = jnp.take(params["emb"], tokens, axis=0)
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(x.dtype)
        if cfg.learned_pos_emb and "pos_emb" in params:
            pos = batch.get("positions")
            if pos is None:
                pos = jnp.arange(x.shape[1])[None, :]
            if pos.ndim == 3:
                pos = pos[:, 0]
            x = x + jnp.take(params["pos_emb"], pos, axis=0).astype(x.dtype)
        return constrain(x, "batch", None, None)

    def _head(self, params, x):
        cfg = self.cfg
        x = layers.apply_norm(x, params["final_norm"], cfg)
        table = params.get("lm_head", params["emb"])
        logits = jnp.einsum("bsd,vd->bsv", x, table).astype(jnp.float32)
        logits = layers.softcap(logits, cfg.logit_softcap)
        if cfg.padded_vocab != cfg.vocab_size:
            # mask padding rows out of softmax/argmax
            pad_mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(pad_mask[None, None, :], -1e30, logits)
        return constrain(logits, "batch", None, "model")

    def _positions(self, batch, length=None, S=1):
        cfg = self.cfg
        if "positions" in batch:
            return batch["positions"]
        if length is not None:
            pos = length + jnp.arange(S)[None, :]        # (1, S) broadcast
            B = (batch.get("tokens").shape[0]
                 if "tokens" in batch else batch["embeds"].shape[0])
            pos = jnp.broadcast_to(pos, (B, S))
        else:
            tk = batch["tokens"] if "tokens" in batch else batch["embeds"]
            B, S = tk.shape[0], tk.shape[1]
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        if cfg.mrope_sections:
            pos = jnp.broadcast_to(pos[:, None, :], (pos.shape[0], 3, S))
        return pos

    # -- encoder (whisper) ----------------------------------------------------
    def _encode(self, params, batch, ctx):
        cfg = self.cfg
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
        if "enc_pos_emb" in params:
            x = x + params["enc_pos_emb"][None, :x.shape[1]].astype(x.dtype)
        x = constrain(x, "batch", None, None)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1])[None, :],
                               (x.shape[0], x.shape[1]))
        seg_idx = 0          # encoder is always seg0
        p_seg = params[f"seg{seg_idx}"]

        def body(carry, lp):
            h, _, _ = _apply_block("enc", carry, lp, ctx, pos, None)
            return h, 0.0

        body = self._maybe_remat(body)
        x, _ = self._seg_scan(body, x, p_seg, self.plan[seg_idx].count)
        return layers.apply_norm(x, params["final_norm"], cfg) \
            if False else x

    def _maybe_remat(self, body):
        if self.remat in ("block", "full"):
            return jax.checkpoint(body)
        return body

    # -- forward (train / prefill without cache) ------------------------------
    def forward(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (logits, aux_loss)."""
        cfg = self.cfg
        ctx = _Ctx(cfg, self.head_tp, self.chunk_k, "train",
                   unroll=not self.scan_layers,
                   pad_heads_to=self.pad_heads_to)
        aux_total = jnp.asarray(0.0, jnp.float32)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch, ctx)
        x = self._embed(params, batch)
        pos = self._positions(batch)
        shared = params.get("shared_block")

        for i, seg in enumerate(self.plan):
            if cfg.family == "encdec" and seg.kind == "enc":
                continue                       # handled by _encode
            p_seg = params[f"seg{i}"]

            def body(carry, lp, kind=seg.kind):
                h, aux = carry
                h, _, a = _apply_block(kind, h, lp, ctx, pos, None,
                                       shared_block=shared, enc_kv=enc_out)
                return (h, aux + a), 0.0

            body = self._maybe_remat(body)
            (x, aux_total), _ = self._seg_scan(body, (x, aux_total), p_seg,
                                               seg.count)

        logits = self._head(params, x)
        return logits, aux_total

    def loss(self, params, batch) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        logits, aux = self.forward(params, batch)
        labels = batch.get("labels")
        if labels is None:
            labels = jnp.pad(batch["tokens"][:, 1:], ((0, 0), (0, 1)))
        ce = cross_entropy(logits, labels)
        return ce + aux, {"ce": ce, "aux": aux}

    # -- serving ---------------------------------------------------------------
    def init_cache(self, batch_size: int, s_max: int, *, abstract=False,
                   prefilled_to: int = 0) -> PyTree:
        """Cache pytree matching the segment plan. For dry-run decode cells we
        size caches at s_max and (abstractly) mark them filled."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        K, hd = cfg.n_kv_heads, cfg.head_dim
        caches = {}

        def full_cache():
            return attention.init_kv_cache(batch_size, s_max, K, hd, dtype,
                                           abstract)

        def ring_cache():
            return attention.init_ring_cache(batch_size, cfg.sliding_window,
                                             K, hd, dtype, abstract)

        def stack(tree, count):
            return jax.tree_util.tree_map(
                lambda l: (jax.ShapeDtypeStruct((count,) + tuple(l.shape),
                                                l.dtype) if abstract
                           else jnp.broadcast_to(l, (count,) + l.shape).copy()),
                tree)

        for i, seg in enumerate(self.plan):
            kind = seg.kind
            if kind in ("dense", "moe"):
                caches[f"seg{i}"] = stack(full_cache(), seg.count)
            elif kind == "dense_local":
                caches[f"seg{i}"] = stack(ring_cache(), seg.count)
            elif kind == "gemma":
                one = {"local": stack(ring_cache(), cfg.global_every - 1),
                       "global": full_cache()}
                caches[f"seg{i}"] = stack(one, seg.count)
            elif kind == "moe_pair":
                one = {"dense": full_cache(), "moe": full_cache()}
                caches[f"seg{i}"] = stack(one, seg.count)
            elif kind == "mamba":
                caches[f"seg{i}"] = stack(
                    ssm_mod.init_ssm_state(batch_size, cfg, dtype, abstract),
                    seg.count)
            elif kind == "zamba":
                one = {"mamba": stack(
                    ssm_mod.init_ssm_state(batch_size, cfg, dtype, abstract),
                    cfg.shared_attn_every),
                    "shared": full_cache()}
                caches[f"seg{i}"] = stack(one, seg.count)
            elif kind == "enc":
                continue
            elif kind == "dec":
                Se = cfg.encoder_seq_len
                ck = (jax.ShapeDtypeStruct((batch_size, Se, K, hd), dtype)
                      if abstract else
                      jnp.zeros((batch_size, Se, K, hd), dtype))
                one = {"self": full_cache(), "cross_k": ck, "cross_v": ck}
                caches[f"seg{i}"] = stack(one, seg.count)
        return caches

    def decode_step(self, params, batch, caches) -> Tuple[jnp.ndarray, PyTree]:
        """One-token step. batch: {"tokens": (B, 1)} (+ positions for mrope).
        caches: from init_cache / prefill. Returns (logits (B,1,V), caches)."""
        cfg = self.cfg
        ctx = _Ctx(cfg, self.head_tp, self.chunk_k, "decode",
                   unroll=not self.scan_layers)
        x = self._embed_decode(params, batch, caches)
        shared = params.get("shared_block")
        length = self._cache_length(caches)
        pos = self._positions(batch, length=length, S=x.shape[1])
        new_caches = {}
        for i, seg in enumerate(self.plan):
            if seg.kind == "enc":
                continue
            p_seg = params[f"seg{i}"]
            seg_cache = caches[f"seg{i}"]

            def body(carry, xs, kind=seg.kind):
                h = carry
                lp, lc = xs
                h, nc, _ = _apply_block(kind, h, lp, ctx, pos, lc,
                                        shared_block=shared)
                return h, nc

            x, new_caches[f"seg{i}"] = self._seg_scan(
                body, x, (p_seg, seg_cache), seg.count)
        logits = self._head(params, x)
        return logits, new_caches

    def _embed_decode(self, params, batch, caches):
        cfg = self.cfg
        if cfg.learned_pos_emb:
            length = self._cache_length(caches)
            tokens = batch["tokens"]
            x = jnp.take(params["emb"], tokens, axis=0)
            x = x * jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)).astype(x.dtype)
            pos_row = jax.lax.dynamic_slice_in_dim(
                params["pos_emb"], length % cfg.max_seq_len, 1, axis=0)
            return constrain(x + pos_row[None].astype(x.dtype),
                             "batch", None, None)
        return self._embed(params, batch)

    def _cache_length(self, caches):
        for leaf in jax.tree_util.tree_leaves(caches):
            pass
        # find any KVCache/RingKVCache length: traverse structure
        def find(node):
            if isinstance(node, (KVCache, RingKVCache)):
                lf = node.length
                return lf.reshape(-1)[0] if lf.ndim else lf
            if isinstance(node, dict):
                for v in node.values():
                    r = find(v)
                    if r is not None:
                        return r
            if isinstance(node, (list, tuple)):
                for v in node:
                    r = find(v)
                    if r is not None:
                        return r
            return None
        r = find(caches)
        return r if r is not None else jnp.zeros((), jnp.int32)

    def prefill(self, params, batch, caches) -> Tuple[jnp.ndarray, PyTree]:
        """Prompt pass that fills caches. batch: {"tokens": (B, S)}."""
        cfg = self.cfg
        ctx = _Ctx(cfg, self.head_tp, self.chunk_k, "prefill",
                   unroll=not self.scan_layers,
                   pad_heads_to=self.pad_heads_to)
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, batch, ctx)
        x = self._embed(params, batch)
        pos = self._positions(batch)
        shared = params.get("shared_block")
        new_caches = {}
        for i, seg in enumerate(self.plan):
            if seg.kind == "enc":
                continue
            p_seg = params[f"seg{i}"]
            seg_cache = caches[f"seg{i}"]
            if seg.kind == "dec" and enc_out is not None:
                # store per-layer cross KV alongside self cache
                K, hd = cfg.n_kv_heads, cfg.head_dim
                B_, Se, _ = enc_out.shape

                def body(carry, xs):
                    h = carry
                    lp, lc = xs
                    ck = (enc_out @ lp["cross_attn"]["wk"]).reshape(B_, Se, K, hd)
                    cv = (enc_out @ lp["cross_attn"]["wv"]).reshape(B_, Se, K, hd)
                    lc = dict(lc)
                    lc["cross_k"], lc["cross_v"] = ck, cv
                    h, nc, _ = _apply_block("dec", h, lp, ctx, pos, lc)
                    return h, nc
            else:
                def body(carry, xs, kind=seg.kind):
                    h = carry
                    lp, lc = xs
                    h, nc, _ = _apply_block(kind, h, lp, ctx, pos, lc,
                                            shared_block=shared)
                    return h, nc
            body = self._maybe_remat(body)
            x, new_caches[f"seg{i}"] = self._seg_scan(
                body, x, (p_seg, seg_cache), seg.count)
        logits = self._head(params, x[:, -1:])
        return logits, new_caches


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token CE. logits (B,S,V) fp32 (possibly vocab-sharded);
    labels (B,S). logsumexp reduces over the sharded vocab dim -> psum."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - picked
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def make_model(cfg: ModelConfig, **kw) -> LanguageModel:
    return LanguageModel(cfg, **kw)
