"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Chunked SSD algorithm in pure jnp: ONE lax.scan over sequence chunks carries
the running state h (B, H, P, N) and computes the intra-chunk quadratic part
per chunk — the (Q, Q) decay-masked score matrix exists for a single chunk
only (peak B*Q*Q*H_local, not B*S*S*H). Decode keeps (conv_state, ssm_state)
and costs O(1) per token — why mamba2/zamba2 run the long_500k cell.

Sharding: the inner dim d_inner = H*P shards over "model" on HEAD boundaries
(d_inner/tp must be a multiple of P; holds for all assigned configs: H=80,
tp=16 -> 5 heads/shard). dt and A are per-head (H % tp == 0). B/C live per
*group* and are consumed by every head, so they stay replicated across
"model" (G*N is tiny). Projections are stored per-component (z/x/B/C/dt
separate matrices) so no slice ever crosses a shard boundary.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def ssm_init(key, cfg, abstract=False):
    s = cfg.ssm
    dtype = jnp.dtype(cfg.dtype)
    d_inner, n_heads = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    ks = jax.random.split(key, 9)
    return {
        "in_proj": {
            "z": layers.dense_init(ks[0], (cfg.d_model, d_inner), dtype, abstract),
            "x": layers.dense_init(ks[1], (cfg.d_model, d_inner), dtype, abstract),
            "B": layers.dense_init(ks[2], (cfg.d_model, gn), dtype, abstract),
            "C": layers.dense_init(ks[3], (cfg.d_model, gn), dtype, abstract),
            "dt": layers.dense_init(ks[4], (cfg.d_model, n_heads), dtype, abstract),
        },
        "conv_w": {
            "x": layers.dense_init(ks[5], (s.conv_width, d_inner), dtype,
                                   abstract, scale=0.5),
            "B": layers.dense_init(ks[6], (s.conv_width, gn), dtype,
                                   abstract, scale=0.5),
            "C": layers.dense_init(ks[7], (s.conv_width, gn), dtype,
                                   abstract, scale=0.5),
        },
        "out_proj": layers.dense_init(ks[8], (d_inner, cfg.d_model), dtype,
                                      abstract),
        "A_log": layers.zeros_init(None, (n_heads,), jnp.float32, abstract),
        "dt_bias": layers.zeros_init(None, (n_heads,), jnp.float32, abstract),
        "skip_d": layers.zeros_init(None, (n_heads,), jnp.float32, abstract),
        "norm_scale": layers.zeros_init(None, (d_inner,), jnp.float32, abstract),
    }


class SSMState(NamedTuple):
    conv_x: jnp.ndarray   # (B, W-1, d_inner)
    conv_B: jnp.ndarray   # (B, W-1, G*N)
    conv_C: jnp.ndarray   # (B, W-1, G*N)
    h: jnp.ndarray        # (B, H, P, N) running SSD state (fp32)


def init_ssm_state(batch, cfg, dtype, abstract=False):
    s = cfg.ssm
    d_inner, n_heads = ssm_dims(cfg)
    gn = s.n_groups * s.state_dim
    w1 = s.conv_width - 1
    shapes = [(batch, w1, d_inner), (batch, w1, gn), (batch, w1, gn),
              (batch, n_heads, s.head_dim, s.state_dim)]
    dtypes = [dtype, dtype, dtype, jnp.float32]
    if abstract:
        return SSMState(*[jax.ShapeDtypeStruct(sh, dt)
                          for sh, dt in zip(shapes, dtypes)])
    return SSMState(*[jnp.zeros(sh, dt) for sh, dt in zip(shapes, dtypes)])


def _causal_conv(u, w, state=None):
    """Depthwise causal conv. u: (B, S, C), w: (W, C). Returns out, new_state."""
    W = w.shape[0]
    if state is not None:
        ctx = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    else:
        ctx = jnp.pad(u, ((0, 0), (W - 1, 0), (0, 0)))
    S = u.shape[1]
    out = sum(ctx[:, i:i + S] * w[i][None, None, :] for i in range(W))
    new_state = ctx[:, -(W - 1):] if W > 1 else ctx[:, :0]
    return jax.nn.silu(out), new_state


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, h0=None):
    """Single-scan chunked SSD.

    xh: (B, S, H, P); dt: (B, S, H) (positive); A: (H,) negative;
    Bm/Cm: (B, S, G, N). Returns y (B, S, H, P), final state (B, H, P, N).
    S must be divisible by the effective chunk (we clamp chunk to S).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Q = min(chunk, S)
    assert S % Q == 0, f"seq {S} not divisible by chunk {Q}"
    nc = S // Q

    def chunkify(t):
        return t.reshape((Bsz, nc, Q) + t.shape[2:]).transpose(
            (1, 0, 2) + tuple(range(3, t.ndim + 1)))

    xc, dtc, Bc, Cc = map(chunkify, (xh, dt, Bm, Cm))   # leading nc

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def scan_fn(h, inp):
        xq, dtq, Bq, Cq = inp                            # (B,Q,H,P) etc.
        dA = dtq.astype(jnp.float32) * A[None, None, :]  # (B,Q,H) <= 0
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1, :]                            # (B,H)
        Bg = jnp.repeat(Bq, rep, axis=2) if rep > 1 else Bq   # (B,Q,H,N)
        Cg = jnp.repeat(Cq, rep, axis=2) if rep > 1 else Cq
        xdt = xq.astype(jnp.float32) * dtq[..., None]    # (B,Q,H,P)

        # intra-chunk: y_i += sum_{j<=i} C_i.B_j exp(cum_i-cum_j) xdt_j
        diff = cum[:, :, None, :] - cum[:, None, :, :]   # (B,Q,Q,H)
        L = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bqhs,bkhs->bqkh", Cg.astype(jnp.float32),
                            Bg.astype(jnp.float32))
        y_intra = jnp.einsum("bqkh,bkhp->bqhp", scores * L, xdt)

        # inter-chunk: y_i += C_i exp(cum_i) h_prev
        y_inter = jnp.einsum("bqhs,bhps->bqhp",
                             Cg.astype(jnp.float32) * jnp.exp(cum)[..., None],
                             h)

        # state update: h <- exp(total) h + sum_j exp(total - cum_j) B_j xdt_j
        decay_to_end = jnp.exp(total[:, None, :] - cum)  # (B,Q,H)
        contrib = jnp.einsum("bqhs,bqhp->bhps",
                             Bg.astype(jnp.float32) * decay_to_end[..., None],
                             xdt)
        h_new = h * jnp.exp(total)[:, :, None, None] + contrib
        return h_new, y_intra + y_inter

    h_final, yc = jax.lax.scan(scan_fn, h0, (xc, dtc, Bc, Cc))
    y = yc.transpose(1, 0, 2, 3, 4).reshape(Bsz, S, H, P)
    return y, h_final


def apply_ssm(x, p, cfg, *, state: Optional[SSMState] = None
              ) -> Tuple[jnp.ndarray, Optional[SSMState]]:
    """Mamba-2 block. x: (B, S, D). state: decode-mode recurrent state."""
    s = cfg.ssm
    Bsz, S, D = x.shape
    d_inner, H = ssm_dims(cfg)
    P, G, N = s.head_dim, s.n_groups, s.state_dim

    ip = p["in_proj"]
    z = x @ ip["z"]
    xs = constrain(x @ ip["x"], "batch", None, "model")
    Bs = constrain(x @ ip["B"], "batch", None, None)
    Cs = constrain(x @ ip["C"], "batch", None, None)
    dt = constrain(x @ ip["dt"], "batch", None, "model")

    xs, new_cx = _causal_conv(xs, p["conv_w"]["x"],
                              state.conv_x if state else None)
    Bs, new_cb = _causal_conv(Bs, p["conv_w"]["B"],
                              state.conv_B if state else None)
    Cs, new_cc = _causal_conv(Cs, p["conv_w"]["C"],
                              state.conv_C if state else None)

    xh = xs.reshape(Bsz, S, H, P)
    Bm = Bs.reshape(Bsz, S, G, N)
    Cm = Cs.reshape(Bsz, S, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["A_log"])                                     # (H,) < 0

    if S == 1 and state is not None:
        # O(1) decode: h <- exp(dt A) h + dt B x ; y = C h
        rep = H // G
        dA = jnp.exp(dt[:, 0, :] * A)                            # (B,H)
        Bg = jnp.repeat(Bm[:, 0], rep, axis=1) if rep > 1 else Bm[:, 0]
        Cg = jnp.repeat(Cm[:, 0], rep, axis=1) if rep > 1 else Cm[:, 0]
        xdt = xh[:, 0].astype(jnp.float32) * dt[:, 0, :, None]   # (B,H,P)
        h_new = (state.h * dA[:, :, None, None]
                 + jnp.einsum("bhs,bhp->bhps", Bg.astype(jnp.float32), xdt))
        y = jnp.einsum("bhs,bhps->bhp", Cg.astype(jnp.float32), h_new)
        y = y[:, None]                                           # (B,1,H,P)
        h_final = h_new
    else:
        y, h_final = ssd_chunked(xh, dt, A, Bm, Cm, s.chunk,
                                 state.h if state is not None else None)

    y = y + xh.astype(jnp.float32) * p["skip_d"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner)
    y = layers.rms_norm(y.astype(x.dtype), p["norm_scale"])
    y = y * jax.nn.silu(z)
    y = constrain(y, "batch", None, "model")
    out = y @ p["out_proj"]
    out = constrain(out, "batch", None, None)
    new_state = (SSMState(new_cx, new_cb, new_cc, h_final)
                 if state is not None else None)
    return out.astype(x.dtype), new_state
