"""Pure-numpy float64 reference DMD (classic direct-SVD formulation).

Oracle for tests: no Gram trick — the textbook algorithm (Schmid 2010 / paper
Algorithm 1) with explicit SVD of the snapshot matrix, used to validate the
jitted Gram-form implementation in repro.core.dmd. Options (anchor / affine /
trust_region / relax) mirror dmd.dmd_coefficients; the affine augmentation is
materialized as an explicit constant column here (the jitted version does it
in Gram space as a rank-one update).
"""
from __future__ import annotations

import numpy as np


def dmd_extrapolate_ref(snapshots: np.ndarray, s: int, tol: float = 1e-10,
                        mode: str = "matpow", clamp_eigs: bool = False,
                        keep_residual: bool = False, anchor: str = "none",
                        affine: bool = False, trust_region: float = 0.0,
                        relax: float = 1.0) -> np.ndarray:
    """snapshots: (m, n) rows=time. Returns extrapolated weights (n,)."""
    S_raw = np.asarray(snapshots, np.float64)
    m = S_raw.shape[0]
    if anchor == "first":
        shift = S_raw[0].copy()
    elif anchor == "mean":
        shift = S_raw.mean(axis=0)
    else:
        shift = np.zeros(S_raw.shape[1])
    D = S_raw - shift
    if affine:
        gamma = np.sqrt(max(np.mean(np.sum(D * D, axis=1)), 1e-300))
        D_aug = np.concatenate([D, np.full((m, 1), gamma)], axis=1)
    else:
        D_aug = D

    W = D_aug.T                           # n(+1) x m, columns = snapshots
    X, Z = W[:, :-1], W[:, 1:]
    U, sig, Vt = np.linalg.svd(X, full_matrices=False)
    mask = sig > tol * max(sig.max(), 1e-300)
    r = int(mask.sum())
    U, sig, Vt = U[:, :r], sig[:r], Vt[:r]
    atilde = U.T @ Z @ Vt.T @ np.diag(1.0 / sig)
    d_last = W[:, -1]
    b = U.T @ d_last
    if mode == "matpow":
        y = np.linalg.matrix_power(atilde, s) @ b
    else:
        lam, Y = np.linalg.eig(atilde)
        if clamp_eigs:
            mag = np.abs(lam)
            lam = np.where(mag > 1.0, lam / np.maximum(mag, 1e-300), lam)
        y = np.real(Y @ np.diag(lam ** s) @ np.linalg.solve(Y, b.astype(complex)))

    # Convert to snapshot-row coefficients (matches the Gram-form impl):
    # d_dmd = U y = X V Sigma^-1 y = D[:-1]^T c_main
    c = np.zeros(m)
    c[:-1] = Vt.T @ (y / sig)
    if keep_residual:
        cp = np.zeros(m)
        cp[:-1] = Vt.T @ ((U.T @ d_last) / sig)
        c = c - cp
        c[-1] += 1.0

    e_last = np.zeros(m)
    e_last[-1] = 1.0
    if trust_region and trust_region > 0:
        w_dyn = D.T @ c                      # original (unaugmented) coords
        jump = np.linalg.norm(w_dyn - D[-1])
        steps = np.linalg.norm(np.diff(D, axis=0), axis=1)
        radius = trust_region * s * np.sqrt(np.mean(steps ** 2))
        if not np.all(np.isfinite(c)):
            c = e_last.copy()
        else:
            scale = min(1.0, radius / max(jump, 1e-300))
            c = scale * c + (1.0 - scale) * e_last

    # Fold anchor into coefficients: w = shift + D^T c = S^T c_folded
    if anchor == "first":
        c = c.copy()
        c[0] += 1.0 - c.sum()
    elif anchor == "mean":
        c = c + (1.0 - c.sum()) / m

    c = relax * c + (1.0 - relax) * e_last
    return S_raw.T @ c
