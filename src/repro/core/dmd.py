"""Dynamic Mode Decomposition of weight trajectories (the paper's core).

Math (paper §3, re-derived in Gram form — see DESIGN.md §2):

With snapshots stored row-major ``S in R^{m x n}`` (row t = flattened weights
after optimizer step t) and ``W = S^T`` the paper's column snapshot matrix:

    X = W[:, :-1]   (lagged),   Z = W[:, 1:]   (forwarded),   Z = A X
    X = U Sigma V^T          (thin SVD via the Gram trick)
    X^T X = G[:-1, :-1],     X^T Z = G[:-1, 1:],   where  G = S S^T  (m x m)
    Atilde = Sigma^-1 V^T (X^T Z) V Sigma^-1                  (reduced Koopman)
    w(m-1+s) = U Atilde^s U^T w_last
             = S[:-1]^T . ( V Sigma^-1 Atilde^s Sigma^-1 V^T (X^T w_last) )
             = S^T c                       with  X^T w_last = G[:-1, -1]

Everything except the two tall-skinny passes (Gram ``S S^T`` and combine
``S^T c`` — Pallas kernels in repro.kernels) is (m x m) algebra computed from
``G`` alone. Distribution: shard S on the parameter axis, psum the local Gram
(O(m^2) bytes), replicate the small algebra, combine locally.

Two evolution modes:
  * ``matpow`` (default, TPU-native): Atilde^s by repeated squaring. This is
    the principled projected-DMD evolution U Atilde^s U^T w (the paper's
    ``b = Phi^T w`` silently assumes the eigenvector matrix is orthogonal),
    and it also handles defective (Jordan-block) operators — which weight
    drifts produce (eigenvalue 1 with multiplicity 2) — where eig-based
    reconstruction breaks down.
  * ``eig``: classic DMD via eigendecomposition Atilde = Y Lambda Y^-1
    (nonsymmetric eig is CPU-only in XLA -> jax.pure_callback host round-trip
    of an r x r matrix). Enables spectral analysis and |lambda|<=1 clamping
    ("stabilized DMD", a beyond-paper option).

Rank selection (``sigma_r / sigma_0 > tol``) is a *mask*, not a slice, so all
shapes are static and the whole update jits/shards.

Numerical robustness beyond the paper (both optional; off = paper-faithful):
  * anchor="first": run DMD on D_t = s_t - s_0. Raw weight trajectories are a
    huge static component plus tiny dynamics; in fp32 the unanchored Gram
    drowns the dynamics in rounding (eps*|w|^2 vs |delta|^2). Anchoring keeps
    every Gram entry at the dynamics' own scale. Anchor at s_0, NOT the mean:
    mean-centering folds a drift into a decay-back-to-the-mean and
    extrapolates BACKWARD (measured cos(jump, true) = -0.996 on an MLP toy).
  * trust_region: cap the jump length at tr*s*rms_step (all Gram-computable).
    Guards the paper's observed large-s failure mode (spurious |lambda|>1
    noise modes explode over s steps; the paper flags annealing as future
    work).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def gram_matrix(snapshots: jnp.ndarray, anchor: str = "none",
                stack_dims: int = 0, upcast: bool = True) -> jnp.ndarray:
    """G = D D^T contracting the trailing (parameter) axes.

    (m, stack..., param...) -> (stack..., m, m): `stack_dims` leading axes
    after the snapshot axis are treated as BATCH dims — one independent Gram
    per stacked layer (the paper runs DMD per layer; segment params are
    stacked (L, ...) for scan). Implemented as a single dot_general with
    batch dims — NO reshape: flattening a sharded tensor would force GSPMD
    to all-gather the whole buffer (measured: 59 GiB of gathers on a 22-layer
    stack), while the batched contraction keeps sharded dims local and emits
    one O(stack x m^2) all-reduce.

    D = anchored snapshots (see module docstring). fp32 accumulation
    regardless of snapshot dtype (bf16 storage supported). Anchoring MUST
    happen here on the data, not as a congruence transform on an unanchored
    G — the fp32 rounding damage would already be done.
    """
    # upcast=False (bf16 buffers): anchor-subtract in storage precision and
    # let the MXU accumulate bf16 x bf16 -> f32 (preferred_element_type) —
    # no 2x fp32 materialization of the m x params buffer. Entry error is
    # O(bf16 eps) per product with exact accumulation: below the tol floor.
    x = snapshots.astype(jnp.float32) if upcast else snapshots
    if anchor == "first":
        x = x - x[:1]
    elif anchor == "mean":
        x = (x - jnp.mean(x.astype(jnp.float32), axis=0,
                          keepdims=True).astype(x.dtype))
    elif anchor != "none":
        raise ValueError(f"unknown anchor {anchor!r}")
    nd = x.ndim
    batch = tuple(range(1, 1 + stack_dims))
    contract = tuple(range(1 + stack_dims, nd))
    return jax.lax.dot_general(
        x, x, dimension_numbers=((contract, contract), (batch, batch)),
        preferred_element_type=jnp.float32)


def gram_row_matrix(snapshots: jnp.ndarray, p: jnp.ndarray,
                    anchor: str = "none", stack_dims: int = 0,
                    upcast: bool = True) -> jnp.ndarray:
    """One streaming Gram row: (stack..., m) of <d_p, d_j> for every buffer
    row j — a single O(m*n) anchored inner-product pass (vs the O(m^2*n)
    full recompute in gram_matrix). `p` is the snapshot just written into the
    buffer, so row[slot] = <d_p, d_p> comes out automatically.

    Anchoring matches gram_matrix: subtract row 0 of the buffer from BOTH
    operands before contracting (never as a congruence transform on a raw
    fp32 Gram — see module docstring / DESIGN.md §2). When `p` IS the new
    anchor (slot 0 just rewritten), p - buf[0] == 0 and the row is exactly
    the zero row the anchored Gram requires.
    """
    x = snapshots.astype(jnp.float32) if upcast else snapshots
    q = p.astype(jnp.float32) if upcast else p.astype(x.dtype)
    if anchor == "first":
        q = q - x[0]
        x = x - x[:1]
    elif anchor != "none":
        raise ValueError(f"streaming gram does not support anchor {anchor!r}")
    nd = x.ndim
    lhs_batch = tuple(range(1, 1 + stack_dims))
    lhs_contract = tuple(range(1 + stack_dims, nd))
    rhs_batch = tuple(range(stack_dims))
    rhs_contract = tuple(range(stack_dims, nd - 1))
    return jax.lax.dot_general(
        x, q,
        dimension_numbers=((lhs_contract, rhs_contract),
                           (lhs_batch, rhs_batch)),
        preferred_element_type=jnp.float32)


def set_gram_row(gram: jnp.ndarray, row: jnp.ndarray, slot) -> jnp.ndarray:
    """Write `row` into row AND column `slot` of a (stack..., m, m) Gram.

    Mask-based (no dynamic-slice scatter), so `slot` may be a traced scalar
    and the update jits/shards inside the train step. This is the
    cyclic-slot invalidation: the stale row/col of the evicted snapshot is
    overwritten in one shot.
    """
    m = gram.shape[-1]
    onehot = jnp.arange(m) == slot
    row = row.astype(gram.dtype)
    gram = jnp.where(onehot[:, None], row[..., None, :], gram)
    return jnp.where(onehot[None, :], row[..., :, None], gram)


def _masked_inv_sigma(eigvals: jnp.ndarray, tol: float, energy: float = 0.0,
                      atol: float = 0.0):
    """eigvals of G- (ascending; batched over leading dims) ->
    sigma, 1/sigma, mask.

    Two truncation policies (static choice):
      * ``energy == 0`` (legacy / paper): keep sigma_r / sigma_0 > tol — a
        global noise-floor constant.
      * ``energy > 0`` (controller mode): keep the smallest leading set of
        modes whose cumulative eigenvalue energy reaches the ``energy``
        fraction of the total — the effective rank tracks the trajectory's
        own spectrum instead of a fixed constant (per-group target resolved
        in core/schedule.py). A small sigma floor (1e-6 * sigma_max) still
        guards the fp32 Gram noise tail.

    ``atol > 0`` joins an ABSOLUTE sigma floor to either policy (pymor's
    atol/rtol-truncated SVD idiom): modes below the floor are dropped no
    matter how the relative mask scores them. 0 (default) is a no-op.
    """
    lam = jnp.maximum(eigvals, 0.0)
    sigma = jnp.sqrt(lam)
    smax = jnp.max(sigma, axis=-1, keepdims=True)
    if energy and energy > 0:
        lam_desc = lam[..., ::-1]                 # descending energies
        cum = jnp.cumsum(lam_desc, axis=-1)
        total = cum[..., -1:]
        # keep mode k while the energy captured BEFORE it is still short of
        # the target (always keeps the top mode)
        keep = (cum - lam_desc) < energy * jnp.maximum(total, 1e-30)
        mask = keep[..., ::-1] & (sigma > 1e-6 * jnp.maximum(smax, 1e-30))
    else:
        mask = sigma > tol * jnp.maximum(smax, 1e-30)
    if atol and atol > 0:
        mask = mask & (sigma > atol)
    inv = jnp.where(mask, 1.0 / jnp.where(mask, sigma, 1.0), 0.0)
    return sigma, inv, mask


def _ridge_inv_sigma(sigma: jnp.ndarray, mask: jnp.ndarray, ridge):
    """Tikhonov-shrunk pseudo-inverse factor: sigma / (sigma^2 + lambda).

    ``lambda = ridge * sigma_max^2`` — the RELATIVE parameterization keeps
    the solve scale-equivariant (doubling the snapshots doubles nothing in
    the coefficients), mirroring the relative ``tol`` mask. At ridge -> 0
    this approaches 1/sigma (callers keep the exact legacy expression for
    the static ridge == 0 path, so that route stays bit-exact); as
    ridge -> inf it approaches 0, the fitted dynamics vanish, and the
    folded coefficients collapse onto the anchor snapshot. ``ridge`` may be
    a traced scalar (the controller's meta-tuned per-group override).
    """
    smax = jnp.max(sigma, axis=-1, keepdims=True)
    lam = jnp.maximum(jnp.asarray(ridge, jnp.float32), 0.0) * smax * smax
    return jnp.where(mask, sigma / (sigma * sigma + lam), 0.0)


def _matrix_power(a: jnp.ndarray, s: int) -> jnp.ndarray:
    """a^s for static integer s >= 1 by binary exponentiation (unrolled)."""
    assert s >= 1
    result = None
    base = a
    k = s
    while k > 0:
        if k & 1:
            result = base if result is None else result @ base
        k >>= 1
        if k == 0:
            break
        base = base @ base
    return result


def _matrix_power_traced(a: jnp.ndarray, s, s_max: int) -> jnp.ndarray:
    """a^s for a TRACED integer s in [1, s_max]: masked binary
    exponentiation with a static bit bound (controller mode — the adapted
    horizon is a carried device scalar, but the unroll length stays static
    at ceil(log2(s_max))). For s >= 1 at least one factor of ``a`` enters
    the product, so masked (zero) rows/cols stay zero exactly as in the
    static path."""
    eye = jnp.broadcast_to(jnp.eye(a.shape[-1], dtype=a.dtype), a.shape)
    s = jnp.asarray(s, jnp.int32)
    result, base = eye, a
    nbits = max(int(s_max).bit_length(), 1)
    for bit in range(nbits):
        take = ((s >> bit) & 1).astype(bool)
        result = jnp.where(take, result @ base, result)
        if bit + 1 < nbits:
            base = base @ base
    return result


def _host_eig(a: np.ndarray):
    w, v = np.linalg.eig(a)              # batched over leading dims
    # rcond of the eigenvector matrix: ~0 for defective (Jordan) operators,
    # where Y Lambda^s Y^-1 reconstruction is meaningless garbage.
    sv = np.linalg.svd(v, compute_uv=False)
    rcond = (sv[..., -1] / np.maximum(sv[..., 0], 1e-300)).astype(np.float32)
    return w.astype(np.complex64), v.astype(np.complex64), rcond


def _eig_power(atilde: jnp.ndarray, s, clamp_eigs: bool,
               s_max=None) -> jnp.ndarray:
    """Atilde^s via eigendecomposition (host callback), optional |lambda|
    clamp. Batched over leading dims (np.linalg.eig batches natively).

    Defective guard (regression: ISSUE 4 satellite): weight drifts produce
    Jordan-block operators (eigenvalue 1, multiplicity 2) whose eigenvector
    matrix is (numerically) singular — eig perturbs the double eigenvalue
    into a split pair with nearly parallel eigenvectors, and the
    reconstruction returns FINITE but wrong powers (measured ~one full
    drift step of error at s=5; worse with s), which no non-finite check
    can catch. The guard is self-validating: reconstruct the UNCLAMPED
    power through the eigenbasis and compare it against the exact matpow
    evolution of the same operator — if the eigendecomposition cannot
    reproduce the power it claims (relative error above a fp32-noise
    threshold, or rcond(Y) ~ 0, or non-finite), fall back to matpow. The
    fallback cannot honor ``clamp_eigs`` (a defective operator has no
    eigenbasis to clamp in); for the drift case the paper cares about,
    |lambda| = 1, so the clamp is a no-op there anyway — eig+clamp agrees
    with matpow (pinned in tests/test_dmd.py).

    ``s`` may be a traced scalar (controller mode) — then ``s_max`` bounds
    the matpow fallback's unroll and lambda^s goes through exp/log.
    """
    shape = atilde.shape
    eigvals, eigvecs, rcond = jax.pure_callback(
        _host_eig,
        (jax.ShapeDtypeStruct(shape[:-1], jnp.complex64),
         jax.ShapeDtypeStruct(shape, jnp.complex64),
         jax.ShapeDtypeStruct(shape[:-2], jnp.float32)),
        atilde, vmap_method="sequential")
    if clamp_eigs:
        # Clamp only |lambda| MEANINGFULLY above 1. A defective lambda = 1
        # pair splits under fp32 eigendecomposition noise into 1 +- delta
        # (delta ~ 1e-4) with huge OPPOSING mode amplitudes ~ 1/delta;
        # clamping just the upper one breaks their cancellation and injects
        # an O(1) error while the unclamped reconstruction is fine. Modes
        # within the 1e-3 band grow at most ~6% over the paper's s = 55 —
        # noise the trust region already owns — so the clamp targets real
        # spurious-growth modes only.
        mag = jnp.abs(eigvals)
        lam_clamped = jnp.where(mag > 1.0 + 1e-3,
                                eigvals / jnp.maximum(mag, 1e-30), eigvals)
    else:
        lam_clamped = eigvals

    if isinstance(s, (int, np.integer)):
        fallback = _matrix_power(atilde, int(s))
    else:
        fallback = _matrix_power_traced(atilde, s, int(s_max))

    def reconstruct(lam):
        # lambda^s with a zero-eigenvalue guard: with a traced s the power
        # lowers to exp(s*log(lambda)) and log(0) would poison the whole
        # reconstruction; masked modes are exactly zero either way.
        mag0 = jnp.abs(lam)
        lam_safe = jnp.where(mag0 > 0, lam, 1.0)
        if isinstance(s, (int, np.integer)):
            lam_s = jnp.where(mag0 > 0, lam_safe ** int(s), 0.0)
        else:
            lam_s = jnp.where(
                mag0 > 0,
                lam_safe ** jnp.asarray(s, jnp.float32).astype(jnp.complex64),
                0.0)
        # Y Lambda^s Y^-1 ; solve instead of invert for stability.
        m_complex = eigvecs * lam_s[..., None, :]
        yt = jnp.swapaxes(eigvecs, -1, -2)
        return jnp.real(jnp.swapaxes(jax.numpy.linalg.solve(
            yt, jnp.swapaxes(m_complex, -1, -2)), -1, -2))

    m_full = reconstruct(lam_clamped)
    m_check = m_full if not clamp_eigs else reconstruct(eigvals)
    norm = lambda x: jnp.sqrt(jnp.sum(jnp.square(x), axis=(-2, -1)))
    rel_err = norm(m_check - fallback) / jnp.maximum(norm(fallback), 1e-30)
    eig_finite = jnp.all(jnp.isfinite(m_full), axis=(-2, -1))
    fb_finite = jnp.all(jnp.isfinite(fallback), axis=(-2, -1))
    # Use the eig reconstruction when it validates against matpow — OR when
    # the matpow fallback itself is unusable: a genuinely explosive
    # operator (|lambda|^s past fp32 range, the very regime clamp_eigs
    # exists for) overflows the unclamped power, which would otherwise
    # poison rel_err and evict the perfectly finite CLAMPED result.
    validated = (rel_err < 1e-2) & (rcond > 1e-7)
    use_eig = eig_finite & (validated | ~fb_finite)
    return jnp.where(use_eig[..., None, None], m_full, fallback)


@functools.partial(jax.jit, static_argnames=("s", "tol", "mode", "clamp_eigs",
                                             "keep_residual", "anchor",
                                             "affine", "trust_region",
                                             "energy", "s_max", "atol",
                                             "ridge"))
def dmd_coefficients(gram: jnp.ndarray, *, s: int, tol: float = 1e-10,
                     mode: str = "matpow", clamp_eigs: bool = False,
                     keep_residual: bool = False, anchor: str = "none",
                     affine: bool = False, trust_region: float = 0.0,
                     relax: jnp.ndarray | float = 1.0,
                     energy: float = 0.0, s_max: int = None,
                     s_dyn=None, atol: float = 0.0, ridge: float = 0.0,
                     ridge_dyn=None) -> Tuple[jnp.ndarray, dict]:
    """Coefficient vector c (m,) such that w_extrapolated = S^T c.

    Args:
      gram: (m, m) = D D^T in fp32 (psum'd across shards by the caller /
         GSPMD), where D = gram_matrix(S, anchor=anchor)'s anchored data.
      s: extrapolation horizon (paper's ``s``): the returned combination
         estimates the weights ``s`` optimizer steps past the last snapshot.
         Always static — with a dynamic horizon (below) it is the CAP that
         sizes the unrolled power chain.
      tol: singular-value filter threshold (paper's "DMD filter tolerance").
      mode: "matpow" | "eig".
      keep_residual: also carry the component of w_last orthogonal to the POD
         subspace (beyond-paper stabilizer; paper drops it).
      anchor: must match the gram_matrix call. The returned c is always over
         the ORIGINAL snapshot rows — the anchor folds into the coefficients:
         w = anchor_vec + D^T c and anchor_vec = S^T a for a in {e_0, 1/m}
         => c_folded = c + (1 - sum(c)) * a.
      trust_region: if > 0, cap the jump length at tr * s * rms_step (all
         computed from the Gram; translation-invariant so anchor-safe).
         0 disables (paper-faithful).
      relax: blend factor, w <- (1-relax) w_last + relax w_dmd. Traced scalar
         so annealing does not trigger recompiles.
      energy: if > 0, replace the tol mask with the cumulative-energy rank
         rule (controller mode — see _masked_inv_sigma). Static.
      s_max: static bound for a traced ``s_dyn`` (defaults to ``s``).
      s_dyn: optional TRACED integer horizon in [1, s_max] (the controller's
         adapted per-group s). None (default) uses the static ``s`` — the
         bit-exact legacy path.
      atol: absolute sigma floor joined to the relative tol/energy mask
         (pymor's atol/rtol truncation). Static; 0 disables.
      ridge: static Tikhonov shrinkage of the REGRESSION factor of the
         reduced Koopman solve, relative to sigma_max^2 (see
         _ridge_inv_sigma). Only Atilde's right inverse factor — the
         least-squares solve against X — is shrunk; the projection factors
         (b, c_main) keep the exact pseudo-inverse, so growing ridge pulls
         the fitted dynamics (and hence the jump) toward the anchor without
         distorting the POD basis. 0 (default) keeps the legacy expression
         textually unchanged: bit-exact.
      ridge_dyn: optional TRACED ridge override (the controller's meta-tuned
         per-group value); takes precedence over the static ``ridge``.

    Returns:
      c: (m,) fp32 coefficients over snapshot rows.
      info: diagnostics dict (rank, sigma_ratio, jump_scale, jump_norm,
      step_rms — the last two feed the controller's gate telemetry).
    """
    m = gram.shape[-1]
    if m < 3:
        raise ValueError("DMD needs at least 3 snapshots (m >= 3)")
    raw_gram = gram
    if affine:
        # Affine-augmented DMD: append a constant coordinate gamma to every
        # (anchored) snapshot, making affine dynamics d+ = A d + b exactly
        # linear on the augmented state [d; gamma]. In Gram space this is a
        # rank-one update — no extra data pass:
        #     G~ = G + gamma^2 * 1 1^T,   gamma^2 = mean(diag(G)).
        # This removes both failure modes of plain anchoring (spurious
        # lambda>1 from the unmodeled affine term) and of plain DMD in fp32
        # (dynamics drowned by the static weight norm).
        diag = jnp.diagonal(gram, axis1=-2, axis2=-1)
        gamma2 = jnp.maximum(jnp.mean(diag, axis=-1), 1e-30)
        gram = gram + gamma2[..., None, None]
    g_lag = gram[..., :-1, :-1]                  # X^T X
    g_cross = gram[..., :-1, 1:]                 # X^T Z
    g_last = gram[..., :-1, -1]                  # X^T d_last

    eigvals, v = jnp.linalg.eigh(g_lag)          # ascending; batched
    sigma, inv_sigma, mask = _masked_inv_sigma(eigvals, tol, energy, atol)
    vt = jnp.swapaxes(v, -1, -2)

    # Reduced Koopman, masked dims are zero rows/cols. The ridge shrinks
    # ONLY the right (regression) factor — Atilde = U^T Z (X^+_ridge) U in
    # Gram form — while the left factor stays the exact projection; with no
    # ridge the legacy expression is reused untouched (bit-exact).
    if ridge_dyn is not None:
        inv_fit = _ridge_inv_sigma(sigma, mask, ridge_dyn)
    elif ridge and ridge > 0:
        inv_fit = _ridge_inv_sigma(sigma, mask, ridge)
    else:
        inv_fit = inv_sigma
    vt_c_v = vt @ g_cross @ v
    atilde = (inv_sigma[..., :, None] * vt_c_v) * inv_fit[..., None, :]

    cap = int(s if s_max is None else s_max)
    s_val = s if s_dyn is None else jnp.clip(
        jnp.asarray(s_dyn, jnp.int32), 1, cap)
    if mode == "matpow":
        if s_dyn is None:
            atilde_s = _matrix_power(atilde, int(s))
        else:
            atilde_s = _matrix_power_traced(atilde, s_val, cap)
    elif mode == "eig":
        atilde_s = _eig_power(atilde, int(s) if s_dyn is None else s_val,
                              clamp_eigs, s_max=cap)
        atilde_s = jnp.where(mask[..., :, None] & mask[..., None, :],
                             atilde_s, 0.0)
    else:
        raise ValueError(f"unknown DMD mode {mode!r}")

    def matvec(mat, vec):
        return jnp.einsum("...ij,...j->...i", mat, vec)

    # b = Sigma^-1 V^T g_last  (= U^T d_last);  y = Atilde^s b
    b = inv_sigma * matvec(vt, g_last)
    y = matvec(atilde_s, b)
    # d_dmd = U y = X V Sigma^-1 y = D[:-1]^T (V Sigma^-1 y)
    c_main = matvec(v, inv_sigma * y)            # (..., m-1)

    batch_shape = c_main.shape[:-1]
    zeros1 = jnp.zeros(batch_shape + (1,), c_main.dtype)
    c = jnp.concatenate([c_main, zeros1], axis=-1)
    if keep_residual:
        # residual = d_last - U U^T d_last
        proj = matvec(v, inv_sigma * inv_sigma * matvec(vt, g_last))
        c = c + jnp.concatenate([-proj, jnp.ones_like(zeros1)], axis=-1)

    e_last = jnp.zeros((m,), jnp.float32).at[-1].set(1.0)
    e_last = jnp.broadcast_to(e_last, c.shape)

    # Jump-gain diagnostics, computed for every call (O(m^2) algebra):
    # ||w_new - w_last||^2 = (c-e)^T G (c-e) — translation-invariant, so the
    # RAW (unaugmented) anchored Gram is the right form; rms_step from the
    # super-diagonal. The trust region reuses both; the controller's gate
    # telemetry reads them from `info` even when the trust region is off.
    d = c - e_last
    jump2 = jnp.maximum(
        jnp.einsum("...i,...ij,...j->...", d, raw_gram, d), 0.0)
    diag = jnp.diagonal(raw_gram, axis1=-2, axis2=-1)
    sup = jnp.diagonal(raw_gram, 1, -2, -1)
    step2 = jnp.mean(diag[..., 1:] + diag[..., :-1] - 2.0 * sup, axis=-1)

    jump_scale = jnp.ones(batch_shape, jnp.float32)
    if trust_region and trust_region > 0:
        # Uses the RAW Gram: the constant coordinate is not a real
        # parameter. Consecutive-step distances are unaffected by the
        # rank-one augmentation anyway ((e_{t+1}-e_t)^T 1 1^T (e_{t+1}-e_t)=0).
        if s_dyn is None:       # static horizon: python-float radius, the
            radius2 = (trust_region * s) ** 2 * jnp.maximum(step2, 0.0)
        else:                   # bit-exact legacy expression
            radius2 = (trust_region * s_val.astype(jnp.float32)) ** 2 \
                * jnp.maximum(step2, 0.0)
        jump_scale = jnp.minimum(1.0, jnp.sqrt(
            radius2 / jnp.maximum(jump2, 1e-30)))
        # The guard must survive non-finite inputs anywhere in the chain: a
        # finite-but-huge c overflows the quadratic form (inf - inf -> NaN in
        # jump2), and a NaN-poisoned Gram poisons step2/radius2 even when c is
        # finite. Any non-finite guard input collapses to the no-op jump
        # c = e_last (keep w_last) with jump_scale = 0.
        finite = (jnp.all(jnp.isfinite(c), axis=-1) & jnp.isfinite(jump2)
                  & jnp.isfinite(step2) & jnp.isfinite(jump_scale))
        jump_scale = jnp.where(finite, jump_scale, 0.0)
        c = jnp.where(finite[..., None], c, e_last)
        c = jump_scale[..., None] * c + (1.0 - jump_scale[..., None]) * e_last

    # Fold the anchor back: w = anchor_vec + D^T c = S^T c_folded.
    if anchor == "first":
        fold = 1.0 - jnp.sum(c, axis=-1)
        c = c.at[..., 0].add(fold)
    elif anchor == "mean":
        c = c + (1.0 - jnp.sum(c, axis=-1, keepdims=True)) / m

    relax = jnp.asarray(relax, jnp.float32)
    c = relax * c + (1.0 - relax) * e_last

    # Last line of defense (active regardless of trust_region): never emit a
    # non-finite combination, and never trust coefficients derived from a
    # non-finite Gram (eigh on an inf/NaN matrix can return finite garbage
    # that the anchor fold then turns into a meaningless jump) — fall back to
    # "keep w_last". A finite c from a finite Gram passes through unchanged,
    # so the paper-faithful path is unaffected.
    ok = (jnp.all(jnp.isfinite(c), axis=-1, keepdims=True)
          & jnp.all(jnp.isfinite(raw_gram), axis=(-2, -1))[..., None])
    c = jnp.where(ok, c, e_last)

    info = {
        "rank": jnp.sum(mask.astype(jnp.int32), axis=-1),
        "sigma_ratio": jnp.min(jnp.where(mask, sigma, jnp.inf), axis=-1)
                       / jnp.maximum(jnp.max(sigma, axis=-1), 1e-30),
        "jump_scale": jump_scale,
        # Gate telemetry (controller / benches): the realized jump length is
        # relax * jump_scale * ||D^T (c_raw - e_last)||, and rms_step sets
        # its natural scale. Both survive non-finite inputs as 0 / 0.
        "jump_norm": jnp.abs(jnp.asarray(relax, jnp.float32)) * jump_scale
                     * jnp.sqrt(jnp.where(jnp.isfinite(jump2), jump2, 0.0)),
        "step_rms": jnp.sqrt(jnp.maximum(
            jnp.where(jnp.isfinite(step2), step2, 0.0), 0.0)),
    }
    return c, info


def combine_snapshots(snapshots: jnp.ndarray, c: jnp.ndarray,
                      stack_dims: int = 0, upcast: bool = True) -> jnp.ndarray:
    """w_new = S^T c without flattening copies.

    (m, stack..., param...) x (stack..., m) -> (stack..., param...) with
    per-stacked-layer coefficients (stack_dims batch dims, matching
    gram_matrix)."""
    x = snapshots.astype(jnp.float32) if upcast else snapshots
    cf = c.astype(jnp.float32) if upcast else c.astype(x.dtype)
    if stack_dims == 0:
        return jnp.tensordot(cf, x, axes=(0, 0),
                             preferred_element_type=jnp.float32)
    letters = "abcdefgh"[:stack_dims]
    return jnp.einsum(f"{letters}m,m{letters}...->{letters}...", cf, x,
                      preferred_element_type=jnp.float32)


def dmd_extrapolate(snapshots: jnp.ndarray, *, s: int, tol: float = 1e-10,
                    mode: str = "matpow", clamp_eigs: bool = False,
                    keep_residual: bool = False, anchor: str = "none",
                    affine: bool = False, trust_region: float = 0.0,
                    relax: float = 1.0, atol: float = 0.0,
                    ridge: float = 0.0) -> Tuple[jnp.ndarray, dict]:
    """One-leaf convenience wrapper: snapshots (m, ...) -> extrapolated (...)."""
    gram = gram_matrix(snapshots, anchor=anchor)
    c, info = dmd_coefficients(gram, s=s, tol=tol, mode=mode,
                               clamp_eigs=clamp_eigs, anchor=anchor,
                               affine=affine, trust_region=trust_region,
                               keep_residual=keep_residual, relax=relax,
                               atol=atol, ridge=ridge)
    w = combine_snapshots(snapshots, c)
    # A non-finite snapshot poisons the combine even under the c = e_last
    # guard (0 * inf = NaN): never return less-finite than the last snapshot.
    return jnp.where(jnp.isfinite(w), w, snapshots[-1].astype(w.dtype)), info


def dmd_eigenvalues_from_gram(gram: np.ndarray, *,
                              tol: float = 1e-10) -> np.ndarray:
    """Spectral diagnostics (host) from an (m, m) Gram alone: the Koopman
    eigenvalues of the reduced operator the next jump would fit. This is
    the Gram-side half of ``dmd_eigenvalues`` factored out so the carried
    streaming Gram (per-system or segment-summed bucket scope) feeds the
    spectrum diagnostic without touching the O(m*n) snapshot data
    (DMDAccelerator.spectrum_table, DESIGN.md §9). The Gram must already
    be in the anchored form the caller maintains."""
    g_np = np.asarray(gram, np.float64)
    g_lag, g_cross = g_np[:-1, :-1], g_np[:-1, 1:]
    lam, v = np.linalg.eigh(g_lag)
    sig = np.sqrt(np.maximum(lam, 0.0))
    mask = sig > tol * max(sig.max(), 1e-300)
    if not mask.any():
        return np.zeros(0, np.complex128)
    inv = np.where(mask, 1.0 / np.where(mask, sig, 1.0), 0.0)
    atilde = (inv[:, None] * (v.T @ g_cross @ v)) * inv[None, :]
    atilde = atilde[np.ix_(mask, mask)]
    return np.linalg.eigvals(atilde)


def dmd_eigenvalues(snapshots: jnp.ndarray, *, tol: float = 1e-10,
                    anchor: str = "none") -> np.ndarray:
    """Spectral diagnostics (host): DMD eigenvalues of a snapshot trajectory."""
    s_np = np.asarray(snapshots, np.float64).reshape(snapshots.shape[0], -1)
    if anchor == "first":
        s_np = s_np - s_np[:1]
    elif anchor == "mean":
        s_np = s_np - s_np.mean(axis=0, keepdims=True)
    return dmd_eigenvalues_from_gram(s_np @ s_np.T, tol=tol)
