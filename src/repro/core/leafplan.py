"""LeafPlan: the per-leaf DMD dispatch table (DESIGN.md §3).

The paper's method is per-layer by construction — every DMD round runs an
independent Gram/coefficient/combine pipeline per weight tensor — so the
per-leaf routing decisions (how many leading stack axes a leaf carries, which
kernel route serves its data passes, how its snapshot buffer is sharded) are
the hot-path control plane of the whole reproduction. Before this module
those decisions were smeared across five call sites (a path-string matcher in
snapshots.py, the kernel-vs-dot_general conditional in update_grams, anchor
gating in the accelerator, gram PartitionSpecs in launch/inputs.py, and the
path-regex sharding rules). Now they are computed ONCE, at accelerator init,
from the real param pytree + mesh, and threaded everywhere as a pytree of
frozen `LeafPlan` records.

Stack dims are STRUCTURAL: models that stack layer params for lax.scan expose
the stacking via `param_stack_dims()` (see models/transformer.py — derived
from the segment plan, the same source of truth that created the stacked
leading axes), and `build_plans` consumes that pytree. No more guessing layer
structure from substrings of the flattened path.

Kernel routes (see kernels/ops.py + kernels/sharded.py):

  * ``pallas_flat``       — flat-safe leaves (no stack axes, not sharded):
                            the (m, n) Pallas kernels after a free reshape.
  * ``pallas_shard_map``  — stacked and/or sharded leaves: the same Pallas
                            kernels run per shard under shard_map (local
                            flatten + fp32 partial + O(stack·m²)/O(stack·m)
                            psum), vmapped over stack axes. Degrades
                            gracefully to local vmapped kernels when no mesh
                            is active.
  * ``dot_general``       — the batched-contraction reference path in
                            core/dmd.py (config override / oracle).

Schedule groups (core/schedule.py, DESIGN.md §4): each plan also records
which schedule group the leaf resolved to (`group`, `sched`) — the group's
window length `m` sizes the leaf's snapshot buffer and Gram, its phase
staggers its jumps, and its index keys the per-group slot/relax vectors
threaded through the train step.

`plan_table()` renders the whole table for auditing (route + group/m/phase
columns); tests/test_configs.py pins it for the production configs.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import schedule as sched_mod
from repro.core.schedule import GroupSchedule

PyTree = Any

ROUTES = ("pallas_flat", "pallas_shard_map", "dot_general")


@dataclass(frozen=True)
class LeafPlan:
    """Per-leaf dispatch record, computed once at accelerator init.

    Deliberately NOT a registered pytree: a LeafPlan is static metadata and
    must stay a *leaf* under tree_map so plan pytrees align 1:1 with param /
    buffer / gram pytrees.
    """
    path: str                     # normalized param path ("/seg0/attn/wqkv")
    shape: Tuple[int, ...]        # param leaf shape (stack dims included)
    dtype: str                    # param dtype name (audit only)
    stack_dims: int               # leading per-layer batch axes (after the
                                  # snapshot axis once buffered)
    flat_size: int                # flattened param size per stacked layer
    route: str                    # one of ROUTES
    anchor_ok: bool               # streaming one-pass row update valid
                                  # (anchor in {none, first})
    sharded: bool                 # any non-stack dim sharded on a >1 axis
    param_spec: P                 # full-length spec for the param leaf
    snapshot_spec: P              # spec for the (m, *shape) buffer leaf
    gram_spec: P                  # spec for the (stack..., m, m) Gram leaf
    block_n: int                  # n-tile for the Pallas kernels (128-lane
                                  # multiple, clamped to the leaf)
    group: int = 0                # schedule-group index (core/schedule.py);
                                  # indexes per-group slot/relax vectors
    sched: Optional[GroupSchedule] = None
                                  # the group's resolved schedule (m, s,
                                  # warmup, cooldown, phase, relax, anneal)
    mesh: Optional[Mesh] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def stack_shape(self) -> Tuple[int, ...]:
        return self.shape[:self.stack_dims]

    @property
    def m(self) -> int:
        """Snapshot-window length for THIS leaf — its buffer is (m, *shape)
        and its Gram (stack..., m, m). Heterogeneous across groups."""
        if self.sched is None:
            raise ValueError(f"plan for {self.path} has no schedule")
        return self.sched.m

    @property
    def stack_spec_entries(self) -> Tuple[Any, ...]:
        ent = tuple(self.param_spec)
        k = self.stack_dims
        return (ent[:k] + (None,) * (k - len(ent)))[:k]

    def psum_axes(self) -> Tuple[str, ...]:
        """Mesh axes the shard-local Gram partials must be psum'd over: every
        axis sharding a CONTRACTED (non-stack) dim of the leaf."""
        axes: List[str] = []
        for e in tuple(self.param_spec)[self.stack_dims:]:
            if e is None:
                continue
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None and a not in axes:
                    axes.append(a)
        return tuple(axes)


def default_block_n(flat_size: int, cap: int = 2048) -> int:
    """Largest useful n-tile for a leaf: a multiple of 128 lanes, never wider
    than the (lane-padded) leaf itself — a (m, 7) leaf gets one 128-lane tile,
    not a 2048-lane one (padding is exact: zero lanes contribute zero).
    Delegates to the kernels' own clamp so plan and wrapper always agree."""
    from repro.kernels.ops import lane_block
    return lane_block(cap, flat_size)


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


def _full_spec(spec: P, ndim: int) -> P:
    ent = tuple(spec)[:ndim]
    return P(*(ent + (None,) * (ndim - len(ent))))


def _is_sharded(entries, mesh: Optional[Mesh]) -> bool:
    if mesh is None:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for e in entries:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None and sizes.get(a, 1) > 1:
                return True
    return False


def _resolve_route(cfg, stack_dims: int, sharded: bool) -> str:
    forced = getattr(cfg, "kernel_route", "auto")
    if forced not in ("auto",) + ROUTES:
        raise ValueError(f"unknown dmd.kernel_route {forced!r}")
    auto = ("pallas_shard_map" if (stack_dims > 0 or sharded)
            else "pallas_flat")
    if forced == "auto":
        return auto
    if forced == "pallas_flat" and (stack_dims > 0 or sharded):
        return auto            # flattening a stacked/sharded leaf is invalid
    return forced


def build_plans(params: PyTree, cfg, mesh: Optional[Mesh] = None,
                stack_dims: Optional[PyTree] = None) -> PyTree:
    """params (+ optional stack-dims pytree) -> pytree of LeafPlan | None.

    `stack_dims` is either a pytree of ints mirroring `params` (the
    structural annotation from `LanguageModel.param_stack_dims()`), a
    callable ``(normalized_path, leaf) -> int``, or None (no stacked leaves —
    plain MLPs / benchmark pytrees). Works on tracers and ShapeDtypeStructs:
    only shape/dtype/path metadata is read, so plans can be built at trace
    time inside a jitted step.
    """
    from repro.distributed.sharding import normalize_path, spec_for_path

    groups = sched_mod.resolve_groups(cfg)

    if stack_dims is None:
        # No annotation means NO stacked leaves. Guessing zero for a
        # scan-stacked tree would silently merge per-layer trajectories into
        # one Gram — numerically wrong DMD, no error. The repo's segment
        # convention (top-level "seg<i>" keys from transformer.init_params)
        # is detectable, so refuse loudly instead.
        if isinstance(params, dict) and any(
                k.startswith("seg") and k[3:].isdigit() for k in params):
            raise ValueError(
                "params look segment-stacked (top-level 'seg<i>' keys) but "
                "no stack_dims annotation was given — pass the model's "
                "param_stack_dims() (or an accelerator built with it, e.g. "
                "make_dmd_step(acfg, model=model) / acc=...) so the paper's "
                "per-layer DMD stays per-layer")
        stack_of = lambda path, leaf: 0
    elif callable(stack_dims):
        stack_of = stack_dims
    else:
        flat_sd = {
            normalize_path(jax.tree_util.keystr(kp)): int(v)
            for kp, v in jax.tree_util.tree_flatten_with_path(stack_dims)[0]}

        def stack_of(path, leaf):
            return flat_sd.get(path, 0)

    def one(keypath, leaf):
        raw = jax.tree_util.keystr(keypath)
        path = normalize_path(raw)
        gi = sched_mod.group_for_leaf(cfg, path, leaf.ndim, leaf.size)
        if gi is None:                       # excluded by a group rule (or
            return None                      # the legacy filters mapped onto
                                             # rules — core/schedule.py)
        nstack = stack_of(path, leaf)
        if not 0 <= nstack < leaf.ndim + 1:
            raise ValueError(
                f"stack_dims {nstack} out of range for {path} {leaf.shape}")
        # No mesh -> nothing is sharded: fully-replicated specs, so
        # psum_axes() is empty and the shard_map wrappers run purely local.
        pspec = _full_spec(
            spec_for_path(path, leaf.ndim, mesh, leaf.shape)
            if mesh is not None else P(), leaf.ndim)
        ent = tuple(pspec)
        sharded = _is_sharded(ent[nstack:], mesh)
        flat_size = _prod(leaf.shape[nstack:])
        route = _resolve_route(cfg, nstack, sharded)
        return LeafPlan(
            path=path,
            shape=tuple(int(d) for d in leaf.shape),
            dtype=str(getattr(leaf, "dtype", "?")),
            stack_dims=nstack,
            flat_size=flat_size,
            route=route,
            anchor_ok=cfg.anchor in ("none", "first"),
            sharded=sharded,
            param_spec=pspec,
            snapshot_spec=P(None, *ent),
            gram_spec=P(*((ent[:nstack] + (None,) * (nstack - len(ent))
                           )[:nstack]), None, None),
            block_n=default_block_n(flat_size),
            group=gi,
            sched=groups[gi],
            mesh=mesh,
        )

    return jax.tree_util.tree_map_with_path(one, params)


def is_plan_leaf(x) -> bool:
    """is_leaf predicate for tree_maps over plan pytrees (None = excluded)."""
    return x is None or isinstance(x, LeafPlan)


def plan_entries(plans: PyTree) -> List[LeafPlan]:
    """Flat list of the selected leaves' plans, in pytree order."""
    return [p for p in jax.tree_util.tree_leaves(plans, is_leaf=is_plan_leaf)
            if isinstance(p, LeafPlan)]


def plan_summary(plans: PyTree) -> Dict[str, Tuple[str, int]]:
    """{path: (route, stack_dims)} — the regression-pin view of the table."""
    return {p.path: (p.route, p.stack_dims) for p in plan_entries(plans)}


def plan_records(plans: PyTree) -> List[dict]:
    """JSON-able rows of the dispatch table — the static-audit export
    consumed by ``repro.audit`` (arena-layout / schedule-conflict /
    collective-budget passes) and the AUDIT_*.json artifact."""
    return [{
        "path": p.path, "shape": list(p.shape), "dtype": p.dtype,
        "stack_dims": p.stack_dims, "flat_size": p.flat_size,
        "route": p.route, "anchor_ok": p.anchor_ok, "sharded": p.sharded,
        "block_n": p.block_n, "group": p.group,
        "m": (p.sched.m if p.sched is not None else None),
        "s": (p.sched.s if p.sched is not None else None),
        "phase": (p.sched.phase if p.sched is not None else None),
        "param_spec": str(p.param_spec),
        "psum_axes": list(p.psum_axes()),
    } for p in plan_entries(plans)]


def plan_table(plans: PyTree, arena: Optional[dict] = None,
               native: bool = False, scope: str = "leaf") -> str:
    """Human-readable audit dump of the whole dispatch table (kernel route
    + schedule group / window / horizon / phase per selected leaf; the
    `energy` column is the group's controller-mode cumulative-energy rank
    target — "-" while the controller is off, i.e. the tol mask rules).

    With the accelerator's arena bucket table (core/arena.py) the `arena`
    and `off` columns show which packed bucket serves each leaf and the
    leaf's lane offset inside it ("-" = per-leaf route: dot_general oracle,
    or arenas disabled). `native` (cfg.dmd.arena_native, resolved by the
    accelerator) fills the `resident` column: "y" for packed leaves whose
    params live IN the bucket buffer during Trainer.fit (DESIGN.md §7),
    "n" for packed-but-copied (the PR-5 pack route), "-" for per-leaf
    leaves. `scope` (cfg.dmd.scope) fills the `scope` column: "bucket"
    for leaves whose bucket fits ONE shared Koopman operator over the
    concatenated bucket state (DESIGN.md §9), "leaf" for per-system
    leaves (including sys-sharded buckets, which never collapse)."""
    seg_of = {}
    for b in (arena or {}).values():
        sc = "bucket" if b.bucket_scoped(scope) else "leaf"
        for s in b.segments:
            seg_of[s.path] = (b.key, s.lane_start, sc)
    rows = [("path", "route", "group", "m", "s", "phase", "energy", "stack",
             "shape", "flat_n", "block_n", "arena", "off", "resident",
             "scope", "spec", "psum")]
    for p in plan_entries(plans):
        sched = p.sched
        akey, aoff, asc = seg_of.get(p.path, ("-", "-", "leaf"))
        res = "-" if akey == "-" else ("y" if native else "n")
        rows.append((p.path, p.route,
                     sched.name if sched is not None else str(p.group),
                     str(p.m if sched is not None else "?"),
                     str(sched.s if sched is not None else "?"),
                     str(sched.phase if sched is not None else "?"),
                     (f"{sched.energy:.3f}"
                      if sched is not None and sched.energy > 0 else "-"),
                     str(p.stack_dims),
                     "x".join(map(str, p.shape)), str(p.flat_size),
                     str(p.block_n), akey, str(aoff), res, asc,
                     str(p.param_spec), ",".join(p.psum_axes()) or "-"))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return "\n".join(lines)
