"""Cyclic per-parameter snapshot buffers (the paper's weight matrices W^l).

A buffer pytree mirrors the (filtered) param pytree with a leading snapshot
axis of length m. Buffers are stored in ``snapshot_dtype`` and sharded with
the *same* PartitionSpec as the parameter (snapshot axis replicated), so the
Gram pass is local + one O(m^2) psum — see DESIGN.md §2.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any


def param_filter_fn(cfg) -> Callable[[str, Any], bool]:
    """cfg: DMDConfig -> predicate(path_string, leaf) for DMD applicability."""
    def pred(path: str, leaf) -> bool:
        if leaf.size < max(cfg.min_param_size, 1):
            return False
        if cfg.param_filter == "all":
            return True
        if cfg.param_filter == "non_expert":
            return "expert" not in path
        if cfg.param_filter == "matrices_only":
            return leaf.ndim >= 2
        raise ValueError(f"unknown param_filter {cfg.param_filter!r}")
    return pred


def _iter_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def selected_paths(params: PyTree, cfg) -> Dict[str, bool]:
    pred = param_filter_fn(cfg)
    return {path: pred(path, leaf) for path, leaf in _iter_paths(params)}


def init_buffers(params: PyTree, cfg) -> PyTree:
    """Zeros buffer (m, *shape) per selected leaf; None for excluded leaves.

    Abstract-aware: ShapeDtypeStruct params produce ShapeDtypeStruct buffers
    (the dry-run path must never materialize m x params of zeros).
    """
    pred = param_filter_fn(cfg)
    dtype = jnp.dtype(cfg.snapshot_dtype)

    def make(path, leaf):
        if not pred(jax.tree_util.keystr(path), leaf):
            return None
        shape = (cfg.m,) + tuple(leaf.shape)
        if isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)
    return jax.tree_util.tree_map_with_path(make, params)


def record(buffers: PyTree, params: PyTree, slot) -> PyTree:
    """Write current params into row `slot` of each buffer (donated update)."""
    def upd(buf, p):
        if buf is None:
            return None
        return jax.lax.dynamic_update_index_in_dim(
            buf, p.astype(buf.dtype), slot, axis=0)
    return jax.tree_util.tree_map(upd, buffers, params,
                                  is_leaf=lambda x: x is None)


def stack_dims_for_path(path: str) -> int:
    """How many leading stack axes a param leaf carries (after the snapshot
    axis): segment params are stacked once; gemma local / zamba mamba
    sub-stacks add a second. The paper's DMD is per-LAYER, so these axes are
    batch dims for the Gram/coefficient math."""
    p = path.replace("['", "/").replace("']", "").replace(".", "/")
    if "/seg" not in p:
        return 0
    n = 1
    if "/local/" in p or "/mamba/" in p:
        n += 1
    return n
