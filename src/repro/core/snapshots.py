"""Cyclic per-parameter snapshot buffers (the paper's weight matrices W^l).

A buffer pytree mirrors the (filtered) param pytree with a leading snapshot
axis of length m. Buffers are stored in ``snapshot_dtype`` and sharded with
the *same* PartitionSpec as the parameter (snapshot axis replicated), so the
Gram pass is local + one O(m^2) psum — see DESIGN.md §2.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import dmd as dmd_math

PyTree = Any


def param_filter_fn(cfg) -> Callable[[str, Any], bool]:
    """cfg: DMDConfig -> predicate(path_string, leaf) for DMD applicability."""
    def pred(path: str, leaf) -> bool:
        if leaf.size < max(cfg.min_param_size, 1):
            return False
        if cfg.param_filter == "all":
            return True
        if cfg.param_filter == "non_expert":
            return "expert" not in path
        if cfg.param_filter == "matrices_only":
            return leaf.ndim >= 2
        raise ValueError(f"unknown param_filter {cfg.param_filter!r}")
    return pred


def _iter_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def selected_paths(params: PyTree, cfg) -> Dict[str, bool]:
    pred = param_filter_fn(cfg)
    return {path: pred(path, leaf) for path, leaf in _iter_paths(params)}


def init_buffers(params: PyTree, cfg) -> PyTree:
    """Zeros buffer (m, *shape) per selected leaf; None for excluded leaves.

    Abstract-aware: ShapeDtypeStruct params produce ShapeDtypeStruct buffers
    (the dry-run path must never materialize m x params of zeros).
    """
    pred = param_filter_fn(cfg)
    dtype = jnp.dtype(cfg.snapshot_dtype)

    def make(path, leaf):
        if not pred(jax.tree_util.keystr(path), leaf):
            return None
        shape = (cfg.m,) + tuple(leaf.shape)
        if isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)
    return jax.tree_util.tree_map_with_path(make, params)


def record(buffers: PyTree, params: PyTree, slot) -> PyTree:
    """Write current params into row `slot` of each buffer (donated update)."""
    def upd(buf, p):
        if buf is None:
            return None
        return jax.lax.dynamic_update_index_in_dim(
            buf, p.astype(buf.dtype), slot, axis=0)
    return jax.tree_util.tree_map(upd, buffers, params,
                                  is_leaf=lambda x: x is None)


def init_grams(buffers: PyTree, cfg) -> PyTree:
    """Zeros running Gram (stack..., m, m) fp32 per buffer leaf; None where
    the buffer is None. Mirrors the buffer pytree so the two thread through
    jitted steps together. Abstract-aware like init_buffers."""
    def make(path, buf):
        if buf is None:
            return None
        nstack = stack_dims_for_path(jax.tree_util.keystr(path))
        shape = tuple(buf.shape[1:1 + nstack]) + (cfg.m, cfg.m)
        if isinstance(buf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        return jnp.zeros(shape, jnp.float32)
    return jax.tree_util.tree_map_with_path(make, buffers,
                                            is_leaf=lambda x: x is None)


def update_grams(grams: PyTree, buffers: PyTree, params: PyTree, slot,
                 cfg) -> PyTree:
    """Streaming Gram maintenance: after `record` wrote params into row
    `slot`, refresh row+column `slot` of every running Gram with one O(m*n)
    anchored inner-product pass per leaf (kernel-dispatched for flat leaves,
    batched dot_general for stacked ones). See DESIGN.md §2 for why this
    equals the full gram_matrix recompute at every window-complete point.
    """
    from repro.kernels import ops

    def upd(path, g, buf, p):
        if g is None:
            return None
        nstack = stack_dims_for_path(jax.tree_util.keystr(path))
        if nstack == 0 and cfg.gram_upcast and buf.ndim == 2:
            # already-flat leaf: kernel dispatch needs no reshape, so it is
            # safe under GSPMD too (TPU -> Pallas, CPU -> dot_general ref)
            row = ops.gram_row(buf, p.astype(buf.dtype),
                               anchor_first=(cfg.anchor == "first"))
        else:
            # multi-dim / stacked / bf16-streaming leaves: the batched
            # dot_general contracts trailing axes in place — flattening a
            # sharded buffer inside the fused train step would force GSPMD
            # to all-gather it every recorded step (DESIGN.md §3; wrapping
            # the Pallas kernel in shard_map is the open item for these)
            row = dmd_math.gram_row_matrix(
                buf, p.astype(buf.dtype), anchor=cfg.anchor,
                stack_dims=nstack, upcast=cfg.gram_upcast)
        return dmd_math.set_gram_row(g, row, slot)

    return jax.tree_util.tree_map_with_path(upd, grams, buffers, params,
                                            is_leaf=lambda x: x is None)


def recompute_grams(grams: PyTree, buffers: PyTree, cfg) -> PyTree:
    """Rebuild running Grams whose leaf is all-zero while its buffer is not
    (a checkpoint written before streaming Grams existed restores the
    template's zeros — the next mid-window apply would otherwise solve on a
    Gram with zeroed rows). Leaves with real data pass through untouched, so
    a streaming-era checkpoint resumes with its carried values. Host-side
    (restore path), one O(m^2*n) oracle pass per stale leaf."""
    def fix(path, g, buf):
        if g is None or buf is None:
            return g
        if bool(jnp.any(g != 0)) or not bool(jnp.any(buf != 0)):
            return g
        nstack = stack_dims_for_path(jax.tree_util.keystr(path))
        return dmd_math.gram_matrix(buf, anchor=cfg.anchor,
                                    stack_dims=nstack,
                                    upcast=cfg.gram_upcast)
    return jax.tree_util.tree_map_with_path(fix, grams, buffers,
                                            is_leaf=lambda x: x is None)


def stack_dims_for_path(path: str) -> int:
    """How many leading stack axes a param leaf carries (after the snapshot
    axis): segment params are stacked once; gemma local / zamba mamba
    sub-stacks add a second. The paper's DMD is per-LAYER, so these axes are
    batch dims for the Gram/coefficient math."""
    p = path.replace("['", "/").replace("']", "").replace(".", "/")
    if "/seg" not in p:
        return 0
    n = 1
    if "/local/" in p or "/mamba/" in p:
        n += 1
    return n
