"""Cyclic per-parameter snapshot buffers (the paper's weight matrices W^l).

A buffer pytree mirrors the (filtered) param pytree with a leading snapshot
axis of length m_leaf — HETEROGENEOUS across schedule groups (DESIGN.md §4):
each leaf's window length comes from its plan's resolved GroupSchedule, so a
norm/bias group with m=6 stores 6 rows while the matrices keep the global
m=14. Buffers are stored in ``snapshot_dtype`` and sharded with the *same*
PartitionSpec as the parameter (snapshot axis replicated), so the Gram pass
is local + one O(m^2) psum — see DESIGN.md §2.

Per-leaf routing (stack axes, kernel route, specs, schedule group) comes
from the LeafPlan pytree (core/leafplan.py), computed once at accelerator
init and threaded through every function here. Write positions arrive as a
scalar slot (legacy single-group path) or a per-group slot vector indexed by
``plan.group`` — computed in-trace from the step index by
``schedule.slots_for_step`` inside the fused train step.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dmd as dmd_math
from repro.core.leafplan import LeafPlan, build_plans, is_plan_leaf

PyTree = Any


def param_filter_fn(cfg) -> Callable[[str, Any], bool]:
    """cfg: DMDConfig -> predicate(path_string, leaf) for DMD applicability.

    Thin wrapper over the group-rule resolution in core/schedule.py: a leaf
    is selected iff it resolves to a schedule group. The legacy
    ``param_filter`` strings / ``min_param_size`` are mapped onto exclusion
    rules there (``schedule.rules_for_config``) — no string dispatch here.
    """
    from repro.core.schedule import group_for_leaf
    from repro.distributed.sharding import normalize_path

    def pred(path: str, leaf) -> bool:
        return group_for_leaf(cfg, normalize_path(path), leaf.ndim,
                              leaf.size) is not None
    return pred


def _static_int(s) -> Optional[int]:
    """Concrete value of a slot scalar, or None when traced."""
    if isinstance(s, jax.core.Tracer):
        return None
    try:
        return int(s)
    except (TypeError, jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return None


def _leaf_slot(plan, slot):
    """Per-leaf write position: vector slots index by the plan's schedule
    group; scalars apply to every leaf (single-group / legacy callers)."""
    if getattr(slot, "ndim", 0) == 1:
        return slot[plan.group]
    return slot


def _iter_paths(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in flat:
        yield jax.tree_util.keystr(path), leaf


def selected_paths(params: PyTree, cfg) -> Dict[str, bool]:
    pred = param_filter_fn(cfg)
    return {path: pred(path, leaf) for path, leaf in _iter_paths(params)}


def init_buffers(params: PyTree, cfg, plans: Optional[PyTree] = None,
                 skip_paths=None) -> PyTree:
    """Zeros buffer (m_leaf, *shape) per selected leaf; None for excluded
    leaves. The window length is PER LEAF (plan.m — the leaf's schedule
    group), so mixed-m configs size each buffer to its own group.

    Selection comes from `plans` when given (the accelerator path), else
    from plans built on the spot (standalone callers with flat pytrees).
    `skip_paths` (a set of normalized paths) excludes leaves served by a
    packed arena instead (core/arena.py) — those live in the bucket's
    block-major ring buffer, not here. Abstract-aware: ShapeDtypeStruct params
    produce ShapeDtypeStruct buffers (the dry-run path must never
    materialize m x params of zeros).
    """
    if plans is None:
        plans = build_plans(params, cfg)
    dtype = jnp.dtype(cfg.snapshot_dtype)
    skip_paths = skip_paths or frozenset()

    def make(plan, leaf):
        if plan is None or plan.path in skip_paths:
            return None
        shape = (plan.m,) + tuple(leaf.shape)
        if isinstance(leaf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)
    return jax.tree_util.tree_map(make, plans, params, is_leaf=is_plan_leaf)


def record(buffers: PyTree, params: PyTree, slot,
           plans: Optional[PyTree] = None, group: Optional[int] = None
           ) -> PyTree:
    """Write current params into each buffer's row for this step (donated
    update; a local dynamic-slice regardless of sharding or stacking).

    `slot` is a scalar (one row for every leaf — the legacy single-group
    idiom) or a per-group vector indexed by ``plan.group``. Concrete
    negative slots skip the leaf (host-side standalone callers pass
    ``acc.slots(step)`` directly); traced slots must be pre-gated by the
    caller — the fused train step conds per group — and are clamped to 0.
    `group` (static) restricts the write to that group's leaves: the
    per-group ``lax.cond`` branches use it so a cooldown group's buffers
    are never touched.
    """
    if plans is None:
        if group is not None or getattr(slot, "ndim", 0) == 1:
            raise ValueError("per-group record needs the plan pytree")

        def upd(buf, p):
            if buf is None:
                return None
            return jax.lax.dynamic_update_index_in_dim(
                buf, p.astype(buf.dtype), slot, axis=0)
        return jax.tree_util.tree_map(upd, buffers, params,
                                      is_leaf=lambda x: x is None)

    def upd(plan, buf, p):
        if buf is None or plan is None:
            return None
        if group is not None and plan.group != group:
            return buf
        s = _leaf_slot(plan, slot)
        si = _static_int(s)
        if si is not None:
            if si < 0:
                return buf
            s = si
        else:
            s = jnp.maximum(s, 0)
        return jax.lax.dynamic_update_index_in_dim(
            buf, p.astype(buf.dtype), s, axis=0)
    return jax.tree_util.tree_map(upd, plans, buffers, params,
                                  is_leaf=is_plan_leaf)


def init_grams(buffers: PyTree, cfg, plans: PyTree) -> PyTree:
    """Zeros running Gram (stack..., m_leaf, m_leaf) fp32 per buffer leaf
    (m_leaf from the leaf's schedule group); None where the buffer is None.
    Mirrors the buffer pytree so the two thread through jitted steps
    together. Abstract-aware like init_buffers."""
    del cfg

    def make(plan, buf):
        if buf is None or plan is None:
            return None
        shape = plan.stack_shape + (plan.m, plan.m)
        if isinstance(buf, jax.ShapeDtypeStruct):
            return jax.ShapeDtypeStruct(shape, jnp.float32)
        return jnp.zeros(shape, jnp.float32)
    return jax.tree_util.tree_map(make, plans, buffers, is_leaf=is_plan_leaf)


def _stream_gram_row(plan: LeafPlan, buf, p, cfg):
    """One leaf's streaming row <d_p, d_j>, dispatched by the plan's route
    (DESIGN.md §3): the flat Pallas kernels for flat-safe leaves, the
    shard_map'd kernels for stacked/sharded ones (local flatten + psum —
    never a GSPMD all-gather), dot_general as the audited fallback."""
    from repro.kernels import ops, sharded

    anchor_first = cfg.anchor == "first"
    if plan.route == "pallas_flat":
        return ops.gram_row(buf, p.astype(buf.dtype),
                            anchor_first=anchor_first, block_n=plan.block_n)
    if plan.route == "pallas_shard_map":
        return sharded.gram_row(buf, p.astype(buf.dtype), plan,
                                anchor_first=anchor_first)
    return dmd_math.gram_row_matrix(
        buf, p.astype(buf.dtype), anchor=cfg.anchor,
        stack_dims=plan.stack_dims, upcast=cfg.gram_upcast)


def update_grams(grams: PyTree, buffers: PyTree, params: PyTree, slot,
                 cfg, plans: PyTree, group: Optional[int] = None) -> PyTree:
    """Streaming Gram maintenance: after `record` wrote params into each
    leaf's row, refresh that row+column of every running Gram with one
    O(m*n) anchored inner-product pass per leaf, kernel-routed by the
    leaf's plan. `slot` / `group` follow the `record` conventions (scalar
    or per-group vector; concrete negatives skip; static `group` restricts
    to one schedule group). See DESIGN.md §2 for why this equals the full
    gram_matrix recompute at every window-complete point.
    """
    def upd(plan, g, buf, p):
        if g is None or plan is None:
            return None
        if group is not None and plan.group != group:
            return g
        s = _leaf_slot(plan, slot)
        si = _static_int(s)
        if si is not None and si < 0:
            return g
        row = _stream_gram_row(plan, buf, p, cfg)
        return dmd_math.set_gram_row(g, row, s if si is None else si)

    return jax.tree_util.tree_map(upd, plans, grams, buffers, params,
                                  is_leaf=is_plan_leaf)


def recompute_grams(grams: PyTree, buffers: PyTree, cfg, plans: PyTree
                    ) -> PyTree:
    """Rebuild running Grams whose leaf is all-zero while its buffer is not
    (a checkpoint written before streaming Grams existed restores the
    template's zeros — the next mid-window apply would otherwise solve on a
    Gram with zeroed rows). Leaves with real data pass through untouched, so
    a streaming-era checkpoint resumes with its carried values.

    Host-side (restore path). The staleness test is ONE batched device
    fetch: the per-leaf scalars are computed in a single jitted program and
    pulled in one round-trip, instead of the old one-`bool(jnp.any(...))`
    -sync-per-leaf crawl. Each stale leaf then pays one O(m^2*n) oracle pass.
    """
    flat_g, treedef = jax.tree_util.tree_flatten(
        grams, is_leaf=lambda x: x is None)
    flat_b = treedef.flatten_up_to(buffers)
    live = [(i, g, b) for i, (g, b) in enumerate(zip(flat_g, flat_b))
            if g is not None and b is not None]
    if not live:
        return grams

    @jax.jit
    def staleness(gs, bs):
        return jnp.stack([(~jnp.any(g != 0)) & jnp.any(b != 0)
                          for g, b in zip(gs, bs)])

    stale = np.asarray(staleness([g for _, g, _ in live],
                                 [b for _, _, b in live]))  # one fetch
    flat_p = treedef.flatten_up_to(plans)
    out = list(flat_g)
    for flag, (i, g, buf) in zip(stale, live):
        if not bool(flag):
            continue
        plan = flat_p[i]
        nstack = plan.stack_dims if plan is not None else 0
        out[i] = dmd_math.gram_matrix(buf, anchor=cfg.anchor,
                                      stack_dims=nstack,
                                      upcast=cfg.gram_upcast)
    return jax.tree_util.tree_unflatten(treedef, out)
