from repro.core.dmd import (
    gram_matrix, gram_row_matrix, set_gram_row, dmd_coefficients,
    combine_snapshots, dmd_extrapolate, dmd_eigenvalues,
)
from repro.core.accelerator import DMDAccelerator
from repro.core.arena import ArenaBucket, ArenaSegment, build_arenas
from repro.core.controller import ControllerState
from repro.core.leafplan import LeafPlan, build_plans, plan_table
from repro.core import arena, controller, leafplan, snapshots

__all__ = [
    "gram_matrix", "gram_row_matrix", "set_gram_row", "dmd_coefficients",
    "combine_snapshots", "dmd_extrapolate", "dmd_eigenvalues",
    "DMDAccelerator", "ArenaBucket", "ArenaSegment", "build_arenas",
    "ControllerState", "LeafPlan", "build_plans",
    "plan_table", "arena", "controller", "leafplan", "snapshots",
]
