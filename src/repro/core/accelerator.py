"""DMDAccelerator: the paper's Algorithm 1 as a training-loop component.

Usage (see repro.train.loop for full integration):

    acc = DMDAccelerator(cfg.dmd, mesh=mesh,
                         stack_dims=model.param_stack_dims())
    buffers = acc.init(params)               # also builds the LeafPlan table
    grams = acc.init_grams(buffers)          # streaming-Gram state (or None)
    # every optimizer step (record always returns the (buffers, grams)
    # pair; grams stays None when not streaming):
    buffers, grams = acc.record(buffers, params, acc.slot(step), grams)
    if acc.should_apply(step):
        params, stats = acc.apply(params, buffers, round_idx, grams=grams)

`record` is fused into the jitted train step by the trainer; `apply` is its
own jitted program (runs every m steps). Both operate on the whole param
pytree at once — XLA fuses the per-layer DMD updates, realizing the paper's
"easily parallelized across layers" note as a single SPMD program.

LeafPlan registry (core/leafplan.py, DESIGN.md §3): every per-leaf routing
decision — leading stack axes, kernel route (``pallas_flat`` |
``pallas_shard_map`` | ``dot_general``), buffer/Gram PartitionSpecs, n-tile —
is computed ONCE per leaf from the real param pytree + mesh + the model's
structural `param_stack_dims()` annotation, and carried as a pytree of frozen
`LeafPlan` records aligned 1:1 with params/buffers/grams. `plans_for(params)`
builds (and caches) the table — it reads only shape/path metadata, so it also
works at trace time inside a jitted step — and `plan_table()` renders the
audited dispatch table:

    print(acc.plan_table(params))
    # path           route             stack  shape        flat_n  spec ...
    # /seg0/attn/wqkv pallas_shard_map 1      48x2048x2560 5242880 ...

Streaming Gram (DESIGN.md §2): with cfg.streaming_gram the (stack..., m, m)
Gram is maintained incrementally — each record adds one O(m*n) row pass —
so `apply` skips the O(m^2*n) gram_matrix recompute entirely and runs pure
O(m^3) coefficient algebra plus one combine pass. gram_matrix remains the
correctness oracle (and the cfg.streaming_gram=False A/B baseline).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dmd, leafplan, snapshots as snap

PyTree = Any


@dataclass
class LeafJump:
    """Result of one leaf's DMD jump. Deliberately NOT a registered pytree:
    it must survive tree_map as an opaque leaf so callers can split it with
    an isinstance check — the old (params, rank) tuples were sniffed by
    shape, which silently mis-split params pytrees containing genuine
    2-tuple nodes."""
    params: Any
    rank: Any


def dmd_leaf_jump(cfg, plan: leafplan.LeafPlan, p, buf, gram, relax):
    """One leaf of the DMD jump: coefficients from `gram` (the carried
    streaming Gram; recomputed from the buffer when None) + one combine
    pass, both kernel-routed by the leaf's plan. Shared by
    DMDAccelerator.apply and train.step.make_dmd_step."""
    from repro.kernels import ops, sharded

    nstack = plan.stack_dims
    anchor_first = cfg.anchor == "first"
    if gram is None:
        if plan.route == "pallas_shard_map" and plan.anchor_ok:
            gram = sharded.gram(buf, plan, anchor_first=anchor_first)
        elif plan.route == "pallas_flat" and plan.anchor_ok:
            gram = ops.gram(buf, anchor_first=anchor_first,
                            block_n=plan.block_n)
        else:
            gram = dmd.gram_matrix(buf, anchor=cfg.anchor, stack_dims=nstack,
                                   upcast=cfg.gram_upcast)
    c, info = dmd.dmd_coefficients(
        gram, s=cfg.s, tol=cfg.tol, mode=cfg.mode,
        clamp_eigs=cfg.clamp_eigs, anchor=cfg.anchor,
        affine=cfg.affine, trust_region=cfg.trust_region, relax=relax)
    if plan.route == "pallas_shard_map":
        w = sharded.combine(buf, c, plan)
    elif plan.route == "pallas_flat":
        w = ops.combine(buf, c, block_n=plan.block_n)
    else:
        w = dmd.combine_snapshots(buf, c, stack_dims=nstack,
                                  upcast=cfg.gram_upcast)
    # Even c = e_last cannot save a non-finite BUFFER: the combine contracts
    # every row, and 0 * inf = NaN. The jump must never leave params less
    # finite than the last snapshot — fall back elementwise.
    w = jnp.where(jnp.isfinite(w), w, buf[-1].astype(w.dtype))
    return w.astype(p.dtype), jnp.mean(info["rank"].astype(jnp.float32))


def jump_tree(cfg, plans: PyTree, params: PyTree, buffers: PyTree,
              grams: PyTree, relax) -> Tuple[PyTree, jnp.ndarray]:
    """Whole-pytree DMD jump keyed by the plan table: returns (new_params,
    mean_rank). Excluded leaves (plan None) pass through untouched."""
    def one(plan, p, buf, g):
        if plan is None or buf is None:
            return p
        w, rank = dmd_leaf_jump(cfg, plan, p, buf, g, relax)
        return LeafJump(w, rank)

    out = jax.tree_util.tree_map(one, plans, params, buffers, grams,
                                 is_leaf=leafplan.is_plan_leaf)
    is_jump = lambda x: isinstance(x, LeafJump)
    new_params = jax.tree_util.tree_map(
        lambda o: o.params if isinstance(o, LeafJump) else o, out,
        is_leaf=is_jump)
    ranks = [o.rank for o in jax.tree_util.tree_leaves(out, is_leaf=is_jump)
             if isinstance(o, LeafJump)]
    mean_rank = (jnp.mean(jnp.stack([r.astype(jnp.float32) for r in ranks]))
                 if ranks else jnp.zeros((), jnp.float32))
    return new_params, mean_rank


def _none_like(buffers: PyTree) -> PyTree:
    """All-None tree matching `buffers` (placeholder gram tree)."""
    return jax.tree_util.tree_map(lambda b: None, buffers,
                                  is_leaf=lambda x: x is None)


class DMDAccelerator:
    def __init__(self, cfg, *, mesh=None, stack_dims: Optional[PyTree] = None):
        """`mesh` + `stack_dims` (the model's structural
        `param_stack_dims()` pytree; None = no stacked leaves) feed the
        LeafPlan table built lazily from the first param pytree seen."""
        self.cfg = cfg
        self.mesh = mesh
        self.stack_dims = stack_dims
        self._plans = None
        self._plans_key = None
        self._apply_jit = None

    @property
    def streaming(self) -> bool:
        """Streaming-Gram engine active? (anchor="mean" has no one-pass row
        update — its anchor moves with every record — so it keeps the
        recompute path.)"""
        return (self.cfg.enabled and self.cfg.streaming_gram
                and self.cfg.anchor in ("none", "first"))

    # ---- the per-leaf dispatch table --------------------------------------
    def plans_for(self, params: PyTree) -> PyTree:
        """LeafPlan pytree for `params`, cached by structure+shape. Reads
        only metadata, so it is trace-safe (params may be tracers or
        ShapeDtypeStructs)."""
        key = (jax.tree_util.tree_structure(params),
               tuple(tuple(l.shape)
                     for l in jax.tree_util.tree_leaves(params)))
        if self._plans is None or self._plans_key != key:
            self._plans = leafplan.build_plans(params, self.cfg, self.mesh,
                                               self.stack_dims)
            self._plans_key = key
        return self._plans

    def plan_table(self, params: Optional[PyTree] = None) -> str:
        """Audited dispatch-table dump (path / route / stack / shape / spec
        per selected leaf). Needs the plans built — pass `params` on first
        use."""
        if params is not None:
            self.plans_for(params)
        if self._plans is None:
            raise ValueError("no plans built yet — pass params")
        return leafplan.plan_table(self._plans)

    # ---- schedule ---------------------------------------------------------
    # Cycle after warmup: [cooldown unrecorded steps][m recorded steps -> jump]
    # The cooldown (beyond-paper, default 0 = paper's Algorithm 1) lets the
    # optimizer moments re-adapt after a jump so the next window measures the
    # trajectory's own dynamics, not the post-jump transient.
    def _cycle(self) -> int:
        return self.cfg.cooldown_steps + self.cfg.m

    def slot(self, step: int) -> int:
        """Buffer row for the snapshot taken after optimizer step `step`.

        Returns -1 during warmup/cooldown phases (not recorded); otherwise the
        row 0..m-1. A DMD jump happens when slot m-1 is written, then the
        window restarts (paper: bp_iter = 0).
        """
        eff = step - self.cfg.warmup_steps
        if eff < 0:
            return -1
        return (eff % self._cycle()) - self.cfg.cooldown_steps

    def should_record(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) >= 0

    def should_apply(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) == self.cfg.m - 1

    def round_index(self, step: int) -> int:
        eff = step - self.cfg.warmup_steps
        return eff // self._cycle()

    def relax_for_round(self, round_idx: int) -> float:
        return float(self.cfg.relax * (self.cfg.anneal ** max(round_idx, 0)))

    # ---- state ------------------------------------------------------------
    def init(self, params: PyTree) -> PyTree:
        if not self.cfg.enabled:
            return None
        return snap.init_buffers(params, self.cfg, self.plans_for(params))

    def init_grams(self, buffers: PyTree) -> Optional[PyTree]:
        """Running-Gram pytree mirroring `buffers` (None when not streaming)."""
        if buffers is None or not self.streaming:
            return None
        if self._plans is None:
            raise ValueError("init_grams before init: no LeafPlan table yet")
        return snap.init_grams(buffers, self.cfg, self._plans)

    def record(self, buffers: PyTree, params: PyTree, slot,
               grams: Optional[PyTree] = None) -> Tuple[PyTree, PyTree]:
        """Write params into row `slot`; with `grams` also refresh the
        streaming Gram row. ALWAYS returns (buffers, grams) — grams stays
        None for non-streaming callers — so `buffers, grams =
        acc.record(...)` is the one idiom regardless of configuration."""
        if buffers is None:
            return None, None
        plans = self.plans_for(params)
        new_bufs = snap.record(buffers, params, slot, plans)
        if grams is None:
            return new_bufs, None
        new_grams = snap.update_grams(grams, new_bufs, params, slot,
                                      self.cfg, plans)
        return new_bufs, new_grams

    # ---- the DMD jump -----------------------------------------------------
    def _apply_impl(self, params: PyTree, buffers: PyTree, grams: PyTree,
                    relax: jnp.ndarray) -> Tuple[PyTree, dict]:
        plans = self.plans_for(params)
        new_params, mean_rank = jump_tree(self.cfg, plans, params, buffers,
                                          grams, relax)
        return new_params, {"mean_rank": mean_rank}

    def apply(self, params: PyTree, buffers: PyTree,
              round_idx: int = 0, grams: Optional[PyTree] = None
              ) -> Tuple[PyTree, dict]:
        if buffers is None:
            return params, {}
        if grams is None or not self.streaming:
            grams = _none_like(buffers)
        self.plans_for(params)        # build outside the trace for caching
        if self._apply_jit is None:
            self._apply_jit = jax.jit(self._apply_impl, donate_argnums=(0,))
        relax = jnp.asarray(self.relax_for_round(round_idx), jnp.float32)
        return self._apply_jit(params, buffers, grams, relax)
