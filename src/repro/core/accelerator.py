"""DMDAccelerator: the paper's Algorithm 1 as a training-loop component.

Usage (see repro.train.loop for full integration):

    acc = DMDAccelerator(cfg.dmd)
    buffers = acc.init(params)
    # every optimizer step:
    buffers = acc.record(buffers, params, acc.slot(step))
    if acc.should_apply(step):
        params, stats = acc.apply(params, buffers, round_idx)

`record` is fused into the jitted train step by the trainer; `apply` is its
own jitted program (runs every m steps). Both operate on the whole param
pytree at once — XLA fuses the per-layer DMD updates, realizing the paper's
"easily parallelized across layers" note as a single SPMD program.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core import dmd, snapshots as snap

PyTree = Any


class DMDAccelerator:
    def __init__(self, cfg):
        self.cfg = cfg
        self._apply_jit = None

    # ---- schedule ---------------------------------------------------------
    # Cycle after warmup: [cooldown unrecorded steps][m recorded steps -> jump]
    # The cooldown (beyond-paper, default 0 = paper's Algorithm 1) lets the
    # optimizer moments re-adapt after a jump so the next window measures the
    # trajectory's own dynamics, not the post-jump transient.
    def _cycle(self) -> int:
        return self.cfg.cooldown_steps + self.cfg.m

    def slot(self, step: int) -> int:
        """Buffer row for the snapshot taken after optimizer step `step`.

        Returns -1 during warmup/cooldown phases (not recorded); otherwise the
        row 0..m-1. A DMD jump happens when slot m-1 is written, then the
        window restarts (paper: bp_iter = 0).
        """
        eff = step - self.cfg.warmup_steps
        if eff < 0:
            return -1
        return (eff % self._cycle()) - self.cfg.cooldown_steps

    def should_record(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) >= 0

    def should_apply(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) == self.cfg.m - 1

    def round_index(self, step: int) -> int:
        eff = step - self.cfg.warmup_steps
        return eff // self._cycle()

    def relax_for_round(self, round_idx: int) -> float:
        return float(self.cfg.relax * (self.cfg.anneal ** max(round_idx, 0)))

    # ---- state ------------------------------------------------------------
    def init(self, params: PyTree) -> PyTree:
        if not self.cfg.enabled:
            return None
        return snap.init_buffers(params, self.cfg)

    def record(self, buffers: PyTree, params: PyTree, slot) -> PyTree:
        if buffers is None:
            return None
        return snap.record(buffers, params, slot)

    # ---- the DMD jump -----------------------------------------------------
    def _apply_impl(self, params: PyTree, buffers: PyTree,
                    relax: jnp.ndarray) -> Tuple[PyTree, dict]:
        cfg = self.cfg

        def one(path, p, buf):
            if buf is None:
                return p, jnp.asarray(0, jnp.int32)
            nstack = snap.stack_dims_for_path(jax.tree_util.keystr(path))
            gram = dmd.gram_matrix(buf, anchor=cfg.anchor, stack_dims=nstack,
                                   upcast=cfg.gram_upcast)
            c, info = dmd.dmd_coefficients(
                gram, s=cfg.s, tol=cfg.tol, mode=cfg.mode,
                clamp_eigs=cfg.clamp_eigs, anchor=cfg.anchor,
                affine=cfg.affine, trust_region=cfg.trust_region, relax=relax)
            w = dmd.combine_snapshots(buf, c, stack_dims=nstack,
                                              upcast=cfg.gram_upcast)
            return w.astype(p.dtype), jnp.mean(info["rank"].astype(jnp.float32))

        out = jax.tree_util.tree_map_with_path(one, params, buffers,
                                               is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], tuple)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=is_pair)
        ranks = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=is_pair)
        mean_rank = jnp.mean(jnp.stack(
            [r.astype(jnp.float32) for r in jax.tree_util.tree_leaves(ranks)]))
        return new_params, {"mean_rank": mean_rank}

    def apply(self, params: PyTree, buffers: PyTree,
              round_idx: int = 0) -> Tuple[PyTree, dict]:
        if buffers is None:
            return params, {}
        if self._apply_jit is None:
            self._apply_jit = jax.jit(self._apply_impl, donate_argnums=(0,))
        relax = jnp.asarray(self.relax_for_round(round_idx), jnp.float32)
        return self._apply_jit(params, buffers, relax)
