"""DMDAccelerator: the paper's Algorithm 1 as a training-loop component.

Usage (see repro.train.loop for full integration):

    acc = DMDAccelerator(cfg.dmd)
    buffers = acc.init(params)
    grams = acc.init_grams(buffers)          # streaming-Gram state (or None)
    # every optimizer step (record always returns the (buffers, grams)
    # pair; grams stays None when not streaming):
    buffers, grams = acc.record(buffers, params, acc.slot(step), grams)
    if acc.should_apply(step):
        params, stats = acc.apply(params, buffers, round_idx, grams=grams)

`record` is fused into the jitted train step by the trainer; `apply` is its
own jitted program (runs every m steps). Both operate on the whole param
pytree at once — XLA fuses the per-layer DMD updates, realizing the paper's
"easily parallelized across layers" note as a single SPMD program.

Streaming Gram (DESIGN.md §2): with cfg.streaming_gram the (stack..., m, m)
Gram is maintained incrementally — each record adds one O(m*n) row pass —
so `apply` skips the O(m^2*n) gram_matrix recompute entirely and runs pure
O(m^3) coefficient algebra plus one combine pass. gram_matrix remains the
correctness oracle (and the cfg.streaming_gram=False A/B baseline).
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import dmd, snapshots as snap

PyTree = Any


def dmd_leaf_jump(cfg, path, p, buf, gram, relax):
    """One leaf of the DMD jump: coefficients from `gram` (the carried
    streaming Gram; recomputed from the buffer when None) + one combine
    pass. Shared by DMDAccelerator.apply and train.step.make_dmd_step."""
    nstack = snap.stack_dims_for_path(jax.tree_util.keystr(path))
    if gram is None:
        gram = dmd.gram_matrix(buf, anchor=cfg.anchor, stack_dims=nstack,
                               upcast=cfg.gram_upcast)
    c, info = dmd.dmd_coefficients(
        gram, s=cfg.s, tol=cfg.tol, mode=cfg.mode,
        clamp_eigs=cfg.clamp_eigs, anchor=cfg.anchor,
        affine=cfg.affine, trust_region=cfg.trust_region, relax=relax)
    w = dmd.combine_snapshots(buf, c, stack_dims=nstack,
                              upcast=cfg.gram_upcast)
    # Even c = e_last cannot save a non-finite BUFFER: the combine contracts
    # every row, and 0 * inf = NaN. The jump must never leave params less
    # finite than the last snapshot — fall back elementwise.
    w = jnp.where(jnp.isfinite(w), w, buf[-1].astype(w.dtype))
    return w.astype(p.dtype), jnp.mean(info["rank"].astype(jnp.float32))


def _none_like(buffers: PyTree) -> PyTree:
    """All-None tree matching `buffers` (placeholder gram tree)."""
    return jax.tree_util.tree_map(lambda b: None, buffers,
                                  is_leaf=lambda x: x is None)


class DMDAccelerator:
    def __init__(self, cfg):
        self.cfg = cfg
        self._apply_jit = None

    @property
    def streaming(self) -> bool:
        """Streaming-Gram engine active? (anchor="mean" has no one-pass row
        update — its anchor moves with every record — so it keeps the
        recompute path.)"""
        return (self.cfg.enabled and self.cfg.streaming_gram
                and self.cfg.anchor in ("none", "first"))

    # ---- schedule ---------------------------------------------------------
    # Cycle after warmup: [cooldown unrecorded steps][m recorded steps -> jump]
    # The cooldown (beyond-paper, default 0 = paper's Algorithm 1) lets the
    # optimizer moments re-adapt after a jump so the next window measures the
    # trajectory's own dynamics, not the post-jump transient.
    def _cycle(self) -> int:
        return self.cfg.cooldown_steps + self.cfg.m

    def slot(self, step: int) -> int:
        """Buffer row for the snapshot taken after optimizer step `step`.

        Returns -1 during warmup/cooldown phases (not recorded); otherwise the
        row 0..m-1. A DMD jump happens when slot m-1 is written, then the
        window restarts (paper: bp_iter = 0).
        """
        eff = step - self.cfg.warmup_steps
        if eff < 0:
            return -1
        return (eff % self._cycle()) - self.cfg.cooldown_steps

    def should_record(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) >= 0

    def should_apply(self, step: int) -> bool:
        return self.cfg.enabled and self.slot(step) == self.cfg.m - 1

    def round_index(self, step: int) -> int:
        eff = step - self.cfg.warmup_steps
        return eff // self._cycle()

    def relax_for_round(self, round_idx: int) -> float:
        return float(self.cfg.relax * (self.cfg.anneal ** max(round_idx, 0)))

    # ---- state ------------------------------------------------------------
    def init(self, params: PyTree) -> PyTree:
        if not self.cfg.enabled:
            return None
        return snap.init_buffers(params, self.cfg)

    def init_grams(self, buffers: PyTree) -> Optional[PyTree]:
        """Running-Gram pytree mirroring `buffers` (None when not streaming)."""
        if buffers is None or not self.streaming:
            return None
        return snap.init_grams(buffers, self.cfg)

    def record(self, buffers: PyTree, params: PyTree, slot,
               grams: Optional[PyTree] = None) -> Tuple[PyTree, PyTree]:
        """Write params into row `slot`; with `grams` also refresh the
        streaming Gram row. ALWAYS returns (buffers, grams) — grams stays
        None for non-streaming callers — so `buffers, grams =
        acc.record(...)` is the one idiom regardless of configuration."""
        if buffers is None:
            return None, None
        new_bufs = snap.record(buffers, params, slot)
        if grams is None:
            return new_bufs, None
        new_grams = snap.update_grams(grams, new_bufs, params, slot, self.cfg)
        return new_bufs, new_grams

    # ---- the DMD jump -----------------------------------------------------
    def _apply_impl(self, params: PyTree, buffers: PyTree, grams: PyTree,
                    relax: jnp.ndarray) -> Tuple[PyTree, dict]:
        cfg = self.cfg

        def one(path, p, buf, g):
            if buf is None:
                return p, jnp.asarray(0, jnp.int32)
            return dmd_leaf_jump(cfg, path, p, buf, g, relax)

        out = jax.tree_util.tree_map_with_path(one, params, buffers, grams,
                                               is_leaf=lambda x: x is None)
        is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], tuple)
        new_params = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=is_pair)
        ranks = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=is_pair)
        mean_rank = jnp.mean(jnp.stack(
            [r.astype(jnp.float32) for r in jax.tree_util.tree_leaves(ranks)]))
        return new_params, {"mean_rank": mean_rank}

    def apply(self, params: PyTree, buffers: PyTree,
              round_idx: int = 0, grams: Optional[PyTree] = None
              ) -> Tuple[PyTree, dict]:
        if buffers is None:
            return params, {}
        if grams is None or not self.streaming:
            grams = _none_like(buffers)
        if self._apply_jit is None:
            self._apply_jit = jax.jit(self._apply_impl, donate_argnums=(0,))
        relax = jnp.asarray(self.relax_for_round(round_idx), jnp.float32)
        return self._apply_jit(params, buffers, grams, relax)
