"""DMDAccelerator: the paper's Algorithm 1 as a training-loop component.

Usage (see repro.train.loop for full integration):

    acc = DMDAccelerator(cfg.dmd, mesh=mesh,
                         stack_dims=model.param_stack_dims())
    buffers = acc.init(params)               # also builds the LeafPlan table
    grams = acc.init_grams(buffers)          # streaming-Gram state (or None)
    # every optimizer step (record always returns the (buffers, grams)
    # pair; grams stays None when not streaming). acc.slots(step) is the
    # per-group slot vector — groups not recording (slot < 0) are skipped:
    buffers, grams = acc.record(buffers, params, acc.slots(step), grams)
    if acc.should_apply(step):               # some group's window closed
        params, stats = acc.apply(params, buffers, grams=grams, step=step)

Per-leaf scheduling (core/schedule.py, DESIGN.md §4): the schedule is a
TABLE of groups — group 0 is the DMDConfig globals, further groups come
from cfg.groups rules resolved per leaf at plan-build time. slot /
should_record / should_apply / round_index are per-group queries
(`group=` arg, default 0); `slots(step)` / `apply_groups(step)` /
`relax_vector(step)` are the whole-table views the Trainer and the fused
train step consume. Groups with distinct `phase` offsets jump on different
steps, so at most a subset of leaves pays the jump at any step.

`record` is fused into the jitted train step by the trainer; `apply` is its
own jitted program (runs every m steps). Both operate on the whole param
pytree at once — XLA fuses the per-layer DMD updates, realizing the paper's
"easily parallelized across layers" note as a single SPMD program.

LeafPlan registry (core/leafplan.py, DESIGN.md §3): every per-leaf routing
decision — leading stack axes, kernel route (``pallas_flat`` |
``pallas_shard_map`` | ``dot_general``), buffer/Gram PartitionSpecs, n-tile —
is computed ONCE per leaf from the real param pytree + mesh + the model's
structural `param_stack_dims()` annotation, and carried as a pytree of frozen
`LeafPlan` records aligned 1:1 with params/buffers/grams. `plans_for(params)`
builds (and caches, keyed by structure+shape+dtype) the table — it reads only
shape/path metadata, so it also works at trace time inside a jitted step —
and `plan_table()` renders the audited dispatch table with the schedule
columns (group / m / phase):

    print(acc.plan_table(params))
    # path            route            group    m   s  phase energy stack arena        off ...
    # /seg0/attn/wqkv pallas_shard_map default  14  55 0     -      1     g0-bfloat16  0
    # /final_norm/... pallas_flat      norms    6   24 7     0.995  0     g1-bfloat16  4096

(`s` is the group's configured horizon — the static cap the controller's
adapted horizon lives under; `energy` shows the controller-mode
cumulative-energy rank target, "-" while the tol mask rules; `arena` /
`off` show each leaf's packed-bucket assignment and lane offset —
core/arena.py, DESIGN.md §7 — "-" for leaves kept on the per-leaf route;
`scope` shows the leaf's DMD granularity under cfg.scope — "bucket" when
its bucket fits ONE shared Koopman operator over the concatenated bucket
state, "leaf" otherwise — DESIGN.md §9.)

Bucket-scope Koopman DMD (cfg.scope="bucket", DESIGN.md §9): each arena
bucket becomes ONE DMD system — the streaming update writes the (m, m)
segment-summed bucket Gram directly (same segmented kernels, collapsed
block table), the jump solves n_buckets coefficient systems per group
instead of n_leaves (eig host-callback batches shrink identically), and
the combine broadcasts one coefficient row per bucket. `spectrum_table()`
renders the per-bucket Koopman eigenvalue magnitudes / mode decay rates
as a convergence diagnostic (comparable across scopes — leaf scope
segment-sums its Grams first). Default "leaf" is bit-exact legacy.

Packed arenas (core/arena.py, DESIGN.md §7): with cfg.arena (default on)
all compatible leaves of a schedule group are packed into contiguous
per-bucket block-major (n_blocks, m, block_n) ring buffers at init (the
layout that keeps every arena pass a batch-leading contraction and makes
the TPU tile the storage tile) — the snapshot/Gram/combine data
passes then cost ONE segmented kernel launch per bucket per step
(kernels/arena.py) and the jump ONE batched coefficient solve per group,
instead of one launch + one eigensolve per leaf. `arena_for(params)`
exposes the bucket table; `init`/`record`/`apply` transparently carry the
``{"__arena__": ..., "leaf": ...}`` two-route state. cfg.arena=False is
the per-leaf A/B oracle (bit-exact with the pre-arena route).

Arena-native residency (cfg.arena_native, DESIGN.md §7): during
``Trainer.fit`` the packed leaves' PARAMS (and elementwise optimizer
moments) also live in the bucket buffers, carried as the same wrapper
layout. Every entry point here is layout-driven — `record` turns into one
dynamic_update_slice per bucket when it sees resident params, `jump_tree`
writes flat bucket rows back without an unpack scatter, and
`state_leafwise` expands residency for checkpoints, so disk format and
non-fit callers never see the wrapper. ``arena_native=False`` keeps the
PR-5 pack-copy route as the bit-exact A/B oracle.

Streaming Gram (DESIGN.md §2): with cfg.streaming_gram the (stack..., m, m)
Gram is maintained incrementally — each record adds one O(m*n) row pass —
so `apply` skips the O(m^2*n) gram_matrix recompute entirely and runs pure
O(m^3) coefficient algebra plus one combine pass. gram_matrix remains the
correctness oracle (and the cfg.streaming_gram=False A/B baseline).

Jump controller (core/controller.py, DESIGN.md §5): with
cfg.controller.enabled the Trainer's jitted DMD step gates every jump on a
held-out microbatch loss (accept / halve-relax re-blend / bit-exact
rollback) and carries per-group ControllerState in TrainState —
`init_controller()` builds it, `controller_on` reports the mode. The
host-side `apply` below stays UNGATED (benches and examples gate by hand);
the gated path lives in train/step.py::make_dmd_step.

Static audits (repro.audit, DESIGN.md §8): every structural invariant
above — buffer/Gram donation, the sharded kernels' collective budget,
trace size, arena lane alignment, schedule phase disjointness — is
checked against the lowered jaxprs/HLO of the step fns built from this
module plus the plan/schedule/arena tables by

    PYTHONPATH=src python -m repro.audit --arch <name> [--reduced] [--mesh DxM]

which CI runs per config (nonzero exit on violation; see the pass
catalog in DESIGN.md §8).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import arena as arena_mod
from repro.core import dmd, leafplan, schedule as sched_mod
from repro.core import snapshots as snap

PyTree = Any


@dataclass
class LeafJump:
    """Result of one leaf's DMD jump. Deliberately NOT a registered pytree:
    it must survive tree_map as an opaque leaf so callers can split it with
    an isinstance check — the old (params, rank) tuples were sniffed by
    shape, which silently mis-split params pytrees containing genuine
    2-tuple nodes."""
    params: Any
    rank: Any


def dmd_leaf_jump(cfg, plan: leafplan.LeafPlan, p, buf, gram, relax,
                  s_dyn=None, ridge_dyn=None):
    """One leaf of the DMD jump: coefficients from `gram` (the carried
    streaming Gram; recomputed from the buffer when None) + one combine
    pass, both kernel-routed by the leaf's plan. The extrapolation horizon
    `s` is the leaf's GROUP horizon (plan.sched.s) — mixed-window groups
    jump different distances; in controller mode `s_dyn` (a traced scalar,
    the group's adapted horizon) replaces it, with plan.sched.s as the
    static cap, the group's energy target replaces the tol mask, and
    `ridge_dyn` (traced, the controller's meta-tuned shrinkage) overrides
    the group's static ridge. Shared by DMDAccelerator.apply and
    train.step.make_dmd_step."""
    from repro.kernels import ops, sharded

    nstack = plan.stack_dims
    anchor_first = cfg.anchor == "first"
    if gram is None:
        if plan.route == "pallas_shard_map" and plan.anchor_ok:
            gram = sharded.gram(buf, plan, anchor_first=anchor_first)
        elif plan.route == "pallas_flat" and plan.anchor_ok:
            gram = ops.gram(buf, anchor_first=anchor_first,
                            block_n=plan.block_n)
        else:
            gram = dmd.gram_matrix(buf, anchor=cfg.anchor, stack_dims=nstack,
                                   upcast=cfg.gram_upcast)
    s = plan.sched.s if plan.sched is not None else cfg.s
    energy = plan.sched.energy if plan.sched is not None else 0.0
    ridge = plan.sched.ridge if plan.sched is not None else 0.0
    c, info = dmd.dmd_coefficients(
        gram, s=s, tol=cfg.tol, mode=cfg.mode,
        clamp_eigs=cfg.clamp_eigs, anchor=cfg.anchor,
        affine=cfg.affine, trust_region=cfg.trust_region, relax=relax,
        energy=energy, s_dyn=s_dyn, atol=getattr(cfg, "atol", 0.0),
        ridge=ridge, ridge_dyn=ridge_dyn)
    if plan.route == "pallas_shard_map":
        w = sharded.combine(buf, c, plan)
    elif plan.route == "pallas_flat":
        w = ops.combine(buf, c, block_n=plan.block_n)
    else:
        w = dmd.combine_snapshots(buf, c, stack_dims=nstack,
                                  upcast=cfg.gram_upcast)
    # Even c = e_last cannot save a non-finite BUFFER: the combine contracts
    # every row, and 0 * inf = NaN. The jump must never leave params less
    # finite than the last snapshot — fall back elementwise.
    w = jnp.where(jnp.isfinite(w), w, buf[-1].astype(w.dtype))
    return w.astype(p.dtype), jnp.mean(info["rank"].astype(jnp.float32))


def jump_tree(cfg, plans: PyTree, params: PyTree, buffers: PyTree,
              grams: PyTree, relax, groups: Optional[Sequence[int]] = None,
              s_vec=None, arena=None,
              ridge_vec=None) -> Tuple[PyTree, jnp.ndarray]:
    """Whole-pytree DMD jump keyed by the plan table: returns (new_params,
    mean_rank). Excluded leaves (plan None) pass through untouched.

    `groups` (STATIC iterable of schedule-group indices) masks the jump to
    those groups' leaves — the staggered schedule jumps only the group(s)
    whose window closed, so the other groups' leaves cost nothing (they are
    compile-time pass-throughs, not runtime selects). None jumps every
    group. `relax` is a scalar or a per-group (n_groups,) vector indexed by
    ``plan.group`` (each group anneals on its own round counter). `s_vec`
    (controller mode) is a traced per-group (n_groups,) int vector of
    adapted horizons — None keeps each group's static configured s.
    `ridge_vec` (controller mode) is a traced per-group (n_groups,) float
    vector of meta-tuned ridge shrinkages — None keeps each group's static
    schedule ridge.

    `arena` (the accelerator's bucket table, core/arena.py) serves every
    arena'd leaf through the packed route: one batched coefficient solve
    per jumping group plus one segmented combine launch per bucket; the
    per-leaf tree_map below then only sees the leaves the arena could not
    take (their buffer entries in the ``leaf`` subtree are None for arena'd
    paths, so the two routes partition the tree cleanly)."""
    gset = None if groups is None else frozenset(int(g) for g in groups)
    per_group = getattr(relax, "ndim", 0) == 1

    # Arena-RESIDENT params (dmd.arena_native): split the wrapper — the
    # per-leaf route below runs over the leaf subtree (None at packed
    # paths, so packed leaves are compile-time pass-throughs there), and
    # the arena jump returns whole flat bucket rows that overlay the
    # resident buffers directly (no unpack scatter at all).
    resident = arena_mod.is_arena_state(params)
    pres: dict = {}
    if resident:
        pres, params = arena_mod.split_state(params)

    arena_updates: dict = {}
    ranks: list = []
    if arena_mod.is_arena_state(buffers):
        if not arena:
            # Refuse loudly: with `arena or {}` the packed leaves would
            # silently pass through UNJUMPED (their `leaf` entries are
            # None, so neither route would touch them).
            raise ValueError(
                "buffers are arena-packed but no bucket table was given — "
                "pass arena=acc.arena_for(params) (the accelerator that "
                "built these buffers)")
        arenas, buffers = arena_mod.split_state(buffers)
        agrams, grams = (arena_mod.split_state(grams)
                         if arena_mod.is_arena_state(grams) else (None, grams))
        arena_updates, ranks = arena_mod.jump(
            cfg, arena, params, arenas, agrams, relax, groups=gset,
            s_vec=s_vec, resident=resident, ridge_vec=ridge_vec)
        ranks = list(ranks)

    def one(plan, p, buf, g):
        if plan is None or buf is None:
            return p
        if gset is not None and plan.group not in gset:
            return p
        r = relax[plan.group] if per_group else relax
        sd = None if s_vec is None else s_vec[plan.group]
        rd = None if ridge_vec is None else ridge_vec[plan.group]
        w, rank = dmd_leaf_jump(cfg, plan, p, buf, g, r, s_dyn=sd,
                                ridge_dyn=rd)
        return LeafJump(w, rank)

    out = jax.tree_util.tree_map(one, plans, params, buffers, grams,
                                 is_leaf=leafplan.is_plan_leaf)
    is_jump = lambda x: isinstance(x, LeafJump)
    new_params = jax.tree_util.tree_map(
        lambda o: o.params if isinstance(o, LeafJump) else o, out,
        is_leaf=is_jump)
    if resident:
        new_params = arena_mod.make_state({**pres, **arena_updates},
                                          new_params)
    elif arena_updates:
        from repro.distributed.sharding import normalize_path

        def overlay(kp, p):
            return arena_updates.get(
                normalize_path(jax.tree_util.keystr(kp)), p)
        new_params = jax.tree_util.tree_map_with_path(overlay, new_params)
    ranks += [o.rank for o in jax.tree_util.tree_leaves(out, is_leaf=is_jump)
              if isinstance(o, LeafJump)]
    mean_rank = (jnp.mean(jnp.stack([r.astype(jnp.float32) for r in ranks]))
                 if ranks else jnp.zeros((), jnp.float32))
    return new_params, mean_rank


def _none_like(buffers: PyTree) -> PyTree:
    """All-None tree matching `buffers` (placeholder gram tree)."""
    return jax.tree_util.tree_map(lambda b: None, buffers,
                                  is_leaf=lambda x: x is None)


class DMDAccelerator:
    def __init__(self, cfg, *, mesh=None, stack_dims: Optional[PyTree] = None):
        """`mesh` + `stack_dims` (the model's structural
        `param_stack_dims()` pytree; None = no stacked leaves) feed the
        LeafPlan table built lazily from the first param pytree seen.
        The schedule-group table (core/schedule.py) resolves eagerly from
        the config: group 0 = the globals, one more group per non-exclude
        cfg.groups rule."""
        self.cfg = cfg
        self.mesh = mesh
        self.stack_dims = stack_dims
        self.groups = sched_mod.resolve_groups(cfg)
        self.n_groups = len(self.groups)
        self._plans = None
        self._plans_key = None
        self._arena = None
        self._apply_jit = None

    @property
    def streaming(self) -> bool:
        """Streaming-Gram engine active? (anchor="mean" has no one-pass row
        update — its anchor moves with every record — so it keeps the
        recompute path.)"""
        return (self.cfg.enabled and self.cfg.streaming_gram
                and self.cfg.anchor in ("none", "first"))

    @property
    def controller_on(self) -> bool:
        """Loss-gated jump controller active? (core/controller.py,
        DESIGN.md §5). Off = the ungated schedule, bit-exact legacy."""
        ccfg = getattr(self.cfg, "controller", None)
        return bool(self.cfg.enabled and ccfg is not None and ccfg.enabled)

    def init_controller(self, abstract: bool = False):
        """Fresh per-group ControllerState carried in TrainState (None when
        the controller is off). `abstract=True` -> ShapeDtypeStruct leaves
        (dry-run)."""
        if not self.controller_on:
            return None
        from repro.core import controller as ctrl_mod
        return ctrl_mod.init_state(self.groups, abstract=abstract)

    # ---- the per-leaf dispatch table --------------------------------------
    def plans_for(self, params: PyTree) -> PyTree:
        """LeafPlan pytree for `params`, cached by structure+shape+DTYPE.
        Dtypes are part of the key because the plan records them (and
        anchor/route decisions may consult them): a bf16<->fp32 param cast
        must rebuild the table, not silently reuse a stale one. Reads only
        metadata, so it is trace-safe (params may be tracers or
        ShapeDtypeStructs)."""
        if arena_mod.is_arena_state(params):
            # Arena-resident params (dmd.arena_native): the wrapper has no
            # leaf metadata for the packed paths — the plan table that
            # BUILT the residency layout is the only valid one.
            if self._plans is None:
                raise ValueError(
                    "resident params before plans were built — call "
                    "plans_for/init on the leafwise params first")
            return self._plans
        key = (jax.tree_util.tree_structure(params),
               tuple((tuple(l.shape), str(getattr(l, "dtype", "?")))
                     for l in jax.tree_util.tree_leaves(params)))
        if self._plans is None or self._plans_key != key:
            self._plans = leafplan.build_plans(params, self.cfg, self.mesh,
                                               self.stack_dims)
            self._plans_key = key
            self._arena = None
        return self._plans

    @property
    def scope(self) -> str:
        """The DMD system granularity (DESIGN.md §9): "leaf" (default,
        bit-exact legacy — one operator per leaf/stacked layer) or
        "bucket" (one shared Koopman operator per arena bucket; the jump's
        solve batch is n_buckets, not n_leaves)."""
        return getattr(self.cfg, "scope", "leaf")

    @property
    def arena_on(self) -> bool:
        """Packed-arena route active? (core/arena.py, DESIGN.md §7).
        Off (``dmd.arena=False``) = the per-leaf route everywhere — the
        bit-exact A/B oracle."""
        return bool(self.cfg.enabled and getattr(self.cfg, "arena", True))

    def arena_for(self, params: PyTree):
        """The bucket table ({key: ArenaBucket}) for `params` — built once
        per plan table (same cache key), empty when arenas are off or no
        leaf is eligible. Static metadata only, so trace-safe like
        plans_for."""
        self.plans_for(params)
        return self._arena_table()

    def _arena_table(self):
        """Bucket table from the CURRENT plan cache (the one builder —
        arena_for and plan_table both route here, so the audited dump and
        the running kernels can never see different bucketings)."""
        if self._plans is None:
            raise ValueError("no plans built yet — pass params")
        if self._arena is None:
            self._arena = (arena_mod.build_arenas(self._plans, self.cfg,
                                                  self.mesh)
                           if self.arena_on else {})
        return self._arena

    def plan_table(self, params: Optional[PyTree] = None) -> str:
        """Audited dispatch-table dump per selected leaf: kernel route,
        schedule group / m / s / phase / energy, stack dims, shapes, the
        packed-arena assignment (`arena` = bucket key, `off` = the leaf's
        lane offset in the bucket — "-" for per-leaf-route leaves), the
        leaf's DMD `scope` ("bucket" when its bucket fits one shared
        Koopman operator under cfg.scope — DESIGN.md §9; "leaf"
        otherwise), and the PartitionSpec / psum axes. Needs the plans
        built — pass `params` on first use."""
        if params is not None:
            self.plans_for(params)
        return leafplan.plan_table(
            self._plans, self._arena_table(),
            native=bool(getattr(self.cfg, "arena_native", True)),
            scope=self.scope)

    def spectrum_table(self, buffers: PyTree,
                       grams: Optional[PyTree] = None) -> str:
        """Per-bucket Koopman spectrum dump — the convergence diagnostic
        (DESIGN.md §9): for every arena bucket, the DMD eigenvalue
        magnitudes and per-step mode decay rates of the operator the NEXT
        jump would fit, computed host-side from the carried (or recomputed)
        Gram via core/dmd.py::dmd_eigenvalues_from_gram. ``|lambda| < 1``
        modes decay (the bucket's trajectory is settling — a candidate for
        the controller's per-group exclusion), ``~ 1`` drift, ``> 1``
        grow. In bucket scope each row is the bucket's single shared
        operator; in leaf scope the bucket's per-system Grams are
        segment-summed first (the identical operator bucket scope would
        fit), so the diagnostic is comparable across scopes. Off the hot
        path — pulls O(m^2) Grams per bucket to host."""
        import numpy as np

        from repro.kernels import arena as ka

        if self._plans is None:
            raise ValueError("spectrum_table before init: no plan table yet")
        table = self._arena_table()
        rows = [("bucket", "scope", "m", "rank", "|lam|max", "|lam|min",
                 "decay/step", "eigs")]
        agrams = (arena_mod.split_state(grams)[0]
                  if arena_mod.is_arena_state(grams) else None)
        arenas = (arena_mod.split_state(buffers)[0]
                  if arena_mod.is_arena_state(buffers) else {})
        for key in sorted(table):
            b = table[key]
            g = agrams.get(key) if agrams is not None else None
            if g is None:
                g = ka.gram(arenas[key], b.scope_block_sys(self.scope),
                            b.scope_n_sys(self.scope),
                            anchor_first=self.cfg.anchor == "first",
                            anchor_mean=self.cfg.anchor == "mean",
                            block_n=b.block_n, mesh=b.mesh,
                            lane_axes=b.lane_axes, sys_axes=b.sys_axes)
            # diagnostic table, not a step fn: the sync is the point
            g = np.asarray(jax.device_get(g), np.float64)  # lint: allow-host-sync
            if not b.bucket_scoped(self.scope):
                # leaf scope: sum the per-system Grams — the concatenated-
                # state operator bucket scope would fit (exact identity)
                g = g.sum(axis=0, keepdims=True)
            lam = dmd.dmd_eigenvalues_from_gram(g[0], tol=self.cfg.tol)
            mag = np.abs(lam)
            scope = "bucket" if b.bucket_scoped(self.scope) else "leaf"
            if mag.size == 0:
                rows.append((key, scope, str(b.m), "0", "-", "-", "-", "-"))
                continue
            # decay/step: slowest mode's per-step magnitude ratio — how
            # fast the bucket's dominant dynamics die out (1.0 = drift)
            top = np.sort(mag)[::-1][:4]
            rows.append((key, scope, str(b.m), str(mag.size),
                         f"{mag.max():.4f}", f"{mag.min():.4f}",
                         f"{mag.max():.4f}",
                         " ".join(f"{v:.3f}" for v in top)))
        widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
        return "\n".join("  ".join(v.ljust(w) for v, w in zip(r, widths))
                         for r in rows)

    # ---- schedule ---------------------------------------------------------
    # Per-group cycle after warmup+phase: [cooldown unrecorded steps]
    # [m recorded steps -> jump]. The math lives in core/schedule.py
    # (GroupSchedule); these are the per-group queries plus the whole-table
    # views the Trainer consumes. Single-group configs reproduce the
    # pre-refactor scalar schedule bit-exactly (group 0 == the globals).
    def slot(self, step: int, group: int = 0) -> int:
        """Buffer row for group `group`'s snapshot after optimizer step
        `step`; negative while the group is not recording (warmup / phase /
        cooldown). A group jumps when its slot m-1 is written, then its
        window restarts (paper: bp_iter = 0)."""
        return self.groups[group].slot(step)

    def slots(self, step: int) -> np.ndarray:
        """(n_groups,) per-group slot vector — the `record` write positions
        (groups with a negative entry are skipped)."""
        return sched_mod.slots_array(self.groups, step)

    def should_record(self, step: int) -> bool:
        return self.cfg.enabled and any(
            g.should_record(step) for g in self.groups)

    def should_apply(self, step: int) -> bool:
        return self.cfg.enabled and bool(self.apply_groups(step))

    def apply_groups(self, step: int) -> Tuple[int, ...]:
        """Indices of the groups whose window closes at `step` (staggered
        phases make this usually empty or a single group)."""
        if not self.cfg.enabled:
            return ()
        return tuple(i for i, g in enumerate(self.groups)
                     if g.should_apply(step))

    def round_index(self, step: int, group: int = 0) -> int:
        return self.groups[group].round_index(step)

    def relax_for_round(self, round_idx: int, group: int = 0) -> float:
        return self.groups[group].relax_for_round(round_idx)

    def relax_vector(self, step: int) -> np.ndarray:
        """(n_groups,) relax factors at `step` — each group annealed on its
        OWN round counter. Indexed by plan.group inside jump_tree."""
        return np.asarray([g.relax_for_round(g.round_index(step))
                           for g in self.groups], np.float32)

    def reset_groups(self, groups: Optional[Sequence[int]] = None
                     ) -> Tuple[int, ...]:
        """Of the jumped groups (None = all), the ones whose optimizer
        moments should reset afterwards (sched.reset_opt — slow leaf
        families typically opt out; see core/schedule.py)."""
        src = range(self.n_groups) if groups is None else groups
        return tuple(g for g in src if self.groups[g].reset_opt)

    # ---- state ------------------------------------------------------------
    def init(self, params: PyTree) -> PyTree:
        """Snapshot state for `params`. With arenas on (DESIGN.md §7) this
        is the two-route wrapper ``{"__arena__": {bucket: block-major
        (n_blocks, m, block_n) ring buffer}, "leaf": per-leaf pytree}``
        — arena'd leaves live packed,
        the rest (dot_general oracle / sharded stack axes) keep their
        per-leaf (m, *shape) buffers; otherwise the plain per-leaf pytree.
        Abstract-aware either way (ShapeDtypeStruct in -> out)."""
        if not self.cfg.enabled:
            return None
        plans = self.plans_for(params)
        table = self.arena_for(params)
        skip = arena_mod.arena_paths(table) if table else None
        leaf = snap.init_buffers(params, self.cfg, plans, skip_paths=skip)
        if not table:
            return leaf
        abstract = any(isinstance(l, jax.ShapeDtypeStruct)
                       for l in jax.tree_util.tree_leaves(params))
        return arena_mod.make_state(
            arena_mod.init_arena_buffers(table, self.cfg, abstract=abstract),
            leaf)

    def init_grams(self, buffers: PyTree) -> Optional[PyTree]:
        """Running-Gram state mirroring `buffers` (None when not streaming):
        per-bucket (n_sys, m, m) stacks for the arenas, per-leaf
        (stack..., m, m) leaves for the rest."""
        if buffers is None or not self.streaming:
            return None
        if self._plans is None:
            raise ValueError("init_grams before init: no LeafPlan table yet")
        if not arena_mod.is_arena_state(buffers):
            return snap.init_grams(buffers, self.cfg, self._plans)
        arenas, leaf = arena_mod.split_state(buffers)
        abstract = any(isinstance(l, jax.ShapeDtypeStruct)
                       for l in jax.tree_util.tree_leaves(buffers))
        return arena_mod.make_state(
            arena_mod.init_arena_grams(self._arena_table(),
                                       scope=self.scope, abstract=abstract),
            snap.init_grams(leaf, self.cfg, self._plans))

    def record(self, buffers: PyTree, params: PyTree, slot,
               grams: Optional[PyTree] = None) -> Tuple[PyTree, PyTree]:
        """Write params into each buffer's row; with `grams` also refresh
        the streaming Gram rows. `slot` is a scalar (single-group / legacy)
        or the per-group vector from ``slots(step)`` — groups with a
        negative entry are skipped. ALWAYS returns (buffers, grams) — grams
        stays None for non-streaming callers — so `buffers, grams =
        acc.record(...)` is the one idiom regardless of configuration."""
        if buffers is None:
            return None, None
        if self.n_groups > 1 and getattr(slot, "ndim", 0) != 1:
            raise ValueError(
                f"{self.n_groups} schedule groups need the per-group slot "
                "vector — pass acc.slots(step), not a scalar slot")
        plans = self.plans_for(params)
        if not arena_mod.is_arena_state(buffers):
            new_bufs = snap.record(buffers, params, slot, plans)
            if grams is None:
                return new_bufs, None
            return new_bufs, snap.update_grams(grams, new_bufs, params, slot,
                                               self.cfg, plans)
        table = self.arena_for(params)
        arenas, leaf = arena_mod.split_state(buffers)
        # With RESIDENT params (the arena wrapper) arena_mod.record is a
        # pointer bump — one astype + dynamic_update_slice per bucket; the
        # per-leaf snapshot calls below only see the non-packed leaves
        # (the wrapper's leaf subtree is None at every packed path).
        arenas = arena_mod.record(arenas, params, slot, table, self.cfg)
        p_leaf = (arena_mod.split_state(params)[1]
                  if arena_mod.is_arena_state(params) else params)
        leaf = snap.record(leaf, p_leaf, slot, plans)
        new_bufs = arena_mod.make_state(arenas, leaf)
        if grams is None:
            return new_bufs, None
        agrams, lgrams = arena_mod.split_state(grams)
        new_grams = arena_mod.make_state(
            arena_mod.update_grams(agrams, arenas, slot, self.cfg, table),
            snap.update_grams(lgrams, leaf, p_leaf, slot, self.cfg, plans))
        return new_bufs, new_grams

    # ---- checkpoint format (leaf-wise arena views) ------------------------
    def params_leafwise(self, params):
        """Param pytree with arena-resident leaves expanded back to
        per-leaf arrays — identity for non-resident params. This is the
        serving/publish template layout: the trainer's publish hook
        (train/loop.py ``on_publish``) exports through here so a serving
        ParamStore / WeightsChannel never sees the packed flat buckets."""
        if arena_mod.is_arena_state(params):
            return arena_mod.tree_leafwise(self.arena_for(params), params)
        return params

    def state_leafwise(self, state):
        """TrainState -> the same state with arenas unpacked into the
        per-leaf buffer/Gram pytrees (the ``dmd.arena=False`` layout) AND
        resident params/optimizer moments expanded back to per-leaf arrays.
        Checkpoints are ALWAYS written in this form, so they are
        byte-compatible across arena on/off AND arena_native on/off,
        pre-residency checkpoints restore unchanged, and elastic
        remapped-mesh restore keeps using the audited per-leaf
        PartitionSpecs. No-op when nothing is packed."""
        if state is None:
            return state
        if arena_mod.is_arena_state(getattr(state, "params", None)):
            table = self.arena_for(state.params)

            def unwrap(x):
                return (arena_mod.tree_leafwise(table, x)
                        if arena_mod.is_arena_state(x) else x)

            state = state._replace(
                params=self.params_leafwise(state.params),
                opt_state=jax.tree_util.tree_map(
                    unwrap, state.opt_state,
                    is_leaf=arena_mod.is_arena_state))
        if not arena_mod.is_arena_state(state.dmd_buffers):
            return state
        from repro.distributed.sharding import normalize_path
        table = self.arena_for(state.params)
        arenas, leaf = arena_mod.split_state(state.dmd_buffers)
        by_path = arena_mod.buffers_leafwise(table, arenas)

        def fill(from_paths):
            def one(kp, x):
                return from_paths.get(
                    normalize_path(jax.tree_util.keystr(kp)), x)
            return one

        bufs = jax.tree_util.tree_map_with_path(
            fill(by_path), leaf, is_leaf=lambda x: x is None)
        grams = state.dmd_gram
        if arena_mod.is_arena_state(grams):
            agrams, lgrams = arena_mod.split_state(grams)
            # bucket scope: the (1, m, m) summed Grams cannot split per
            # leaf — grams_leafwise recomputes the per-system stacks from
            # the snapshot buffers, keeping the disk format leaf-wise
            g_by_path = arena_mod.grams_leafwise(table, agrams,
                                                 cfg=self.cfg, arenas=arenas)
            grams = jax.tree_util.tree_map_with_path(
                fill(g_by_path), lgrams, is_leaf=lambda x: x is None)
        return state._replace(dmd_buffers=bufs, dmd_gram=grams)

    def state_arenaize(self, state):
        """Inverse of state_leafwise: re-pack a restored per-leaf state
        into the arena layout this accelerator runs with (no-op when
        arenas are off / empty / already packed)."""
        if state is None or state.dmd_buffers is None \
                or arena_mod.is_arena_state(state.dmd_buffers) \
                or not self.arena_on:
            return state
        table = self.arena_for(state.params)
        if not table:
            return state
        from repro.distributed.sharding import normalize_path
        paths = arena_mod.arena_paths(table)

        def by_path_of(tree):
            flat = jax.tree_util.tree_flatten_with_path(
                tree, is_leaf=lambda x: x is None)[0]
            return {normalize_path(jax.tree_util.keystr(kp)): leaf
                    for kp, leaf in flat}

        def strip(tree):
            return jax.tree_util.tree_map_with_path(
                lambda kp, x: None
                if normalize_path(jax.tree_util.keystr(kp)) in paths else x,
                tree, is_leaf=lambda x: x is None)

        bufs = arena_mod.make_state(
            arena_mod.buffers_from_leafwise(table, by_path_of(
                state.dmd_buffers), self.cfg), strip(state.dmd_buffers))
        grams = state.dmd_gram
        if grams is not None and self.streaming:
            grams = arena_mod.make_state(
                arena_mod.grams_from_leafwise(table, by_path_of(grams),
                                              scope=self.scope),
                strip(grams))
        return state._replace(dmd_buffers=bufs, dmd_gram=grams)

    # ---- the DMD jump -----------------------------------------------------
    def _apply_impl(self, params: PyTree, buffers: PyTree, grams: PyTree,
                    relax: jnp.ndarray, groups=None) -> Tuple[PyTree, dict]:
        plans = self.plans_for(params)
        new_params, mean_rank = jump_tree(self.cfg, plans, params, buffers,
                                          grams, relax, groups=groups,
                                          arena=self.arena_for(params))
        return new_params, {"mean_rank": mean_rank}

    def apply(self, params: PyTree, buffers: PyTree,
              round_idx: int = 0, grams: Optional[PyTree] = None,
              groups: Optional[Tuple[int, ...]] = None,
              step: Optional[int] = None) -> Tuple[PyTree, dict]:
        """The jump. Two idioms:

          * ``apply(params, buffers, round_idx, grams=...)`` — legacy:
            every group jumps, relaxed at `round_idx` (per-group anneal).
          * ``apply(params, buffers, grams=..., step=step)`` — schedule-
            driven: only ``apply_groups(step)`` jump, each at its own
            round's relax. `groups` (static tuple) overrides the mask.
        """
        if buffers is None:
            return params, {}
        if grams is None or not self.streaming:
            grams = _none_like(buffers)
        self.plans_for(params)        # build outside the trace for caching
        if step is not None:
            if groups is None:
                groups = self.apply_groups(step)
            relax = jnp.asarray(self.relax_vector(step), jnp.float32)
        else:
            relax = jnp.asarray(
                [self.relax_for_round(round_idx, g)
                 for g in range(self.n_groups)], jnp.float32)
        groups = None if groups is None else tuple(sorted(groups))
        if self._apply_jit is None:
            self._apply_jit = jax.jit(self._apply_impl, donate_argnums=(0,),
                                      static_argnames=("groups",))
        return self._apply_jit(params, buffers, grams, relax, groups=groups)
