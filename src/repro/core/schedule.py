"""Per-leaf DMD scheduling: group rules -> per-group windows (DESIGN.md §4).

The paper treats the snapshot window ``m`` and jump horizon ``s`` as one
global knob, but its own premise — POD/DMD learns *per-layer* dynamics —
implies each layer family deserves its own schedule (Turjeman et al. 2022:
layer families evolve on visibly different timescales; Manojlović et al.
2020: per-layer Koopman spectra a single window cannot serve). This module
is the scheduling control plane on top of the LeafPlan registry:

  * ``DMDGroupRule``   — a structural matcher (path regex / ndim / size
                         bounds) plus either ``exclude`` or per-group
                         schedule overrides (m, s, warmup, cooldown, relax,
                         anneal, phase).
  * ``GroupSchedule``  — one resolved group's schedule. Group 0 is always
                         the DEFAULT group built from the DMDConfig globals
                         (phase 0), so a config with no rules reproduces the
                         pre-refactor single-window behavior bit-exactly.
  * ``group_for_leaf`` — rule resolution, run ONCE per leaf at plan-build
                         time (core/leafplan.py): legacy-filter rules first
                         (``param_filter`` / ``min_param_size`` are mapped
                         onto exclusion rules — no string dispatch survives
                         below the config layer), then ``cfg.groups`` in
                         declaration order, first match wins, no match ->
                         the default group.

Schedule math (per group g): with ``cycle = cooldown + m`` and
``eff = step - warmup - phase``,

    slot(step) = -1                          if eff < 0   (not started)
                 eff % cycle - cooldown      otherwise    (< 0 in cooldown)

a snapshot is recorded when slot >= 0, and the group jumps when
slot == m - 1. The ``phase`` offset staggers groups against each other:
two groups with disjoint jump residues (e.g. m=14/phase=0 jumps on odd
effective steps, m=6/phase=7 on even ones) never jump on the same step, so
the whole-tree jump spike of the synchronous schedule is amortized into
smaller per-group jumps (benchmarks: ``staggered_jump``).

Everything here is pure arithmetic on Python ints or traced scalars:
``slots_for_step`` is the in-trace variant the fused train step uses, and
it agrees with the host-side ``GroupSchedule.slot`` for every step
(tests/test_schedule.py pins both, plus the legacy closed form).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class DMDGroupRule:
    """One config-declared scheduling rule: matcher + overrides.

    Matcher fields (all must hold for the rule to match a leaf):
      path_regex  re.search against the normalized param path ("" = any)
      min_ndim /  bounds on the RAW leaf ndim, stack axes included — the
      max_ndim    same convention the legacy "matrices_only" filter used
                  (max_ndim = -1 means unbounded)
      min_size /  bounds on the RAW leaf element count (max_size = -1
      max_size    means unbounded)

    Action: ``exclude=True`` removes matching leaves from DMD entirely;
    otherwise the rule defines a schedule group whose ``None`` fields
    inherit the DMDConfig globals. ``phase`` delays the group's first
    window by that many steps, staggering its jumps against other groups.
    ``reset_opt`` controls the post-jump optimizer-moment reset for THIS
    group's leaves (inherits cfg.reset_opt_state): slow leaf families
    (norms/biases) whose jumps barely move the weights should usually set
    it False — zeroing their Adam moments every short cycle costs more
    adaptation than the tiny teleport justifies.
    """
    name: str = ""
    path_regex: str = ""
    min_ndim: int = 0
    max_ndim: int = -1
    min_size: int = 0
    max_size: int = -1
    exclude: bool = False
    m: Optional[int] = None
    s: Optional[int] = None
    warmup_steps: Optional[int] = None
    cooldown_steps: Optional[int] = None
    phase: int = 0
    relax: Optional[float] = None
    anneal: Optional[float] = None
    reset_opt: Optional[bool] = None
    energy: Optional[float] = None      # controller mode only: this group's
                                        # cumulative-energy rank target
                                        # (inherits cfg.controller.energy;
                                        # ignored while the controller is off)
    ridge: Optional[float] = None       # controller mode only: this group's
                                        # Tikhonov shrinkage of the jump
                                        # solve, relative to sigma_max^2
                                        # (inherits cfg.controller.ridge;
                                        # ignored while the controller is off)

    def matches(self, path: str, ndim: int, size: int) -> bool:
        if self.path_regex and not re.search(self.path_regex, path):
            return False
        if ndim < self.min_ndim:
            return False
        if 0 <= self.max_ndim < ndim:
            return False
        if size < self.min_size:
            return False
        if 0 <= self.max_size < size:
            return False
        return True


@dataclass(frozen=True)
class GroupSchedule:
    """One resolved schedule group. Hashable/static: lives inside LeafPlan
    records and jit-static config closures."""
    index: int
    name: str
    m: int
    s: int
    warmup_steps: int
    cooldown_steps: int
    phase: int
    relax: float
    anneal: float
    reset_opt: bool = True
    energy: float = 0.0         # > 0 only in controller mode: POD rank from
                                # cumulative-energy fraction instead of the
                                # global tol (core/dmd.py). 0.0 keeps the
                                # tol mask — bit-exact legacy behavior.
    ridge: float = 0.0          # > 0 only in controller mode: base Tikhonov
                                # shrinkage of this group's jump solve
                                # (core/dmd.py::_ridge_inv_sigma). 0.0 keeps
                                # the exact pseudo-inverse — bit-exact.

    @property
    def cycle(self) -> int:
        return self.cooldown_steps + self.m

    # Cycle after warmup+phase: [cooldown unrecorded steps][m recorded
    # steps -> jump]. cooldown (beyond-paper, default 0 = the paper's
    # Algorithm 1) lets the optimizer moments re-adapt after a jump so the
    # next window measures the trajectory's own dynamics, not the post-jump
    # transient.
    def slot(self, step: int) -> int:
        """Buffer row for the snapshot taken after optimizer step `step`;
        negative while not recording (warmup / phase / cooldown)."""
        eff = int(step) - self.warmup_steps - self.phase
        if eff < 0:
            return -1
        return eff % self.cycle - self.cooldown_steps

    def should_record(self, step: int) -> bool:
        return self.slot(step) >= 0

    def should_apply(self, step: int) -> bool:
        return self.slot(step) == self.m - 1

    def round_index(self, step: int) -> int:
        return (int(step) - self.warmup_steps - self.phase) // self.cycle

    def relax_for_round(self, round_idx: int) -> float:
        return float(self.relax * (self.anneal ** max(round_idx, 0)))


def rules_for_config(cfg) -> Tuple[DMDGroupRule, ...]:
    """The config's full rule list: the legacy ``param_filter`` /
    ``min_param_size`` strings mapped onto exclusion rules (resolved FIRST,
    so a legacy filter excludes a leaf even when a group rule would match),
    followed by ``cfg.groups`` in declaration order."""
    legacy = []
    if cfg.param_filter == "non_expert":
        legacy.append(DMDGroupRule(name="legacy_non_expert",
                                   path_regex="expert", exclude=True))
    elif cfg.param_filter == "matrices_only":
        legacy.append(DMDGroupRule(name="legacy_matrices_only",
                                   max_ndim=1, exclude=True))
    elif cfg.param_filter != "all":
        raise ValueError(f"unknown param_filter {cfg.param_filter!r}")
    if cfg.min_param_size > 1:
        legacy.append(DMDGroupRule(name="legacy_min_param_size",
                                   max_size=cfg.min_param_size - 1,
                                   exclude=True))
    return tuple(legacy) + tuple(getattr(cfg, "groups", ()) or ())


def _validate(g: GroupSchedule) -> GroupSchedule:
    if g.m < 3:
        raise ValueError(f"group {g.name!r}: DMD needs m >= 3 (got {g.m})")
    for field in ("warmup_steps", "cooldown_steps", "phase"):
        if getattr(g, field) < 0:
            raise ValueError(f"group {g.name!r}: {field} must be >= 0")
    if g.s < 1:
        raise ValueError(f"group {g.name!r}: s must be >= 1 (got {g.s})")
    if not 0.0 <= g.energy <= 1.0:
        raise ValueError(
            f"group {g.name!r}: energy must be in [0, 1] (got {g.energy})")
    if not (g.ridge >= 0.0 and math.isfinite(g.ridge)):
        raise ValueError(
            f"group {g.name!r}: ridge must be finite and >= 0 "
            f"(got {g.ridge})")
    return g


def resolve_groups(cfg) -> Tuple[GroupSchedule, ...]:
    """Config -> the resolved group table. Group 0 is ALWAYS the default
    group (the DMDConfig globals, phase 0); groups 1..K are the non-exclude
    rules in rule order, each inheriting unset fields from the globals.

    The energy-rank target resolves to 0.0 (tol mask — legacy) unless the
    jump controller is enabled, in which case each group inherits
    ``cfg.controller.energy`` overridable per rule — the "tol becomes a
    per-group cumulative-energy fraction" switch (DESIGN.md §5). The
    ridge shrinkage resolves the same way from ``cfg.controller.ridge``
    (per-rule override: ``DMDGroupRule.ridge``); both stay 0.0 — bit-exact
    legacy — while the controller is off.
    """
    reset_default = bool(getattr(cfg, "reset_opt_state", True))
    ccfg = getattr(cfg, "controller", None)
    ctrl_on = ccfg is not None and ccfg.enabled
    energy_default = float(ccfg.energy) if ctrl_on else 0.0
    ridge_default = float(getattr(ccfg, "ridge", 0.0)) if ctrl_on else 0.0
    groups = [_validate(GroupSchedule(
        index=0, name="default", m=cfg.m, s=cfg.s,
        warmup_steps=cfg.warmup_steps, cooldown_steps=cfg.cooldown_steps,
        phase=0, relax=cfg.relax, anneal=cfg.anneal,
        reset_opt=reset_default, energy=energy_default,
        ridge=ridge_default))]
    for rule in rules_for_config(cfg):
        if rule.exclude:
            continue
        idx = len(groups)
        pick = lambda v, d: d if v is None else v
        groups.append(_validate(GroupSchedule(
            index=idx, name=rule.name or f"group{idx}",
            m=pick(rule.m, cfg.m), s=pick(rule.s, cfg.s),
            warmup_steps=pick(rule.warmup_steps, cfg.warmup_steps),
            cooldown_steps=pick(rule.cooldown_steps, cfg.cooldown_steps),
            phase=rule.phase,
            relax=pick(rule.relax, cfg.relax),
            anneal=pick(rule.anneal, cfg.anneal),
            reset_opt=pick(rule.reset_opt, reset_default),
            energy=(pick(rule.energy, energy_default)
                    if ctrl_on else 0.0),
            ridge=(pick(rule.ridge, ridge_default)
                   if ctrl_on else 0.0))))
    return tuple(groups)


def group_for_leaf(cfg, path: str, ndim: int, size: int) -> Optional[int]:
    """Rule resolution for one leaf: index into ``resolve_groups(cfg)`` or
    None (excluded). `path` is the NORMALIZED param path ("/seg0/attn/wq").
    First matching rule wins; an exclude match returns None; no match falls
    through to the default group 0. Zero-size leaves are never schedulable.
    """
    if size < 1:
        return None
    next_group = 1
    for rule in rules_for_config(cfg):
        gi = None if rule.exclude else next_group
        if not rule.exclude:
            next_group += 1
        if rule.matches(path, ndim, size):
            return gi
    return 0


def schedule_records(groups: Sequence[GroupSchedule]) -> list:
    """JSON-able rows of the resolved group table — the static-audit
    export consumed by ``repro.audit`` (schedule-conflict pass) and the
    AUDIT_*.json artifact. One dict per group, every resolved field."""
    return [{
        "index": g.index, "name": g.name, "m": g.m, "s": g.s,
        "warmup_steps": g.warmup_steps, "cooldown_steps": g.cooldown_steps,
        "phase": g.phase, "cycle": g.cycle, "relax": g.relax,
        "anneal": g.anneal, "reset_opt": g.reset_opt, "energy": g.energy,
        "ridge": g.ridge,
        "jump_residue": (g.warmup_steps + g.phase + g.cycle - 1) % g.cycle,
    } for g in groups]


def jump_collisions(groups: Sequence[GroupSchedule]
                    ) -> list:
    """Pairs of groups that jump on the SAME step infinitely often.

    Group g jumps at steps ``step ≡ warmup+phase+cycle-1 (mod cycle)``
    (for step past its start); two groups collide iff the congruences are
    simultaneously solvable, i.e. ``r_a ≡ r_b (mod gcd(cycle_a,
    cycle_b))`` (CRT). Staggered configs (distinct declared phases) are
    expected to be pairwise collision-free — benchmarks/staggered_jump
    measures exactly that; the schedule-conflict pass flags violations."""
    import math
    out = []
    for i, a in enumerate(groups):
        ra = (a.warmup_steps + a.phase + a.cycle - 1) % a.cycle
        for b in groups[i + 1:]:
            rb = (b.warmup_steps + b.phase + b.cycle - 1) % b.cycle
            if (ra - rb) % math.gcd(a.cycle, b.cycle) == 0:
                out.append((a.index, b.index))
    return out


def slots_for_step(groups: Sequence[GroupSchedule], step) -> jnp.ndarray:
    """(n_groups,) int32 slot vector for a (possibly traced) step scalar —
    the in-trace counterpart of ``GroupSchedule.slot``, used by the fused
    train step. Entry g is -1 before group g's first window, else
    ``eff % cycle - cooldown`` (negative during cooldown)."""
    step = jnp.asarray(step, jnp.int32)
    slots = []
    for g in groups:
        eff = step - (g.warmup_steps + g.phase)
        slots.append(jnp.where(eff < 0, jnp.int32(-1),
                               eff % g.cycle - g.cooldown_steps))
    return jnp.stack(slots).astype(jnp.int32)


def slots_array(groups: Sequence[GroupSchedule], step: int) -> np.ndarray:
    """Host-side per-group slot vector (concrete ints)."""
    return np.asarray([g.slot(step) for g in groups], np.int32)


# ---------------------------------------------------------------------------
# Dynamic-horizon round math (controller mode — core/controller.py)
# ---------------------------------------------------------------------------
# The configured ``s`` stays the STATIC per-group cap (it sizes the unrolled
# matrix-power chain and the trust radius at compile time); the controller's
# adapted horizon is a TRACED value clamped into [s_floor, s]. Keeping the
# clamp math here, next to the rest of the schedule arithmetic, means the
# host-side audit (`effective_s_array`) and the in-trace variant
# (`effective_s_vector`) can never drift apart.

def s_caps(groups: Sequence[GroupSchedule]) -> np.ndarray:
    """(n_groups,) static horizon caps — each group's configured ``s``."""
    return np.asarray([g.s for g in groups], np.float32)


def s_bounds(groups: Sequence[GroupSchedule], s_floor: float = 1.0
             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(lo, caps) fp32 bounds of the adapted horizon per group — THE one
    definition of the [floor, configured-s] band. Both the controller's
    grow/shrink update (core/controller.py) and the realized-horizon
    rounding below consume it, so the persisted state and the horizon the
    jump actually uses can never live under different rules."""
    caps = jnp.asarray(s_caps(groups))
    lo = jnp.minimum(jnp.float32(max(s_floor, 1.0)), caps)
    return lo, caps


def effective_s_vector(groups: Sequence[GroupSchedule], s_eff,
                       s_floor: float = 1.0) -> jnp.ndarray:
    """Traced (n_groups,) integer horizons from the controller's fp32
    ``s_eff`` state: rounded, then clamped into [s_floor, s_g]. Entry g is
    what ``dmd_coefficients`` receives as its dynamic ``s_dyn`` (with the
    group's configured s as the static ``s_max``)."""
    lo, caps = s_bounds(groups, s_floor)
    return jnp.clip(jnp.round(jnp.asarray(s_eff, jnp.float32)), lo,
                    caps).astype(jnp.int32)


def effective_s_array(groups: Sequence[GroupSchedule], s_eff,
                      s_floor: float = 1.0) -> np.ndarray:
    """Host-side counterpart of ``effective_s_vector`` (concrete ints)."""
    caps = s_caps(groups)
    lo = np.minimum(np.float32(max(s_floor, 1.0)), caps)
    return np.clip(np.round(np.asarray(s_eff, np.float32)), lo,
                   caps).astype(np.int32)
