"""Packed leaf arenas: one buffer, one launch, one solve per bucket (§7).

The paper's speedup argument is operation-count reduction, but the realized
wall-clock of the per-leaf pipeline is dominated by *dispatch*: every
DMD-managed leaf pays its own ``record`` / ``gram_row`` / ``combine``
kernel launch via tree_map and its own tiny (m, m) eigensolve, so a
transformer config with hundreds of leaves pays hundreds of launches per
recorded step and a long unrolled jitted trace. The Koopman-mode view
(Manojlović et al.) and Turjeman et al.'s correlated-dynamics observation
both treat the whole weight state as one dynamical system — which is also
exactly the layout that runs fastest on hardware: one contiguous buffer,
one kernel, one batched solve.

This module buckets all compatible leaves at accelerator init —

    bucket key = (schedule group, param dtype, lane-sharding axes)

— into one contiguous arena per bucket, with an offset/length table
(``ArenaSegment``) carried on the ``ArenaBucket`` alongside the LeafPlan
pytree. Per-system segments (a "system" = one independent DMD trajectory:
an unstacked leaf, or one layer of a scan-stacked leaf) are padded to a
multiple of the bucket's ``block_n`` (itself a 128-lane multiple), so the
segmented kernels in kernels/arena.py can walk the whole arena in ONE
launch with no block ever straddling systems; tail lanes are zero and
contribute zero to every inner product (padding is exact).

State layout (TrainState.dmd_buffers / dmd_gram when arenas are active):

    {"__arena__": {bucket_key: (n_blocks, m, block_n) ring buffer}, "leaf": …}
    {"__arena__": {bucket_key: (n_sys, m, m) fp32 Grams},           "leaf": …}

The snapshot ring buffer is BLOCK-MAJOR: the flat lane axis is cut into
``block_n``-lane blocks and each block carries its own m snapshot rows
contiguously. That single layout decision makes every DMD data pass a
batch-LEADING contraction (one gemm/gemv-shaped ``dot_general`` per
bucket on CPU/GPU — batch dims must lead, so the old snapshot-major
(m, N) layout forced either a full-buffer transpose or a slow fused
multiply-reduce) and makes the TPU Pallas tile literally the storage
tile ``x[i]``. The every-step record writes one (nb, 1, bn) slab per
bucket; flat (N,) rows appear only at the pack/unpack and jump-blend
boundaries, where blocking is a free divisible reshape.

The ``leaf`` subtree keeps the per-leaf layout for leaves an arena cannot
take (route forced to ``dot_general``, or a stack axis sharded on a
non-leading dim) — the two routes coexist leaf-by-leaf. ``dmd.arena=False``
disables bucketing entirely and keeps the bit-exact per-leaf A/B oracle.

Parameter residency (``dmd.arena_native``, DESIGN.md §7): during
``Trainer.fit`` the managed params (and elementwise optimizer moments) of
packed leaves live IN their bucket's contiguous ``(N_local,)`` device
buffer — the same wrapper layout as the snapshot state:

    {"__arena__": {bucket_key: (N,) flat params}, "leaf": pytree-with-None}

``tree_resident`` / ``tree_leafwise`` convert between the two layouts;
``tree_leafwise`` doubles as the in-trace view expansion for the model's
forward (static slice + reshape per segment — zero-copy views of the
contiguous buffer, no scatter). With resident params, ``record`` is one
``astype`` + ``dynamic_update_slice`` per bucket (a pointer bump) instead
of the per-leaf pack gather, and ``jump`` writes the blended flat row
straight back as the new resident buffer.

Sharded-stack leaves (scan-stacked params whose leading stack dim is
sharded) pack into their own SINGLE-SEGMENT bucket per leaf: each device
owns whole systems (``sys_axes``), the Gram stack stays sharded
``P(sys_axes, None, None)``, and the kernels need no collective beyond
the usual lane psum. ``anchor=mean`` buckets run the full-recompute Gram
kernel with fused mean subtraction (streaming is structurally off for
mean — dmd.gram_row_matrix rejects it).

Jump solve: instead of one ``eigh``/``_host_eig`` call per leaf,
``jump`` concatenates every bucket's Grams of a jumping group into one
(n_sys_total, m, m) batch and makes ONE ``dmd_coefficients`` call per
group (``m`` is uniform within a group by construction — the group's
schedule sizes every member's window), then splits the coefficient rows
back per bucket for the single segmented combine launch.

Checkpoint compatibility: arenas are serialized LEAF-WISE
(``buffers_leafwise`` / ``grams_leafwise`` and their inverses) — the
Trainer unpacks arenas into the per-leaf pytree before ``save_checkpoint``
and re-packs after restore, so checkpoints are byte-identical between
arena on/off, pre-arena checkpoints load unchanged, and elastic restore
onto a remapped mesh keeps using the audited per-leaf PartitionSpecs.
Pack/unpack is lossless (pad lanes are zero on both sides).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import dmd as dmd_math
from repro.core.leafplan import LeafPlan, plan_entries
from repro.core.schedule import GroupSchedule
from repro.core.snapshots import _static_int

PyTree = Any

ARENA_KEY = "__arena__"


@dataclass(frozen=True)
class ArenaSegment:
    """One leaf's slice of a bucket's lane axis (the offset/length table).

    A leaf with k stack dims contributes ``n_sys`` consecutive systems,
    each occupying ``seg_lanes`` lanes (``flat_local`` real + zero tail).
    ``*_local`` fields and ``n_sys`` are shard-local for sharded buckets
    (every device holds the same layout over its own shards; for a
    system-sharded bucket the global count is ``n_sys * sys_factor``)."""
    path: str
    sys_start: int                 # first system index within the bucket
    lane_start: int                # first (shard-local) lane offset
    n_sys: int                     # shard-LOCAL DMD systems in this leaf
    flat_local: int                # real lanes per system (unpadded)
    seg_lanes: int                 # padded lanes per system (block multiple)
    shape: Tuple[int, ...]         # full global leaf shape
    local_shape: Tuple[int, ...]   # shard-local leaf shape
    stack_dims: int
    param_dtype: str
    param_spec: P
    snapshot_spec: P

    @property
    def lanes(self) -> int:
        return self.n_sys * self.seg_lanes


@dataclass(frozen=True)
class ArenaBucket:
    """One packed arena: all leaves of one (group, dtype, sharding) class."""
    key: str
    group: int
    sched: GroupSchedule
    block_n: int                   # segment quantum / kernel tile (128-mult)
    segments: Tuple[ArenaSegment, ...]
    lane_axes: Tuple[str, ...]     # mesh axes sharding the lane dim (== the
                                   # Gram psum axes; () = unsharded bucket)
    shard_factor: int              # prod of lane_axes' mesh sizes
    sys_axes: Tuple[str, ...] = () # mesh axes sharding the (leading) stack
                                   # dim — single-segment buckets only: each
                                   # device owns whole systems, the Gram
                                   # stack stays sharded over these axes
    sys_factor: int = 1            # prod of sys_axes' mesh sizes
    mesh: Optional[Mesh] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def m(self) -> int:
        return self.sched.m

    @property
    def n_sys(self) -> int:
        """Shard-LOCAL system count (what the segmented kernels see)."""
        return sum(s.n_sys for s in self.segments)

    @property
    def n_sys_global(self) -> int:
        """Global system count (the carried Gram stack's leading dim)."""
        return self.n_sys * self.sys_factor

    @property
    def n_lanes_local(self) -> int:
        return sum(s.lanes for s in self.segments)

    @property
    def n_lanes(self) -> int:
        """Global lane count (flat rows; block_n * n_blocks)."""
        return self.n_lanes_local * self.shard_factor * self.sys_factor

    @property
    def n_blocks_local(self) -> int:
        """Shard-local block count (what the segmented kernels walk)."""
        return self.n_lanes_local // self.block_n

    @property
    def n_blocks(self) -> int:
        """Global block count: leading dim of the carried block-major
        (n_blocks, m, block_n) snapshot buffer."""
        return self.n_lanes // self.block_n

    def block_sys(self) -> np.ndarray:
        """Static (shard-local) block -> system-index table for the
        segmented kernels; blocks of one system are consecutive."""
        parts = [np.repeat(
            np.arange(s.sys_start, s.sys_start + s.n_sys, dtype=np.int32),
            s.seg_lanes // self.block_n) for s in self.segments]
        return np.concatenate(parts) if parts else np.zeros(0, np.int32)

    # ---- dmd.scope (DESIGN.md §9) -----------------------------------------
    def bucket_scoped(self, scope: str) -> bool:
        """True when this bucket carries ONE shared Koopman system under
        ``scope="bucket"``. System-sharded buckets (``sys_axes``) stay
        per-system in either scope: each shard owns whole systems, and
        collapsing them into one would need a cross-shard psum over the
        stack axis that the lane-psum kernel contract does not emit."""
        if scope not in ("leaf", "bucket"):
            raise ValueError(f"unknown dmd.scope {scope!r}")
        return scope == "bucket" and not self.sys_axes

    def gram_lead(self, scope: str) -> int:
        """Leading dim of the carried Gram stack (and the bucket's share of
        the batched coefficient solve) under ``scope``."""
        return 1 if self.bucket_scoped(scope) else self.n_sys_global

    def scope_block_sys(self, scope: str) -> np.ndarray:
        """Block -> system table the kernels walk under ``scope``. Bucket
        scope collapses every block onto system 0: pad lanes are zero and
        all segments share the bucket's slot schedule, so the EXISTING
        segmented kernels then compute exactly the concatenated-bucket-state
        Gram (= the segment-SUM of the per-system Grams) in gram_row/gram,
        and broadcast the single coefficient row across every block in
        combine — the fused segment-summed reduction needs no new kernel."""
        if self.bucket_scoped(scope):
            return np.zeros(self.n_blocks_local, np.int32)
        return self.block_sys()

    def scope_n_sys(self, scope: str) -> int:
        """Shard-local system count the segmented kernels see under
        ``scope`` (their output's leading dim)."""
        return 1 if self.bucket_scoped(scope) else self.n_sys

    def lane_spec(self) -> P:
        """Spec of the FLAT 1-D lane axis (pack/unpack rows, jump blend):
        system-sharded buckets are sys-major so the flat lane dim shards
        over sys_axes THEN lane_axes."""
        from repro.kernels.arena import lane_spec
        return lane_spec(self.sys_axes + self.lane_axes)

    def buffer_spec(self) -> P:
        """Spec of the block-major (n_blocks, m, block_n) snapshot buffer:
        the same mesh axes shard the leading BLOCK axis (every shard's
        lane count is a block_n multiple, so shard boundaries are block
        boundaries and flat<->blocked reshapes split/merge the sharded
        dim divisibly)."""
        from repro.kernels.arena import buf_spec
        return buf_spec(self.sys_axes + self.lane_axes)

    def gram_spec(self) -> P:
        """Spec of the (n_sys_global, m, m) Gram stack."""
        from repro.kernels.arena import _axis_entry
        return (P(_axis_entry(self.sys_axes), None, None)
                if self.sys_axes else P())


# ---------------------------------------------------------------------------
# Bucketing
# ---------------------------------------------------------------------------

def _axes_of(entries, mesh: Optional[Mesh]) -> Tuple[str, ...]:
    """Mesh axes (size > 1) appearing in a run of PartitionSpec entries."""
    if mesh is None:
        return ()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out: List[str] = []
    for e in entries:
        if e is None:
            continue
        for a in (e if isinstance(e, tuple) else (e,)):
            if a is not None and sizes.get(a, 1) > 1 and a not in out:
                out.append(a)
    return tuple(sorted(out))


def _local_shape(plan: LeafPlan, mesh: Optional[Mesh]) -> Tuple[int, ...]:
    if mesh is None:
        return plan.shape
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    ent = tuple(plan.param_spec) + (None,) * len(plan.shape)
    out = []
    for d, e in zip(plan.shape, ent):
        f = 1
        if e is not None:
            for a in (e if isinstance(e, tuple) else (e,)):
                if a is not None:
                    f *= sizes.get(a, 1)
        out.append(d // f)
    return tuple(out)


def arena_eligible(plan: LeafPlan, cfg, mesh: Optional[Mesh]) -> bool:
    """A leaf joins an arena unless it must keep its per-leaf route: only
    the forced ``dot_general`` oracle, and stack axes sharded on a
    NON-leading stack dim (shard-major packing would interleave the
    global system ordering). ``anchor=mean`` leaves pack (the full-gram
    kernel fuses the mean subtraction) and leading-dim sharded stacks get
    their own single-segment bucket (``sys_axes``)."""
    if not getattr(cfg, "arena", True):
        return False
    if plan.route == "dot_general":
        return False
    ent = tuple(plan.param_spec) + (None,) * plan.stack_dims
    if plan.stack_dims > 1 and _axes_of(ent[1:plan.stack_dims], mesh):
        return False                   # non-leading sharded stack axes
    return True


def build_arenas(plans: PyTree, cfg, mesh: Optional[Mesh] = None
                 ) -> Dict[str, ArenaBucket]:
    """LeafPlan pytree -> {bucket_key: ArenaBucket}, leaves in pytree order.

    Bucket key = (schedule group, param dtype, lane-sharding axes): one
    slot schedule (group fixes m/phase), one cast-back dtype, one psum
    pattern per bucket. ``block_n`` is the bucket-wide segment quantum:
    ``lane_block(cfg.arena_block_n, widest member)`` so tiny-leaf buckets
    collapse to one 128-lane tile while big buckets keep wide tiles."""
    from repro.kernels.ops import lane_block

    grouped: Dict[str, List[Tuple[LeafPlan, Tuple[str, ...],
                                  Tuple[str, ...]]]] = {}
    for plan in plan_entries(plans):
        if not arena_eligible(plan, cfg, mesh):
            continue
        ent = tuple(plan.param_spec) + (None,) * len(plan.shape)
        lane_axes = _axes_of(ent[plan.stack_dims:], mesh)
        sys_axes = _axes_of(ent[:plan.stack_dims], mesh)
        key = f"g{plan.group}-{plan.dtype}"
        if lane_axes:
            key += "-" + "+".join(lane_axes)
        if sys_axes:
            # system-sharded leaves get their own SINGLE-segment bucket:
            # packing two leaves shard-major would interleave their global
            # system ordering; the path disambiguates the key.
            key += ("-sys" + "+".join(sys_axes) + "-"
                    + plan.path.replace("/", "."))
        grouped.setdefault(key, []).append((plan, lane_axes, sys_axes))

    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else {})
    out: Dict[str, ArenaBucket] = {}
    for key in sorted(grouped):
        members = grouped[key]
        locals_ = [_local_shape(p, mesh) for p, _, _ in members]
        flats = [int(np.prod(ls[p.stack_dims:], dtype=np.int64) or 1)
                 for (p, _, _), ls in zip(members, locals_)]
        block_n = lane_block(int(getattr(cfg, "arena_block_n", 512)),
                             max(flats))
        segs: List[ArenaSegment] = []
        sys_i = lane_i = 0
        for (plan, lane_axes, sys_axes), lshape, flat in zip(
                members, locals_, flats):
            n_sys = int(np.prod(lshape[:plan.stack_dims], dtype=np.int64)) \
                if plan.stack_dims else 1
            seg_lanes = -(-flat // block_n) * block_n
            segs.append(ArenaSegment(
                path=plan.path, sys_start=sys_i, lane_start=lane_i,
                n_sys=n_sys, flat_local=flat, seg_lanes=seg_lanes,
                shape=plan.shape, local_shape=lshape,
                stack_dims=plan.stack_dims, param_dtype=plan.dtype,
                param_spec=plan.param_spec,
                snapshot_spec=plan.snapshot_spec))
            sys_i += n_sys
            lane_i += n_sys * seg_lanes
        lane_axes, sys_axes = members[0][1], members[0][2]
        factor = sys_f = 1
        for a in lane_axes:
            factor *= sizes.get(a, 1)
        for a in sys_axes:
            sys_f *= sizes.get(a, 1)
        out[key] = ArenaBucket(
            key=key, group=members[0][0].group, sched=members[0][0].sched,
            block_n=block_n, segments=tuple(segs), lane_axes=lane_axes,
            shard_factor=factor, sys_axes=sys_axes, sys_factor=sys_f,
            mesh=mesh)
    return out


def arena_paths(table: Dict[str, ArenaBucket]) -> frozenset:
    return frozenset(s.path for b in table.values() for s in b.segments)


def layout_table(table: Dict[str, ArenaBucket],
                 scope: str = "leaf") -> list:
    """JSON-able rows of the packed-arena layout — the static-audit export
    consumed by ``repro.audit`` (arena-layout pass) and the AUDIT_*.json
    artifact: one dict per bucket carrying the offset/length table the
    segmented kernels index by. ``scope`` stamps each bucket's effective
    DMD granularity and solve share (``n_solve = gram_lead(scope)``)."""
    out = []
    for key in sorted(table):
        b = table[key]
        out.append({
            "key": b.key, "group": b.group, "m": b.m,
            "scope": "bucket" if b.bucket_scoped(scope) else "leaf",
            "n_solve": b.gram_lead(scope),
            "block_n": b.block_n, "n_sys": b.n_sys,
            "n_sys_global": b.n_sys_global,
            "n_lanes_local": b.n_lanes_local, "n_lanes": b.n_lanes,
            "lane_axes": list(b.lane_axes), "shard_factor": b.shard_factor,
            "sys_axes": list(b.sys_axes), "sys_factor": b.sys_factor,
            "segments": [{
                "path": s.path, "sys_start": s.sys_start,
                "lane_start": s.lane_start, "n_sys": s.n_sys,
                "flat_local": s.flat_local, "seg_lanes": s.seg_lanes,
                "shape": list(s.shape), "local_shape": list(s.local_shape),
                "stack_dims": s.stack_dims, "param_dtype": s.param_dtype,
            } for s in b.segments],
        })
    return out


# ---------------------------------------------------------------------------
# State: the {"__arena__": ..., "leaf": ...} wrapper
# ---------------------------------------------------------------------------

def is_arena_state(x) -> bool:
    return isinstance(x, dict) and ARENA_KEY in x


def make_state(arenas: Dict[str, jnp.ndarray], leaf: PyTree) -> PyTree:
    return {ARENA_KEY: arenas, "leaf": leaf}


def split_state(x) -> Tuple[Dict[str, jnp.ndarray], PyTree]:
    return x[ARENA_KEY], x["leaf"]


def init_arena_buffers(table: Dict[str, ArenaBucket], cfg,
                       abstract: bool = False) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.snapshot_dtype)
    out = {}
    for key, b in table.items():
        shape = (b.n_blocks, b.m, b.block_n)
        out[key] = (jax.ShapeDtypeStruct(shape, dtype) if abstract
                    else jnp.zeros(shape, dtype))
    return out


def init_arena_grams(table: Dict[str, ArenaBucket], scope: str = "leaf",
                     abstract: bool = False) -> Dict[str, Any]:
    """Per-bucket Gram stacks: (n_sys_global, m, m) in leaf scope, the
    single (1, m, m) shared-operator Gram in bucket scope (DESIGN.md §9)."""
    out = {}
    for key, b in table.items():
        shape = (b.gram_lead(scope), b.m, b.m)
        out[key] = (jax.ShapeDtypeStruct(shape, jnp.float32) if abstract
                    else jnp.zeros(shape, jnp.float32))
    return out


# ---------------------------------------------------------------------------
# Pack / unpack (the gather/scatter copies; shard-local for sharded buckets)
# ---------------------------------------------------------------------------

def _pack_leaf_local(x: jnp.ndarray, seg: ArenaSegment, dtype,
                     lead: int = 0) -> jnp.ndarray:
    """(lead..., stack..., rest_local...) -> (lead..., n_sys * seg_lanes)."""
    head = x.shape[:lead]
    x = x.astype(dtype).reshape(head + (seg.n_sys, seg.flat_local))
    if seg.seg_lanes != seg.flat_local:
        pad = [(0, 0)] * lead + [(0, 0), (0, seg.seg_lanes - seg.flat_local)]
        x = jnp.pad(x, pad)
    return x.reshape(head + (seg.n_sys * seg.seg_lanes,))


def _unpack_leaf_local(row: jnp.ndarray, seg: ArenaSegment,
                       lead: int = 0) -> jnp.ndarray:
    """(lead..., N_local) -> (lead..., *local_shape) (caller casts)."""
    head = row.shape[:lead]
    x = jax.lax.slice_in_dim(row, seg.lane_start,
                             seg.lane_start + seg.lanes, axis=lead)
    x = x.reshape(head + (seg.n_sys, seg.seg_lanes))
    x = jax.lax.slice_in_dim(x, 0, seg.flat_local, axis=lead + 1)
    return x.reshape(head + seg.local_shape)


def _shard_wrap(bucket: ArenaBucket, fn, in_specs, out_specs):
    """One shard_map contract for pack/unpack AND the kernels: delegate to
    kernels/arena.py's shard_wrap so the two paths can never diverge."""
    from repro.kernels.arena import shard_wrap
    return shard_wrap(bucket.mesh, bucket.sys_axes + bucket.lane_axes, fn,
                      in_specs, out_specs)


def _params_by_path(params: PyTree) -> Dict[str, Any]:
    from repro.distributed.sharding import normalize_path
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    return {normalize_path(jax.tree_util.keystr(kp)): leaf
            for kp, leaf in flat}


def pack_row(bucket: ArenaBucket, params_by_path: Dict[str, Any],
             dtype) -> jnp.ndarray:
    """Current params -> one (N,) arena row (the `record` gather)."""
    leaves = [params_by_path[s.path] for s in bucket.segments]

    def local(*ls):
        return jnp.concatenate(
            [_pack_leaf_local(x, s, dtype)
             for x, s in zip(ls, bucket.segments)])

    in_specs = tuple(s.param_spec for s in bucket.segments)
    return _shard_wrap(bucket, local, in_specs, bucket.lane_spec())(*leaves)


def _unpack_row(bucket: ArenaBucket, row: jnp.ndarray, lead: int = 0
                ) -> List[jnp.ndarray]:
    """One (lead..., N) arena slab -> per-leaf local arrays (uncast)."""

    def local(r):
        return tuple(_unpack_leaf_local(r, s, lead) for s in bucket.segments)

    spec = P(*((None,) * lead + tuple(bucket.lane_spec())))
    if lead:
        out_specs = tuple(P(*((None,) * lead + tuple(s.param_spec)))
                          for s in bucket.segments)
    else:
        out_specs = tuple(s.param_spec for s in bucket.segments)
    return list(_shard_wrap(bucket, local, (spec,), out_specs)(row))


# ---------------------------------------------------------------------------
# Parameter residency (dmd.arena_native): params/moments live in the bucket
# ---------------------------------------------------------------------------

def tree_resident(table: Dict[str, ArenaBucket], tree: PyTree) -> PyTree:
    """Move every packed leaf of a params-shaped ``tree`` into its bucket's
    contiguous ``(N,)`` flat buffer (the resident layout). The buffer
    keeps each field's OWN leaf dtype (param dtype for params, fp32 for
    optimizer moments); packed positions of the ``leaf`` subtree become
    None. Inverse: ``tree_leafwise``. Off the hot path — called once at
    ``Trainer.fit`` entry."""
    from repro.distributed.sharding import normalize_path

    by_path = _params_by_path(tree)
    arenas: Dict[str, jnp.ndarray] = {}
    for key in sorted(table):
        b = table[key]
        dtype = by_path[b.segments[0].path].dtype
        arenas[key] = pack_row(b, by_path, dtype)
    packed = arena_paths(table)

    def strip(kp, leaf):
        path = normalize_path(jax.tree_util.keystr(kp))
        return None if path in packed else leaf

    return make_state(arenas,
                      jax.tree_util.tree_map_with_path(strip, tree))


def tree_leafwise(table: Dict[str, ArenaBucket], wrapper: PyTree) -> PyTree:
    """Resident wrapper -> per-leaf pytree. ALSO the in-trace zero-copy
    view expansion for the model's forward: each leaf is a static
    slice + reshape of the contiguous resident row (no data movement, no
    scatter — XLA keeps them as views), so grads of loss∘views transpose
    to pure pad-extended slices of the flat gradient."""
    from repro.distributed.sharding import normalize_path

    arenas, leaf = split_state(wrapper)
    by_path: Dict[str, jnp.ndarray] = {}
    for key, row in arenas.items():
        b = table[key]
        for seg, x in zip(b.segments, _unpack_row(b, row)):
            by_path[seg.path] = x          # uncast: row dtype == leaf dtype

    def fill(kp, x):
        return by_path.get(normalize_path(jax.tree_util.keystr(kp)), x)

    return jax.tree_util.tree_map_with_path(
        fill, leaf, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# record / streaming-Gram update (one launch per bucket)
# ---------------------------------------------------------------------------

def _bucket_slot(bucket: ArenaBucket, slot):
    return slot[bucket.group] if getattr(slot, "ndim", 0) == 1 else slot


def record(arenas: Dict[str, jnp.ndarray], params: PyTree, slot,
           table: Dict[str, ArenaBucket], cfg,
           group: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Write current params into each bucket's snapshot row `slot` — with
    RESIDENT params (``params`` is the arena wrapper) this is one
    ``astype`` + blocked reshape + ``dynamic_update_slice`` on the middle
    (snapshot) axis per bucket: the row is already contiguous in the
    resident buffer, and the flat->(nb, bn) reshape is a free divisible
    split. Leafwise params pay the PR-5 pack gather instead. Slot
    semantics match snapshots.record."""
    resident = is_arena_state(params)
    pres = split_state(params)[0] if resident else None
    by_path = None if resident else _params_by_path(params)
    dtype = jnp.dtype(cfg.snapshot_dtype)
    out = dict(arenas)
    for key, buf in arenas.items():
        b = table[key]
        if group is not None and b.group != group:
            continue
        s = _bucket_slot(b, slot)
        si = _static_int(s)
        if si is not None:
            if si < 0:
                continue
            s = si
        else:
            s = jnp.maximum(s, 0)
        row = (pres[key].astype(dtype) if resident
               else pack_row(b, by_path, dtype))
        out[key] = jax.lax.dynamic_update_index_in_dim(
            buf, row.reshape(b.n_blocks, b.block_n), s, axis=1)
    return out


def update_grams(agrams: Dict[str, jnp.ndarray],
                 arenas: Dict[str, jnp.ndarray], slot, cfg,
                 table: Dict[str, ArenaBucket],
                 group: Optional[int] = None) -> Dict[str, jnp.ndarray]:
    """Streaming-Gram maintenance over whole buckets: ONE segmented
    gram_row launch per bucket emits every system's row, then one masked
    row+column write per bucket (set_gram_row batches over systems). The
    just-written arena row doubles as the rhs, so no second pack pass.

    Under ``cfg.scope="bucket"`` the same launch runs with the collapsed
    block table (``scope_block_sys``): the kernel's in-place segment
    accumulation then sums every block's partial into ONE (m,) row — the
    fused segment-summed reduction that writes the (m, m) bucket Gram
    directly instead of n_sys per-system Grams."""
    from repro.kernels import arena as ka

    scope = getattr(cfg, "scope", "leaf")
    out = dict(agrams)
    for key, g in agrams.items():
        b = table[key]
        if group is not None and b.group != group:
            continue
        s = _bucket_slot(b, slot)
        si = _static_int(s)
        if si is not None and si < 0:
            continue
        sv = si if si is not None else jnp.maximum(s, 0)
        buf = arenas[key]
        q = jax.lax.dynamic_index_in_dim(buf, sv, 1, keepdims=False)
        row = ka.gram_row(buf, q, b.scope_block_sys(scope),
                          b.scope_n_sys(scope),
                          anchor_first=cfg.anchor == "first",
                          block_n=b.block_n, mesh=b.mesh,
                          lane_axes=b.lane_axes, sys_axes=b.sys_axes)
        out[key] = dmd_math.set_gram_row(g, row, sv)
    return out


# ---------------------------------------------------------------------------
# The jump: one batched solve per group, one combine launch per bucket
# ---------------------------------------------------------------------------

def jump(cfg, table: Dict[str, ArenaBucket], params: PyTree,
         arenas: Dict[str, jnp.ndarray],
         agrams: Optional[Dict[str, jnp.ndarray]], relax,
         groups: Optional[frozenset] = None, s_vec=None,
         resident: bool = False, ridge_vec=None
         ) -> Tuple[Dict[str, jnp.ndarray], List[jnp.ndarray]]:
    """DMD jump over every arena'd leaf of the jumping groups.

    Returns ({path: new_leaf (param dtype)}, [per-leaf mean rank ...]);
    with ``resident=True`` the updates stay flat and are keyed by BUCKET
    ({bucket_key: (N,) new resident row}) — no unpack scatter at all.
    Per group: concatenate the buckets' (n_sys, m, m) Grams, ONE
    dmd_coefficients call (the batched eigh/host-eig solve — m is uniform
    within a group), split the coefficient rows back per bucket, ONE
    segmented combine launch per bucket, then scatter the flat result into
    per-leaf arrays. Missing/None ``agrams`` entries trigger the one-launch
    full Gram recompute (the streaming_gram=False A/B path — also the only
    Gram path for ``anchor=mean`` buckets, whose mean subtraction is fused
    into the kernel).

    Under ``cfg.scope="bucket"`` (DESIGN.md §9) each bucket contributes ONE
    shared-operator system to the group's batched solve (gram_lead == 1):
    the solve batch shrinks from n_leaves to n_buckets (eig host-callback
    rows shrink identically), and the combine broadcasts the bucket's
    single coefficient row across all its blocks via the collapsed
    ``scope_block_sys`` table."""
    from repro.kernels import arena as ka

    scope = getattr(cfg, "scope", "leaf")
    by_path = None if resident else _params_by_path(params)
    per_group = getattr(relax, "ndim", 0) == 1
    updates: Dict[str, jnp.ndarray] = {}
    ranks: List[jnp.ndarray] = []
    by_gi: Dict[int, List[ArenaBucket]] = {}
    for key in sorted(table):
        by_gi.setdefault(table[key].group, []).append(table[key])
    # every bucket must have its arena: a missing key would otherwise leave
    # that bucket's leaves silently unjumped (their `leaf` entries are
    # None); the indexing below fails loudly instead

    for gi in sorted(by_gi):
        if groups is not None and gi not in groups:
            continue
        buckets = by_gi[gi]
        grams = []
        for b in buckets:
            g = agrams.get(b.key) if agrams is not None else None
            if g is None:
                g = ka.gram(arenas[b.key], b.scope_block_sys(scope),
                            b.scope_n_sys(scope),
                            anchor_first=cfg.anchor == "first",
                            anchor_mean=cfg.anchor == "mean",
                            block_n=b.block_n, mesh=b.mesh,
                            lane_axes=b.lane_axes, sys_axes=b.sys_axes)
            grams.append(g)
        gcat = grams[0] if len(grams) == 1 else jnp.concatenate(grams)
        sched = buckets[0].sched
        r = relax[gi] if per_group else relax
        sd = None if s_vec is None else s_vec[gi]
        rd = None if ridge_vec is None else ridge_vec[gi]
        c, info = dmd_math.dmd_coefficients(
            gcat, s=sched.s, tol=cfg.tol, mode=cfg.mode,
            clamp_eigs=cfg.clamp_eigs, anchor=cfg.anchor, affine=cfg.affine,
            trust_region=cfg.trust_region, relax=r, energy=sched.energy,
            s_dyn=sd, atol=getattr(cfg, "atol", 0.0),
            ridge=getattr(sched, "ridge", 0.0), ridge_dyn=rd)
        ofs = 0
        for b in buckets:
            lead = b.gram_lead(scope)
            cb = jax.lax.slice_in_dim(c, ofs, ofs + lead, axis=0)
            rb = jax.lax.slice_in_dim(info["rank"], ofs, ofs + lead, axis=0)
            ofs += lead

            def seg_rank(seg, b=b, rb=rb):
                # bucket scope: one shared operator — every segment reports
                # the bucket's single rank
                if b.bucket_scoped(scope):
                    return jnp.mean(rb.astype(jnp.float32))
                return jnp.mean(jax.lax.slice_in_dim(
                    rb, seg.sys_start * b.sys_factor,
                    (seg.sys_start + seg.n_sys) * b.sys_factor, axis=0
                ).astype(jnp.float32))

            buf = arenas[b.key]
            flat = ka.combine(buf, cb, b.scope_block_sys(scope),
                              block_n=b.block_n, mesh=b.mesh,
                              lane_axes=b.lane_axes, sys_axes=b.sys_axes)
            # Same last line of defense as the per-leaf route: a non-finite
            # BUFFER poisons the combine even under c = e_last (0*inf=NaN);
            # never leave params less finite than the last snapshot.
            flat = jnp.where(jnp.isfinite(flat), flat,
                             buf[:, -1, :].reshape(-1).astype(flat.dtype))
            if resident:
                updates[b.key] = flat.astype(
                    jnp.dtype(b.segments[0].param_dtype))
                for seg in b.segments:
                    ranks.append(seg_rank(seg))
                continue
            for seg, leaf in zip(b.segments, _unpack_row(b, flat)):
                p = by_path[seg.path]
                updates[seg.path] = leaf.astype(p.dtype)
                ranks.append(seg_rank(seg))
    return updates, ranks


# ---------------------------------------------------------------------------
# Leaf-wise views (checkpoint format compatibility)
# ---------------------------------------------------------------------------

def buffers_leafwise(table: Dict[str, ArenaBucket],
                     arenas: Dict[str, jnp.ndarray]) -> Dict[str, Any]:
    """{path: (m, *shape) buffer} — the per-leaf layout a non-arena run
    would carry, sliced out of the arenas (checkpoint save path). The
    block-major buffer is re-slabbed to snapshot-major (m, N) first — a
    transpose + divisible reshape, off the hot path."""
    out = {}
    for key, buf in arenas.items():
        b = table[key]
        slab = jnp.transpose(buf, (1, 0, 2)).reshape(b.m, b.n_lanes)
        for seg, arr in zip(b.segments, _unpack_row(b, slab, lead=1)):
            out[seg.path] = arr
    return out


def grams_leafwise(table: Dict[str, ArenaBucket],
                   agrams: Dict[str, jnp.ndarray], cfg=None,
                   arenas: Optional[Dict[str, jnp.ndarray]] = None
                   ) -> Dict[str, Any]:
    """{path: (stack..., m, m) Gram} per arena'd leaf (checkpoint save).

    The on-disk format is ALWAYS leaf-wise, in both scopes. A bucket-scoped
    (1, m, m) summed Gram cannot be split back per leaf, so those buckets
    recompute the per-system Gram stack from the snapshot buffers (one
    segmented ``ka.gram`` launch per bucket, off the hot path) and slice
    that — ``grams_from_leafwise`` sums it back to the identical bucket
    Gram on a bucket-scope restore (pad lanes are zero, segments share the
    slot schedule, so sum-of-per-system == concatenated-state exactly).
    Mid-window anchor="first" rows recomputed against the CURRENT anchor
    may differ from streamed values that used the then-current anchor —
    the same staleness class snapshots.recompute_grams already repairs on
    restore. ``cfg`` + ``arenas`` are only needed when a bucket is
    bucket-scoped (leaf-scope callers may omit them)."""
    from repro.kernels import arena as ka

    scope = getattr(cfg, "scope", "leaf") if cfg is not None else "leaf"
    out = {}
    for key, g in agrams.items():
        b = table[key]
        if b.bucket_scoped(scope):
            if arenas is None or cfg is None:
                raise ValueError(
                    "bucket-scoped Grams need the snapshot buffers to "
                    "rebuild the leaf-wise checkpoint form — pass cfg and "
                    "arenas")
            g = ka.gram(arenas[key], b.block_sys(), b.n_sys,
                        anchor_first=cfg.anchor == "first",
                        anchor_mean=cfg.anchor == "mean",
                        block_n=b.block_n, mesh=b.mesh,
                        lane_axes=b.lane_axes, sys_axes=b.sys_axes)
        for seg in b.segments:
            sub = jax.lax.slice_in_dim(
                g, seg.sys_start * b.sys_factor,
                (seg.sys_start + seg.n_sys) * b.sys_factor, axis=0)
            stack = seg.shape[:seg.stack_dims]
            out[seg.path] = sub.reshape(stack + (b.m, b.m))
    return out


def buffers_from_leafwise(table: Dict[str, ArenaBucket],
                          by_path: Dict[str, Any], cfg
                          ) -> Dict[str, jnp.ndarray]:
    """Inverse of buffers_leafwise: re-pack restored per-leaf buffers into
    block-major arenas (checkpoint restore path; pad lanes re-zeroed).
    The shard-local pack concatenates to snapshot-major (m, N_local) then
    re-slabs to (nb_local, m, bn) — a transpose + divisible reshape, off
    the hot path."""
    dtype = jnp.dtype(cfg.snapshot_dtype)
    out = {}
    for key, b in table.items():
        leaves = [by_path[s.path] for s in b.segments]

        def local(*ls, b=b):
            packed = jnp.concatenate(
                [_pack_leaf_local(x, s, dtype, lead=1)
                 for x, s in zip(ls, b.segments)], axis=1)
            return jnp.transpose(
                packed.reshape(b.m, -1, b.block_n), (1, 0, 2))

        in_specs = tuple(s.snapshot_spec for s in b.segments)
        out[key] = _shard_wrap(b, local, in_specs, b.buffer_spec())(*leaves)
    return out


def grams_from_leafwise(table: Dict[str, ArenaBucket],
                        by_path: Dict[str, Any], scope: str = "leaf"
                        ) -> Dict[str, jnp.ndarray]:
    """Inverse of grams_leafwise. Bucket-scoped buckets SUM the restored
    per-system Grams into the (1, m, m) shared-operator Gram — an exact
    identity (zero pads, shared slot schedule), so leaf-scope checkpoints
    restore into bucket scope and vice versa, remapped meshes included."""
    out = {}
    for key, b in table.items():
        parts = [jnp.asarray(by_path[s.path], jnp.float32
                             ).reshape(s.n_sys * b.sys_factor, b.m, b.m)
                 for s in b.segments]
        g = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        if b.bucket_scoped(scope):
            g = jnp.sum(g, axis=0, keepdims=True)
        out[key] = g
    return out
