"""Loss-gated adaptive jump controller (DESIGN.md §5).

The paper's headline speedup depends on hand-tuning how many backprop steps
feed each DMD estimation and silently trusts every extrapolation; a bad jump
poisons the next window with nothing to catch it. This module closes the
loop, following two observations from the related work: weight trajectories
concentrate in a small, *drifting* number of correlated modes (Turjeman et
al. 2022), and feeding an objective signal back into the DMD fit improves
extrapolation (Weiner & Semaan 2023).

Mechanism (all of it inside the jitted DMD step — train/step.py):

  * **Gate.** At a group's jump step, evaluate a held-out microbatch loss at
    the pre-jump and jumped params. The gate batch is a VALIDATION batch
    disjoint from the training stream (train/loop.py carves a persistent
    split at trainer init — a gate scored on training rows happily accepts
    train-overfit jumps, the ISSUE 9 generalization bug). Three outcomes:
      - ACCEPT  (loss_post <= loss_pre * (1 + accept_tol)): keep the jump.
      - SCALED  : a small in-trace shrinkage line search — try the
        configured ``shrink_levels`` blend fractions in order; the blend
        level*w_jump + (1-level)*w_pre IS the level-scaled-relax jump,
        because relax enters the coefficients linearly, so each rung costs
        one forward and ZERO extra solves (the Gram/eigh are shared).
        The default (0.5,) is the legacy single blind halving.
      - REJECT  : bit-exact rollback — params and optimizer moments are the
        donated pre-jump buffers passed straight through (the snapshot
        buffers and Gram were never touched by the jump), and the group
        re-enters its scheduled cooldown because the schedule is pure
        step-index arithmetic.
  * **Meta-tuning.** (``meta_lr > 0``, matpow mode) After each gate round
    the gate loss is backpropagated through the differentiable jump wrt the
    per-group relax scale and ridge shrinkage; ``meta_update`` EMAs
    relax_eff / ridge_eff toward the descent direction (Weiner & Semaan,
    PAPERS.md). ridge_eff feeds dmd_coefficients' ``ridge_dyn`` — a
    Tikhonov term that pulls overfit jumps back toward the anchor.
  * **Adaptation.** Per-group counters (accepts / rejects / scale-backs), a
    consecutive-full-accept streak, and an EMA of the per-jump relative gain
    drive two knobs: the effective horizon s_eff grows multiplicatively on
    consecutive accepts and shrinks on rejects, clamped into
    [s_min, configured s] (the static cap sizes the unrolled matrix-power
    chain — core/schedule.py's dynamic-s round math); the effective relax
    scale multiplies by the realized line-search level on every scale-back
    and recovers toward 1 on full accepts.
  * **Rank.** While the controller is on, the POD truncation is
    energy-based per group (GroupSchedule.energy -> dmd_coefficients'
    cumulative-energy mask) instead of the global tol noise floor.

ControllerState is a NamedTuple of tiny (n_groups,) arrays carried in
TrainState — checkpointed, restored, and resharded like any other leaf, so
preemption on the exact jump step resumes counters, s_eff, and the cooldown
phase bit-exactly (tests/test_checkpoint.py, tests/dist_worker.py).

Memory: the gate holds ONE extra params-sized buffer (the pre-jump params)
alive across the jump step only; every other candidate (the half blend) is
formed inside a cond branch and freed with it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched_mod

PyTree = Any

# Gate outcomes (scalar int32 emitted by the jitted gate).
REJECT, SCALED, ACCEPT = 0, 1, 2
OUTCOME_NAMES = ("reject", "scaled", "accept")


class ControllerState(NamedTuple):
    """Per-group controller state, all (n_groups,) arrays."""
    accepts: jnp.ndarray      # int32: jumps kept at full strength
    scaled: jnp.ndarray       # int32: jumps kept after a relax scale-back
    rejects: jnp.ndarray      # int32: jumps rolled back
    streak: jnp.ndarray       # int32: consecutive FULL accepts
    gain_ema: jnp.ndarray     # fp32: EMA of (loss_pre - loss_final)/loss_pre
    s_eff: jnp.ndarray        # fp32: adapted horizon (<= configured s)
    relax_eff: jnp.ndarray    # fp32: effective relax scale in (0, 1]
    ridge_eff: jnp.ndarray    # fp32: meta-tuned Tikhonov shrinkage in
                              # [0, ccfg.ridge_max] (core/dmd.py ridge_dyn);
                              # absent from older checkpoints — restore
                              # keeps the template init (forward-compat)


def init_state(groups: Sequence[sched_mod.GroupSchedule],
               abstract: bool = False) -> ControllerState:
    """Fresh controller state: zero counters, s_eff at each group's
    configured cap, relax scale 1, ridge at each group's schedule base.
    `abstract=True` returns ShapeDtypeStruct leaves (the dry-run path
    allocates nothing)."""
    import jax
    n = len(groups)
    if abstract:
        i = jax.ShapeDtypeStruct((n,), jnp.int32)
        f = jax.ShapeDtypeStruct((n,), jnp.float32)
        return ControllerState(i, i, i, i, f, f, f, f)
    # distinct arrays per field: donated TrainStates may not alias buffers
    zi = lambda: jnp.zeros((n,), jnp.int32)
    return ControllerState(
        accepts=zi(), scaled=zi(), rejects=zi(), streak=zi(),
        gain_ema=jnp.zeros((n,), jnp.float32),
        s_eff=jnp.asarray(sched_mod.s_caps(groups)),
        relax_eff=jnp.ones((n,), jnp.float32),
        ridge_eff=jnp.asarray([getattr(g, "ridge", 0.0) for g in groups],
                              jnp.float32))


def effective_s(state: ControllerState,
                groups: Sequence[sched_mod.GroupSchedule],
                ccfg) -> jnp.ndarray:
    """Traced (n_groups,) integer horizons for this jump (schedule math in
    core/schedule.py so host audits agree with the trace)."""
    return sched_mod.effective_s_vector(groups, state.s_eff,
                                        s_floor=ccfg.s_min)


def gate_outcome(loss_pre, loss_candidate, accept_tol: float):
    """The accept predicate: finite AND within (1 + accept_tol) of the
    pre-jump held-out loss. Shared by the full-jump and half-blend conds."""
    thresh = loss_pre * (1.0 + accept_tol)
    return jnp.isfinite(loss_candidate) & (loss_candidate <= thresh)


def update_on_jump(state: ControllerState, jumped: Tuple[int, ...],
                   outcome, gain, ccfg,
                   groups: Sequence[sched_mod.GroupSchedule],
                   level=0.5) -> ControllerState:
    """Fold one gate decision into the per-group state.

    `jumped` is the STATIC tuple of group indices whose window closed this
    step (staggered schedules: usually one; simultaneous closers share the
    single gate decision — the gate evaluates the combined update).
    `outcome` is the traced scalar {REJECT, SCALED, ACCEPT}; `gain` the
    traced relative improvement of the final (kept) params on the eval
    batch. `level` (traced scalar) is the blend fraction the SCALED branch
    actually kept — the winning rung of the shrinkage line search
    (train/step.py); the default 0.5 is the single-halving legacy value.
    Non-jumped groups pass through untouched.
    """
    n = len(groups)
    gmask = np.zeros((n,), bool)
    gmask[list(jumped)] = True
    gmask = jnp.asarray(gmask)

    full = outcome == ACCEPT
    half = outcome == SCALED
    rej = outcome == REJECT

    accepts = state.accepts + (gmask & full).astype(jnp.int32)
    scaled = state.scaled + (gmask & half).astype(jnp.int32)
    rejects = state.rejects + (gmask & rej).astype(jnp.int32)
    streak = jnp.where(gmask,
                       jnp.where(full, state.streak + 1, 0), state.streak)

    # the SAME [floor, cap] band the realized horizon is clamped into
    # (schedule.s_bounds): persisted state and used horizon cannot drift
    lo, caps = sched_mod.s_bounds(groups, s_floor=ccfg.s_min)
    s_grown = jnp.minimum(state.s_eff * ccfg.grow, caps)
    s_shrunk = jnp.maximum(state.s_eff * ccfg.shrink, lo)
    # grow only on CONSECUTIVE accepts (streak >= 2 after this one), shrink
    # on every reject; a scale-back leaves the horizon alone (the relax
    # halving already tempers the next window's blend).
    s_eff = jnp.where(gmask & rej, s_shrunk,
                      jnp.where(gmask & full & (streak >= 2), s_grown,
                                state.s_eff))

    # scale-back multiplies by the REALIZED line-search level (0.5 when the
    # legacy single halving is the only rung)
    r_scaled = jnp.maximum(
        state.relax_eff * jnp.asarray(level, jnp.float32), ccfg.relax_floor)
    r_recovered = jnp.minimum(state.relax_eff * 2.0, 1.0)
    relax_eff = jnp.where(gmask & half, r_scaled,
                          jnp.where(gmask & full, r_recovered,
                                    state.relax_eff))

    gain = jnp.asarray(gain, jnp.float32)
    gain_ema = jnp.where(
        gmask, ccfg.gain_ema * state.gain_ema + (1.0 - ccfg.gain_ema) * gain,
        state.gain_ema)

    return ControllerState(accepts, scaled, rejects, streak, gain_ema,
                           s_eff, relax_eff, state.ridge_eff)


def meta_update(state: ControllerState, jumped: Tuple[int, ...],
                g_relax, g_ridge, ccfg,
                groups: Sequence[sched_mod.GroupSchedule]
                ) -> ControllerState:
    """Weiner & Semaan meta-tuning fold (DESIGN.md §5): after a gate round,
    move each jumped group's relax/ridge knobs by an EMA step toward the
    descent direction of the gate-batch loss, whose per-group gradients
    `g_relax` / `g_ridge` come from backprop THROUGH the (matpow-mode,
    differentiable) jump in train/step.py.

    Sign-only rule: the raw gradient magnitudes depend on the loss scale
    and the trajectory, so instead of a raw gradient step each knob EMAs
    toward the boundary the gradient points at — relax toward
    ``relax_floor`` when more jump hurts (g_relax > 0) and toward 1.0 when
    it helps; ridge toward ``ridge_max`` when more jump hurts (g_ridge < 0
    means more SHRINKAGE helps, since ridge opposes the jump) and toward
    0.0 otherwise. ``meta_lr`` is the EMA step; non-finite gradients (e.g.
    eigh's degenerate-eigenvalue JVP) leave the knobs untouched, as do
    non-jumped groups.
    """
    n = len(groups)
    gmask = np.zeros((n,), bool)
    gmask[list(jumped)] = True
    gmask = jnp.asarray(gmask)

    lr = jnp.float32(ccfg.meta_lr)
    g_relax = jnp.asarray(g_relax, jnp.float32)
    g_ridge = jnp.asarray(g_ridge, jnp.float32)
    relax_tgt = jnp.where(g_relax > 0, jnp.float32(ccfg.relax_floor),
                          jnp.float32(1.0))
    ridge_tgt = jnp.where(g_ridge > 0, jnp.float32(0.0),
                          jnp.float32(ccfg.ridge_max))
    relax_new = (1.0 - lr) * state.relax_eff + lr * relax_tgt
    ridge_new = jnp.clip((1.0 - lr) * state.ridge_eff + lr * ridge_tgt,
                         0.0, ccfg.ridge_max)
    ok_relax = gmask & jnp.isfinite(g_relax)
    ok_ridge = gmask & jnp.isfinite(g_ridge)
    return state._replace(
        relax_eff=jnp.where(ok_relax, relax_new, state.relax_eff),
        ridge_eff=jnp.where(ok_ridge, ridge_new, state.ridge_eff))


def summary(state: ControllerState,
            groups: Sequence[sched_mod.GroupSchedule]) -> str:
    """Host-side audit table (benches / logging)."""
    rows = [("group", "accepts", "scaled", "rejects", "streak",
             "gain_ema", "s_eff", "relax_eff", "ridge_eff")]
    for g in groups:
        i = g.index
        rows.append((g.name, str(int(state.accepts[i])),
                     str(int(state.scaled[i])), str(int(state.rejects[i])),
                     str(int(state.streak[i])),
                     f"{float(state.gain_ema[i]):.4f}",
                     f"{float(state.s_eff[i]):.1f}",
                     f"{float(state.relax_eff[i]):.3f}",
                     f"{float(state.ridge_eff[i]):.4f}"))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
                     for r in rows)
