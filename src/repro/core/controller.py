"""Loss-gated adaptive jump controller (DESIGN.md §5).

The paper's headline speedup depends on hand-tuning how many backprop steps
feed each DMD estimation and silently trusts every extrapolation; a bad jump
poisons the next window with nothing to catch it. This module closes the
loop, following two observations from the related work: weight trajectories
concentrate in a small, *drifting* number of correlated modes (Turjeman et
al. 2022), and feeding an objective signal back into the DMD fit improves
extrapolation (Weiner & Semaan 2023).

Mechanism (all of it inside the jitted DMD step — train/step.py):

  * **Gate.** At a group's jump step, evaluate a held-out microbatch loss at
    the pre-jump and jumped params. Three outcomes:
      - ACCEPT  (loss_post <= loss_pre * (1 + accept_tol)): keep the jump.
      - SCALED  : halve the effective relax and re-blend — the midpoint
        (w_pre + w_jump) / 2 IS the halved-relax jump, because relax enters
        the coefficients linearly; one extra forward decides it.
      - REJECT  : bit-exact rollback — params and optimizer moments are the
        donated pre-jump buffers passed straight through (the snapshot
        buffers and Gram were never touched by the jump), and the group
        re-enters its scheduled cooldown because the schedule is pure
        step-index arithmetic.
  * **Adaptation.** Per-group counters (accepts / rejects / scale-backs), a
    consecutive-full-accept streak, and an EMA of the per-jump relative gain
    drive two knobs: the effective horizon s_eff grows multiplicatively on
    consecutive accepts and shrinks on rejects, clamped into
    [s_min, configured s] (the static cap sizes the unrolled matrix-power
    chain — core/schedule.py's dynamic-s round math); the effective relax
    scale halves on every scale-back and recovers toward 1 on full accepts.
  * **Rank.** While the controller is on, the POD truncation is
    energy-based per group (GroupSchedule.energy -> dmd_coefficients'
    cumulative-energy mask) instead of the global tol noise floor.

ControllerState is a NamedTuple of tiny (n_groups,) arrays carried in
TrainState — checkpointed, restored, and resharded like any other leaf, so
preemption on the exact jump step resumes counters, s_eff, and the cooldown
phase bit-exactly (tests/test_checkpoint.py, tests/dist_worker.py).

Memory: the gate holds ONE extra params-sized buffer (the pre-jump params)
alive across the jump step only; every other candidate (the half blend) is
formed inside a cond branch and freed with it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import schedule as sched_mod

PyTree = Any

# Gate outcomes (scalar int32 emitted by the jitted gate).
REJECT, SCALED, ACCEPT = 0, 1, 2
OUTCOME_NAMES = ("reject", "scaled", "accept")


class ControllerState(NamedTuple):
    """Per-group controller state, all (n_groups,) arrays."""
    accepts: jnp.ndarray      # int32: jumps kept at full strength
    scaled: jnp.ndarray       # int32: jumps kept after a relax halving
    rejects: jnp.ndarray      # int32: jumps rolled back
    streak: jnp.ndarray       # int32: consecutive FULL accepts
    gain_ema: jnp.ndarray     # fp32: EMA of (loss_pre - loss_final)/loss_pre
    s_eff: jnp.ndarray        # fp32: adapted horizon (<= configured s)
    relax_eff: jnp.ndarray    # fp32: effective relax scale in (0, 1]


def init_state(groups: Sequence[sched_mod.GroupSchedule],
               abstract: bool = False) -> ControllerState:
    """Fresh controller state: zero counters, s_eff at each group's
    configured cap, relax scale 1. `abstract=True` returns ShapeDtypeStruct
    leaves (the dry-run path allocates nothing)."""
    import jax
    n = len(groups)
    if abstract:
        i = jax.ShapeDtypeStruct((n,), jnp.int32)
        f = jax.ShapeDtypeStruct((n,), jnp.float32)
        return ControllerState(i, i, i, i, f, f, f)
    # distinct arrays per field: donated TrainStates may not alias buffers
    zi = lambda: jnp.zeros((n,), jnp.int32)
    return ControllerState(
        accepts=zi(), scaled=zi(), rejects=zi(), streak=zi(),
        gain_ema=jnp.zeros((n,), jnp.float32),
        s_eff=jnp.asarray(sched_mod.s_caps(groups)),
        relax_eff=jnp.ones((n,), jnp.float32))


def effective_s(state: ControllerState,
                groups: Sequence[sched_mod.GroupSchedule],
                ccfg) -> jnp.ndarray:
    """Traced (n_groups,) integer horizons for this jump (schedule math in
    core/schedule.py so host audits agree with the trace)."""
    return sched_mod.effective_s_vector(groups, state.s_eff,
                                        s_floor=ccfg.s_min)


def gate_outcome(loss_pre, loss_candidate, accept_tol: float):
    """The accept predicate: finite AND within (1 + accept_tol) of the
    pre-jump held-out loss. Shared by the full-jump and half-blend conds."""
    thresh = loss_pre * (1.0 + accept_tol)
    return jnp.isfinite(loss_candidate) & (loss_candidate <= thresh)


def update_on_jump(state: ControllerState, jumped: Tuple[int, ...],
                   outcome, gain, ccfg,
                   groups: Sequence[sched_mod.GroupSchedule]
                   ) -> ControllerState:
    """Fold one gate decision into the per-group state.

    `jumped` is the STATIC tuple of group indices whose window closed this
    step (staggered schedules: usually one; simultaneous closers share the
    single gate decision — the gate evaluates the combined update).
    `outcome` is the traced scalar {REJECT, SCALED, ACCEPT}; `gain` the
    traced relative improvement of the final (kept) params on the eval
    batch. Non-jumped groups pass through untouched.
    """
    n = len(groups)
    gmask = np.zeros((n,), bool)
    gmask[list(jumped)] = True
    gmask = jnp.asarray(gmask)

    full = outcome == ACCEPT
    half = outcome == SCALED
    rej = outcome == REJECT

    accepts = state.accepts + (gmask & full).astype(jnp.int32)
    scaled = state.scaled + (gmask & half).astype(jnp.int32)
    rejects = state.rejects + (gmask & rej).astype(jnp.int32)
    streak = jnp.where(gmask,
                       jnp.where(full, state.streak + 1, 0), state.streak)

    # the SAME [floor, cap] band the realized horizon is clamped into
    # (schedule.s_bounds): persisted state and used horizon cannot drift
    lo, caps = sched_mod.s_bounds(groups, s_floor=ccfg.s_min)
    s_grown = jnp.minimum(state.s_eff * ccfg.grow, caps)
    s_shrunk = jnp.maximum(state.s_eff * ccfg.shrink, lo)
    # grow only on CONSECUTIVE accepts (streak >= 2 after this one), shrink
    # on every reject; a scale-back leaves the horizon alone (the relax
    # halving already tempers the next window's blend).
    s_eff = jnp.where(gmask & rej, s_shrunk,
                      jnp.where(gmask & full & (streak >= 2), s_grown,
                                state.s_eff))

    r_halved = jnp.maximum(state.relax_eff * 0.5, ccfg.relax_floor)
    r_recovered = jnp.minimum(state.relax_eff * 2.0, 1.0)
    relax_eff = jnp.where(gmask & half, r_halved,
                          jnp.where(gmask & full, r_recovered,
                                    state.relax_eff))

    gain = jnp.asarray(gain, jnp.float32)
    gain_ema = jnp.where(
        gmask, ccfg.gain_ema * state.gain_ema + (1.0 - ccfg.gain_ema) * gain,
        state.gain_ema)

    return ControllerState(accepts, scaled, rejects, streak, gain_ema,
                           s_eff, relax_eff)


def summary(state: ControllerState,
            groups: Sequence[sched_mod.GroupSchedule]) -> str:
    """Host-side audit table (benches / logging)."""
    rows = [("group", "accepts", "scaled", "rejects", "streak",
             "gain_ema", "s_eff", "relax_eff")]
    for g in groups:
        i = g.index
        rows.append((g.name, str(int(state.accepts[i])),
                     str(int(state.scaled[i])), str(int(state.rejects[i])),
                     str(int(state.streak[i])),
                     f"{float(state.gain_ema[i]):.4f}",
                     f"{float(state.s_eff[i]):.1f}",
                     f"{float(state.relax_eff[i]):.3f}"))
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    return "\n".join("  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
                     for r in rows)
