"""Learning-rate schedules as pure functions of the step counter.

Includes WSD (warmup-stable-decay), the schedule MiniCPM trains with
[arXiv:2404.06395]: linear warmup -> constant plateau -> decay over the final
`decay_fraction` of training down to `min_lr_ratio * lr`.
"""
from __future__ import annotations

import jax.numpy as jnp


def make_schedule(cfg):
    """cfg: OptimizerConfig -> f(step) -> lr (jnp scalar)."""
    base = cfg.lr
    warm = max(int(cfg.warmup_steps), 0)
    total = max(int(cfg.total_steps), 1)
    floor = cfg.min_lr_ratio * base

    def warmup_part(step):
        if warm == 0:
            return jnp.asarray(1.0, jnp.float32)
        return jnp.minimum((step + 1.0) / warm, 1.0).astype(jnp.float32)

    if cfg.schedule == "constant":
        def f(step):
            return base * warmup_part(step)
    elif cfg.schedule == "linear_warmup":
        def f(step):
            return base * warmup_part(step)
    elif cfg.schedule == "cosine":
        def f(step):
            t = jnp.clip((step - warm) / max(total - warm, 1), 0.0, 1.0)
            cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
            return (floor + (base - floor) * cos) * warmup_part(step)
    elif cfg.schedule == "wsd":
        decay_steps = max(int(total * cfg.decay_fraction), 1)
        stable_end = total - decay_steps

        def f(step):
            t = jnp.clip((step - stable_end) / decay_steps, 0.0, 1.0)
            lr = base - (base - floor) * t            # linear decay tail
            return lr * warmup_part(step)
    else:
        raise ValueError(f"unknown schedule {cfg.schedule!r}")
    return f
