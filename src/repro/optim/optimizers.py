"""Mini-optax: gradient-transform optimizers as pure pytree functions.

Every optimizer is an `Optimizer(init, update)` pair:
    state   = opt.init(params)
    updates, state = opt.update(grads, state, params, step)
    params  = apply_updates(params, updates)

All states are pytrees of arrays (shardable, checkpointable). `step` is a
scalar int32 array; schedules are baked into `update` via closures.

Beyond-paper / at-scale extras:
  * `adafactor` — factored second moment (Shazeer & Stern, arXiv:1804.04235):
    O(n) -> O(rows+cols) optimizer memory, what makes the 400B llama4 cell fit
    16 GB/chip.
  * `adam8bit` — block-wise int8 quantized Adam moments (Dettmers,
    arXiv:2110.02861 adapted): 4x optimizer-state compression with per-block
    absmax scales.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.optim.schedules import make_schedule

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[..., tuple]      # (grads, state, params, step) -> (updates, state)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p,
        params, updates)


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.asarray(0.0)


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree_util.tree_map(lambda x: x * scale.astype(x.dtype), tree)


# ---------------------------------------------------------------------------
# Plain SGD / momentum
# ---------------------------------------------------------------------------

def sgd(lr_fn) -> Optimizer:
    def init(params):
        return ()

    def update(grads, state, params, step):
        lr = lr_fn(step)
        updates = jax.tree_util.tree_map(lambda g: -lr * g.astype(jnp.float32), grads)
        return updates, state
    return Optimizer(init, update)


def momentum(lr_fn, beta: float = 0.9) -> Optimizer:
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        new_m = jax.tree_util.tree_map(
            lambda m, g: beta * m + g.astype(jnp.float32), state, grads)
        updates = jax.tree_util.tree_map(lambda m: -lr * m, new_m)
        return updates, new_m
    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adam / AdamW
# ---------------------------------------------------------------------------

class AdamState(NamedTuple):
    m: PyTree
    v: PyTree


def adam(lr_fn, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamState(jax.tree_util.tree_map(zeros, params),
                         jax.tree_util.tree_map(zeros, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(m, v, g, p):
            if m is None:
                # arena-resident params (core/arena.py): packed positions
                # of the leaf subtree are None nodes — but the is_leaf
                # below makes them leaves of the driving tree, so skip
                # them here (their moments live in the __arena__ buffers).
                return None
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return m, v, u

        out = jax.tree_util.tree_map(upd, state.m, state.v, grads, params,
                                     is_leaf=lambda x: x is None)
        m = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        u = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
        return u, AdamState(m, v)
    return Optimizer(init, update)


def adamw(lr_fn, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01) -> Optimizer:
    return adam(lr_fn, b1, b2, eps, weight_decay)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment) — optimizer-memory O(rows + cols)
# ---------------------------------------------------------------------------

class AdafactorState(NamedTuple):
    vr: PyTree      # row second-moment (or full v for <2D leaves)
    vc: PyTree      # col second-moment (or () for <2D leaves)


def adafactor(lr_fn, decay=0.999, eps=1e-30, clip_threshold=1.0) -> Optimizer:
    """Beta1-free Adafactor. Factors the trailing two dims of >=2D params."""

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def vr_of(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-1], jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        def vc_of(p):
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            return jnp.zeros((), jnp.float32)
        return AdafactorState(jax.tree_util.tree_map(vr_of, params),
                              jax.tree_util.tree_map(vc_of, params))

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** -0.8           # time-dependent decay (Shazeer & Stern)
        beta = jnp.minimum(beta, decay)

        def upd(vr, vc, g, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p):
                new_vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                new_vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                # rank-1 reconstruction of v
                denom = jnp.mean(new_vr, axis=-1, keepdims=True)
                vhat = (new_vr[..., :, None] * new_vc[..., None, :]
                        / jnp.maximum(denom[..., None], eps))
                u = g / jnp.sqrt(vhat + eps)
            else:
                new_vr = beta * vr + (1 - beta) * g2
                new_vc = vc
                u = g / jnp.sqrt(new_vr + eps)
            # update clipping by RMS
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return new_vr, new_vc, -lr * u

        out = jax.tree_util.tree_map(upd, state.vr, state.vc, grads, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(2), AdafactorState(pick(0), pick(1))
    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# 8-bit Adam: block-quantized moments
# ---------------------------------------------------------------------------

_QBLOCK = 256


def _quantize(x: jnp.ndarray):
    """Flatten to blocks of _QBLOCK, store int8 + fp32 absmax scale per block."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % _QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return q, scale[:, 0]


def _dequantize(q, scale, shape):
    blocks = q.astype(jnp.float32) * scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


class Adam8bitState(NamedTuple):
    mq: PyTree
    ms: PyTree
    vq: PyTree
    vs: PyTree


def adam8bit(lr_fn, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0) -> Optimizer:
    def init(params):
        def qz(p):
            q, s = _quantize(jnp.zeros(p.shape, jnp.float32))
            return q, s
        qs = jax.tree_util.tree_map(qz, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], qs, is_leaf=lambda x: isinstance(x, tuple))
        mq, ms = pick(0), pick(1)
        return Adam8bitState(mq, ms, mq, ms)

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def upd(mq, ms, vq, vs, g, p):
            g = g.astype(jnp.float32)
            m = b1 * _dequantize(mq, ms, p.shape) + (1 - b1) * g
            v = b2 * _dequantize(vq, vs, p.shape) + (1 - b2) * g * g
            v = jnp.maximum(v, 0.0)
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            nmq, nms = _quantize(m)
            nvq, nvs = _quantize(v)
            return nmq, nms, nvq, nvs, u

        out = jax.tree_util.tree_map(upd, state.mq, state.ms, state.vq,
                                     state.vs, grads, params)
        pick = lambda i: jax.tree_util.tree_map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        return pick(4), Adam8bitState(pick(0), pick(1), pick(2), pick(3))
    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Factory
# ---------------------------------------------------------------------------

def make_optimizer(cfg) -> Optimizer:
    """cfg: OptimizerConfig -> Optimizer with schedule + clipping baked in."""
    lr_fn = make_schedule(cfg)
    if cfg.name == "sgd":
        base = sgd(lr_fn)
    elif cfg.name == "momentum":
        base = momentum(lr_fn, beta=cfg.b1)
    elif cfg.name == "adam":
        base = adam(lr_fn, cfg.b1, cfg.b2, cfg.eps, 0.0)
    elif cfg.name == "adamw":
        base = adamw(lr_fn, cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay)
    elif cfg.name == "adafactor":
        base = adafactor(lr_fn, decay=cfg.b2)
    elif cfg.name == "adam8bit":
        base = adam8bit(lr_fn, cfg.b1, cfg.b2, cfg.eps, cfg.weight_decay)
    else:
        raise ValueError(f"unknown optimizer {cfg.name!r}")

    if cfg.grad_clip and cfg.grad_clip > 0:
        inner = base

        def update(grads, state, params, step):
            grads = clip_by_global_norm(grads, cfg.grad_clip)
            return inner.update(grads, state, params, step)
        base = Optimizer(inner.init, update)
    return base
