from repro.optim.optimizers import (
    Optimizer, make_optimizer, sgd, momentum, adam, adamw, adafactor, adam8bit,
    global_norm, clip_by_global_norm, apply_updates,
)
from repro.optim.schedules import make_schedule

__all__ = [
    "Optimizer", "make_optimizer", "sgd", "momentum", "adam", "adamw",
    "adafactor", "adam8bit", "global_norm", "clip_by_global_norm",
    "apply_updates", "make_schedule",
]
