"""Sharded, atomic, elastic checkpointing.

Format: one .npz per checkpoint step holding every leaf (flattened paths) +
a manifest.json (step, leaf paths, shapes, dtypes). Writes go to a temp dir
renamed atomically, so a preemption mid-write never corrupts the latest
checkpoint. keep=k prunes old steps.

Elastic restore: leaves are loaded as host numpy then device_put against the
CURRENT mesh's shardings — a checkpoint written on one topology restores onto
any other (tested across different host-device counts).

Packed-arena states (DESIGN.md §7) are saved/restored LEAF-WISE: the Trainer
unpacks the per-bucket block-major ring buffers into per-leaf buffers/Grams
(``DMDAccelerator.state_leafwise``) before calling save_checkpoint here, and
re-packs after restore — so the manifest paths and on-disk format are
identical whether ``dmd.arena`` is on or off, pre-arena checkpoints load
unchanged, and the elastic re-placement above keeps operating on the audited
per-leaf PartitionSpecs. Nothing in this module needs to know about arenas;
the format contract is the point.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(tree: PyTree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(ckpt_dir: str, state: PyTree, step: int, keep: int = 3):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    arrays = {}
    manifest = {"step": int(step), "leaves": {}}
    for i, (path, leaf) in enumerate(sorted(flat.items())):
        if leaf is None:
            manifest["leaves"][path] = None
            continue
        key = f"a{i}"
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":        # np.savez can't store bf16
            arr = arr.view(np.uint16)
        arrays[key] = arr
        manifest["leaves"][path] = {"key": key, "shape": list(arr.shape),
                                    "dtype": logical_dtype}
    tmp = Path(tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_"))
    try:
        np.savez(tmp / "arrays.npz", **arrays)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        final = ckpt_dir / f"step_{step}"
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return str(ckpt_dir / f"step_{step}")


def _prune(ckpt_dir: Path, keep: int):
    steps = sorted(list_checkpoints(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(ckpt_dir / f"step_{s}", ignore_errors=True)


def list_checkpoints(ckpt_dir) -> list:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return []
    out = []
    for d in ckpt_dir.iterdir():
        m = _STEP_RE.match(d.name)
        if m and (d / "manifest.json").exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, template: PyTree, *, mesh=None,
                       step: Optional[int] = None) -> Optional[PyTree]:
    """Restore onto the CURRENT topology. template supplies the pytree
    structure (and target shardings via its leaves or the mesh rules).

    Leaves present in the template but absent from the manifest keep the
    template's value — this is the forward-compat path for state grown
    AFTER a checkpoint was written (e.g. the jump-controller arrays in
    TrainState: a pre-controller checkpoint restores with a freshly
    initialized ControllerState, while controller-era checkpoints restore
    counters / s_eff / relax_eff bit-exactly)."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None
    d = ckpt_dir / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    arrays = np.load(d / "arrays.npz")

    flat_t = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat_t[0]:
        key = jax.tree_util.keystr(path)
        meta = manifest["leaves"].get(key)
        if meta is None:
            leaves.append(None if leaf is None else leaf)
            continue
        arr = arrays[meta["key"]]
        if meta["dtype"] == "bfloat16":
            arr = np.asarray(jnp.asarray(arr).view(jnp.bfloat16))
        if leaf is not None and hasattr(leaf, "sharding") and mesh is not None:
            leaves.append(jax.device_put(arr, leaf.sharding))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves)
