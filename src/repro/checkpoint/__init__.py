from repro.checkpoint.checkpoint import (
    save_checkpoint, restore_checkpoint, latest_step, list_checkpoints,
)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "list_checkpoints"]
