"""shard_map'd DMD data passes for sharded / stacked buffer leaves.

The flat Pallas kernels (kernels/gram.py, gram_row.py, combine.py) take an
(m, n) buffer — but flattening a GSPMD-sharded buffer forces an all-gather of
the whole thing (measured 59 GiB on a 22-layer stack; DESIGN.md §3), which is
why sharded multi-dim and stacked leaves historically fell back to the
batched dot_general. This module closes that gap (the ROADMAP item): run the
SAME Pallas kernels per shard under `shard_map`, where the reshape is local
and free:

    shard_map(buf sharded per plan.snapshot_spec):
        local flatten (m, n_local)  ->  Pallas kernel, fp32 partial
        -> psum over the axes sharding the contracted dims
           (O(stack·m²) for gram, O(stack·m) for gram_row — tiny)
    combine needs NO psum: c is replicated, the output is sharded exactly
    like the param.

Stacked leaves (scan-over-layers params) vmap the kernel over the collapsed
stack axes — one independent (m, m) Gram per layer, as the paper prescribes.
The anchor subtraction stays fused in-kernel and is shard-local-correct: row
0 of each local tile IS the local slice of the global anchor row. bf16
buffers (`gram_upcast=False`) work unchanged — the kernels upcast per tile in
VMEM, so there is never an HBM-sized fp32 materialization.

Inside shard_map the local call goes through `kernels.ops`, so backend
dispatch still applies: compiled Pallas on TPU, dot_general refs on CPU, and
`ops.set_backend("pallas")` + interpret for the kernel-contract tests. The
shard_map wrapper needs `check_rep=False` (no replication rule exists for
`pallas_call`).

With no mesh on the plan the wrappers degrade to the same local computation
without shard_map — single-host benchmarks and tests share one code path.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def _split_stack(x: jnp.ndarray, k: int):
    """(m, s1..sk, rest...) -> (S, m, rest...) with S = prod(stack)."""
    m = x.shape[0]
    stack = x.shape[1:1 + k]
    rest = x.shape[1 + k:]
    xt = jnp.moveaxis(x, 0, k)                    # (s1..sk, m, rest...)
    s_flat = 1
    for d in stack:
        s_flat *= int(d)
    return xt.reshape((s_flat, m) + tuple(rest)), tuple(stack), tuple(rest)


def _local_gram(x, k, anchor_first, block_n, interpret):
    if k == 0:
        return ops.gram(x, anchor_first=anchor_first, block_n=block_n,
                        interpret=interpret)
    xs, stack, _ = _split_stack(x, k)
    g = jax.vmap(lambda s: ops.gram(s, anchor_first=anchor_first,
                                    block_n=block_n, interpret=interpret))(xs)
    m = x.shape[0]
    return g.reshape(stack + (m, m))


def _local_gram_row(x, q, k, anchor_first, block_n, interpret):
    if k == 0:
        return ops.gram_row(x, q, anchor_first=anchor_first, block_n=block_n,
                            interpret=interpret)
    xs, stack, rest = _split_stack(x, k)
    qs = q.reshape((xs.shape[0],) + rest)
    r = jax.vmap(lambda s, qq: ops.gram_row(
        s, qq, anchor_first=anchor_first, block_n=block_n,
        interpret=interpret))(xs, qs)
    return r.reshape(stack + (x.shape[0],))


def _local_combine(x, c, k, block_n, interpret):
    if k == 0:
        return ops.combine(x, c, block_n=block_n, interpret=interpret)
    xs, stack, rest = _split_stack(x, k)
    cs = c.reshape((xs.shape[0], x.shape[0]))
    w = jax.vmap(lambda s, cc: ops.combine(
        s, cc, block_n=block_n, interpret=interpret))(xs, cs)
    return w.reshape(stack + rest)


def _wrap(plan, fn, in_specs, out_specs):
    if plan.mesh is None:
        return fn
    from repro.distributed.sharding import shard_map
    return shard_map(fn, mesh=plan.mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


def gram(buf: jnp.ndarray, plan, *, anchor_first: bool = False,
         interpret=None) -> jnp.ndarray:
    """(m, stack..., param...) -> (stack..., m, m) fp32 full Gram."""
    k = plan.stack_dims
    axes = plan.psum_axes()

    def local(x):
        g = _local_gram(x, k, anchor_first, plan.block_n, interpret)
        return jax.lax.psum(g, axes) if axes else g

    out_spec = P(*plan.stack_spec_entries, None, None)
    return _wrap(plan, local, (plan.snapshot_spec,), out_spec)(buf)


def gram_row(buf: jnp.ndarray, p: jnp.ndarray, plan, *,
             anchor_first: bool = False, interpret=None) -> jnp.ndarray:
    """(m, stack..., param...), (stack..., param...) -> (stack..., m): the
    streaming row of <d_p, d_j>, one O(stack·m·n_local) pass + psum."""
    k = plan.stack_dims
    axes = plan.psum_axes()

    def local(x, q):
        r = _local_gram_row(x, q, k, anchor_first, plan.block_n, interpret)
        return jax.lax.psum(r, axes) if axes else r

    out_spec = P(*plan.stack_spec_entries, None)
    return _wrap(plan, local, (plan.snapshot_spec, plan.param_spec),
                 out_spec)(buf, p)


def combine(buf: jnp.ndarray, c: jnp.ndarray, plan, *,
            interpret=None) -> jnp.ndarray:
    """(m, stack..., param...), (stack..., m) -> (stack..., param...) fp32.
    Pure local pass: c is replicated and the contraction runs over the
    replicated snapshot axis, so the output inherits the param's sharding
    with zero collectives."""
    k = plan.stack_dims

    def local(x, cc):
        return _local_combine(x, cc, k, plan.block_n, interpret)

    c_spec = P(*plan.stack_spec_entries, None)
    return _wrap(plan, local, (plan.snapshot_spec, c_spec),
                 plan.param_spec)(buf, c)
