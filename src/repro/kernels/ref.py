"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(snapshots: jnp.ndarray, anchor_first: bool = False) -> jnp.ndarray:
    """(m, n) -> (m, m) = D D^T with optional D = S - S[0]."""
    s = snapshots.astype(jnp.float32)
    if anchor_first:
        s = s - s[:1]
    return s @ s.T


def combine_ref(snapshots: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(m, n), (m,) -> (n,) = S^T c in fp32."""
    return jnp.einsum("m,mn->n", c.astype(jnp.float32),
                      snapshots.astype(jnp.float32))


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """(B, Sq, H, d), (B, Sk, H, d) -> (B, Sq, H, d), fp32 softmax."""
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    rel = q_pos - k_pos
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= rel >= 0
    if window:
        mask &= rel < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
