"""Pure-jnp oracles for every Pallas kernel (the correctness contract)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_ref(snapshots: jnp.ndarray, anchor_first: bool = False) -> jnp.ndarray:
    """(m, ...) -> (m, m) = D D^T with optional D = S - S[0].

    Contracts ALL trailing axes with one dot_general — no flatten: a reshape
    of a sharded buffer would force GSPMD to all-gather it (dmd.gram_matrix
    has the measurement), and this function doubles as the CPU dispatch
    target for sharded training, not just the (m, n) kernel-test oracle."""
    s = snapshots.astype(jnp.float32)
    if anchor_first:
        s = s - s[:1]
    contract = tuple(range(1, s.ndim))
    return jax.lax.dot_general(s, s, ((contract, contract), ((), ())),
                               preferred_element_type=jnp.float32)


def gram_row_ref(snapshots: jnp.ndarray, p: jnp.ndarray,
                 anchor_first: bool = False) -> jnp.ndarray:
    """(m, ...), (...) -> (m,) = row of <d_p, d_j>, optional d = s - s[0]."""
    x = snapshots.astype(jnp.float32)
    q = p.astype(jnp.float32)
    if anchor_first:
        q = q - x[0]
        x = x - x[:1]
    contract = tuple(range(1, x.ndim))
    return jax.lax.dot_general(x, q, ((contract, tuple(range(q.ndim))), ((), ())),
                               preferred_element_type=jnp.float32)


def combine_ref(snapshots: jnp.ndarray, c: jnp.ndarray) -> jnp.ndarray:
    """(m, ...), (m,) -> (...) = S^T c in fp32 (trailing axes preserved)."""
    return jnp.tensordot(c.astype(jnp.float32),
                         snapshots.astype(jnp.float32), axes=(0, 0))


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jnp.ndarray:
    """(B, Sq, H, d), (B, Sk, H, d) -> (B, Sq, H, d), fp32 softmax."""
    B, Sq, H, d = q.shape
    Sk = k.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    rel = q_pos - k_pos
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= rel >= 0
    if window:
        mask &= rel < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
