"""Pallas TPU kernel: streaming snapshot Gram matrix G = D D^T.

The DMD hot spot #1 (DESIGN.md §2): a tall-skinny (m x n, n up to billions
per shard) self-Gram. Bandwidth-bound: each n-tile of the snapshot buffer
streams HBM -> VMEM exactly once; the m x m fp32 accumulator lives in VMEM
scratch across the whole grid (m <= 32). The anchor subtraction (D = S -
S[0], the fp32-conditioning fix) is fused into the same pass — row 0 of each
tile IS the anchor slice, so anchoring costs zero extra bandwidth.

Tiling: grid over n // block_n; block (m_pad, block_n) with m padded to the
8-row sublane multiple and block_n a multiple of 128 lanes. One MXU
contraction (m_pad x block_n) @ (block_n x m_pad) per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(x_ref, out_ref, acc_ref, *, anchor_first: bool, m: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    if anchor_first:
        x = x - x[0:1, :]
    acc_ref[...] += jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("anchor_first", "block_n", "interpret"))
def gram_pallas(snapshots: jnp.ndarray, *, anchor_first: bool = False,
                block_n: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """(m, n) -> (m, m) fp32. Pads m to 8 and n to block_n (zero rows/cols
    contribute zero to the Gram, so padding is exact)."""
    m, n = snapshots.shape
    m_pad = max(-(-m // 8) * 8, 8)
    n_pad = -(-n // block_n) * block_n
    x = snapshots
    if (m_pad, n_pad) != (m, n):
        x = jnp.pad(x, ((0, m_pad - m), (0, n_pad - n)))
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, anchor_first=anchor_first, m=m),
        grid=grid,
        in_specs=[pl.BlockSpec((m_pad, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m_pad, m_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, m_pad), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, m_pad), jnp.float32)]
        if not interpret else
        [pltpu.VMEM((m_pad, m_pad), jnp.float32)],
        interpret=interpret,
    )(x)
    return out[:m, :m]
