"""Pallas TPU kernel: one streaming Gram row r_j = <d_p, d_j>.

The streaming-Gram engine's hot pass (DESIGN.md §2): after the train step
writes the new snapshot p into its buffer slot, the running (m, m) Gram only
needs ONE new row — an O(m*n) anchored inner-product sweep over the buffer,
instead of the O(m^2*n) full recompute `gram.py` does. Bandwidth-bound: each
n-tile of the buffer streams HBM -> VMEM exactly once, together with the
matching tile of p; the (m, 1) fp32 accumulator lives in VMEM scratch across
the whole grid. The anchor subtraction (d = s - s_0) is fused: row 0 of each
buffer tile IS the anchor slice, so anchoring costs zero extra bandwidth.

Tiling matches gram.py: grid over n // block_n; blocks (m_pad, block_n) with
m padded to the 8-row sublane multiple and block_n a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_row_kernel(x_ref, p_ref, out_ref, acc_ref, *, anchor_first: bool):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    q = p_ref[...].astype(jnp.float32)            # (1, block_n)
    if anchor_first:
        q = q - x[0:1, :]
        x = x - x[0:1, :]
    acc_ref[...] += jax.lax.dot_general(
        x, q, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (m_pad, 1)

    @pl.when(i == pl.num_programs(0) - 1)
    def _done():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("anchor_first", "block_n", "interpret"))
def gram_row_pallas(snapshots: jnp.ndarray, p: jnp.ndarray, *,
                    anchor_first: bool = False, block_n: int = 2048,
                    interpret: bool = True) -> jnp.ndarray:
    """(m, n), (n,) -> (m,) fp32 row of <d_p, d_j>. Pads m to 8 and n to
    block_n (zero lanes contribute zero to every inner product, and the
    anchor row's padding is zero too, so padding is exact)."""
    m, n = snapshots.shape
    m_pad = max(-(-m // 8) * 8, 8)
    n_pad = -(-n // block_n) * block_n
    x = snapshots
    p2 = p.reshape(1, n)
    if (m_pad, n_pad) != (m, n):
        x = jnp.pad(x, ((0, m_pad - m), (0, n_pad - n)))
        p2 = jnp.pad(p2, ((0, 0), (0, n_pad - n)))
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        functools.partial(_gram_row_kernel, anchor_first=anchor_first),
        grid=grid,
        in_specs=[pl.BlockSpec((m_pad, block_n), lambda i: (0, i)),
                  pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((m_pad, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((m_pad, 1), jnp.float32)],
        interpret=interpret,
    )(x, p2)
    return out[:m, 0]
