"""Pallas TPU kernel: snapshot combination w = S^T c.

The DMD hot spot #2 (DESIGN.md §2): the extrapolated weights are a linear
combination of the m stored snapshots with coefficients c computed from the
Gram matrix. Bandwidth-bound pass: each n-tile streams once, multiplied by
the tiny (m,) coefficient vector held in VMEM; fused anchor fold-back is
unnecessary because the anchor is already folded into c (dmd_coefficients).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(c_ref, x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    c = c_ref[...].astype(jnp.float32)            # (1, m_pad)
    out_ref[...] = jax.lax.dot_general(
        c, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, block_n)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def combine_pallas(snapshots: jnp.ndarray, c: jnp.ndarray, *,
                   block_n: int = 2048, interpret: bool = True) -> jnp.ndarray:
    """(m, n), (m,) -> (n,) fp32."""
    m, n = snapshots.shape
    m_pad = max(-(-m // 8) * 8, 8)
    n_pad = -(-n // block_n) * block_n
    x = snapshots
    if (m_pad, n_pad) != (m, n):
        x = jnp.pad(x, ((0, m_pad - m), (0, n_pad - n)))
    c2 = jnp.pad(c.astype(jnp.float32), (0, m_pad - m)).reshape(1, m_pad)
    grid = (n_pad // block_n,)
    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, m_pad), lambda i: (0, 0)),
                  pl.BlockSpec((m_pad, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        interpret=interpret,
    )(c2, x)
    return out[0, :n]
