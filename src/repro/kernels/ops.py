"""Jit'd public wrappers around the Pallas kernels.

On this CPU container the kernels run in interpret mode (the kernel body
executes as plain jnp on CPU — the correctness contract vs ref.py holds);
on TPU set interpret=False (the default flips on TPU backends).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.gram import gram_pallas
from repro.kernels.combine import combine_pallas
from repro.kernels.flash_attention import flash_attention_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def gram(snapshots: jnp.ndarray, *, anchor_first: bool = False,
         block_n: int = 2048, interpret=None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    m = snapshots.shape[0]
    flat = snapshots.reshape(m, -1)
    return gram_pallas(flat, anchor_first=anchor_first,
                       block_n=min(block_n, max(flat.shape[1], 128)),
                       interpret=interpret)


def combine(snapshots: jnp.ndarray, c: jnp.ndarray, *, block_n: int = 2048,
            interpret=None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    m = snapshots.shape[0]
    flat = snapshots.reshape(m, -1)
    out = combine_pallas(flat, c,
                         block_n=min(block_n, max(flat.shape[1], 128)),
                         interpret=interpret)
    return out.reshape(snapshots.shape[1:])


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tq: int = 128, tk: int = 128, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  tq=tq, tk=tk, interpret=interpret)
