"""Backend dispatch for the DMD data-pass kernels (DESIGN.md §3).

Every public entry point (`gram`, `gram_row`, `combine`, `flash_attention`)
routes by backend:

  * TPU  -> the Pallas kernels, COMPILED (interpret=False). The seed
    hard-wired interpret mode everywhere, so the kernels never actually
    compiled even on TPU hardware.
  * CPU/GPU -> the pure `dot_general` references in `ref.py`. These are the
    correctness oracles and XLA already emits optimal code for them; running
    the Pallas interpreter on CPU would be strictly slower.

`interpret=True` may still be passed explicitly to force the Pallas kernel
body through the interpreter on any backend — that is the kernel-vs-oracle
contract exercised by tests/test_kernels.py. `set_backend()` is the test /
benchmark override for the automatic routing.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.gram import gram_pallas
from repro.kernels.gram_row import gram_row_pallas
from repro.kernels.combine import combine_pallas
from repro.kernels.flash_attention import flash_attention_pallas

_FORCED_BACKEND: Optional[str] = None


def set_backend(backend: Optional[str]) -> None:
    """Force routing: "pallas" | "ref" | None (auto by jax.default_backend)."""
    global _FORCED_BACKEND
    if backend not in (None, "pallas", "ref"):
        raise ValueError(f"unknown kernel backend {backend!r}")
    _FORCED_BACKEND = backend


def active_backend() -> str:
    if _FORCED_BACKEND is not None:
        return _FORCED_BACKEND
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _route(interpret) -> str:
    """interpret=None -> backend routing; interpret=True/False -> Pallas with
    that interpreter setting (the explicit kernel-test path)."""
    if interpret is None:
        return active_backend()
    return "pallas"


def _interp(interpret) -> bool:
    """Resolve interpret for a Pallas route: None ("auto", reached via a
    forced set_backend('pallas')) must still interpret off-TPU — compiled
    Pallas only exists on TPU."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


LANES = 128                       # TPU vector-lane width (last-dim tiling)


def lane_block(block_n: int, n: int) -> int:
    """Clamp the requested n-tile to the leaf: a 128-lane multiple no wider
    than the lane-padded leaf itself. The old ``min(block_n, max(n, 128))``
    returned blocks that were NOT lane multiples for 128 < n < block_n
    (n=333 -> block 333) — interpret mode shrugged, compiled TPU Pallas
    requires the multiple. Tiny leaves (n < 128) get one 128-lane tile; the
    wrappers zero-pad to the block, and zero lanes contribute zero to every
    inner product, so padding is exact (tests: tiny-leaf kernel-vs-oracle).

    The ONE home of this invariant: core/leafplan.py sizes plan.block_n with
    it too, so the plan and the kernel wrappers can never disagree."""
    n_pad = max(-(-max(n, 1) // LANES) * LANES, LANES)
    return max(min(block_n // LANES * LANES, n_pad), LANES)


_block = lane_block               # internal call sites


def gram(snapshots: jnp.ndarray, *, anchor_first: bool = False,
         block_n: int = 2048, interpret=None) -> jnp.ndarray:
    """(m, ...) -> (m, m) fp32 full Gram (the recompute / oracle pass).

    The ref route contracts trailing axes in place; only the Pallas route
    flattens (a reshape of a sharded buffer would force an all-gather, and
    on TPU the kernel wants the flat layout anyway)."""
    if _route(interpret) == "ref":
        return ref.gram_ref(snapshots, anchor_first=anchor_first)
    m = snapshots.shape[0]
    flat = snapshots.reshape(m, -1)
    return gram_pallas(flat, anchor_first=anchor_first,
                       block_n=_block(block_n, flat.shape[1]),
                       interpret=_interp(interpret))


def gram_row(snapshots: jnp.ndarray, p: jnp.ndarray, *,
             anchor_first: bool = False, block_n: int = 2048,
             interpret=None) -> jnp.ndarray:
    """(m, ...), (...) -> (m,) streaming Gram row <d_p, d_j> (one O(m*n)
    pass; p is the snapshot just written into its buffer slot)."""
    if _route(interpret) == "ref":
        return ref.gram_row_ref(snapshots, p, anchor_first=anchor_first)
    m = snapshots.shape[0]
    flat = snapshots.reshape(m, -1)
    return gram_row_pallas(flat, p.reshape(-1), anchor_first=anchor_first,
                           block_n=_block(block_n, flat.shape[1]),
                           interpret=_interp(interpret))


def combine(snapshots: jnp.ndarray, c: jnp.ndarray, *, block_n: int = 2048,
            interpret=None) -> jnp.ndarray:
    """(m, ...), (m,) -> (...) = S^T c in fp32."""
    if _route(interpret) == "ref":
        return ref.combine_ref(snapshots, c)
    m = snapshots.shape[0]
    flat = snapshots.reshape(m, -1)
    out = combine_pallas(flat, c,
                         block_n=_block(block_n, flat.shape[1]),
                         interpret=_interp(interpret))
    return out.reshape(snapshots.shape[1:])


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    tq: int = 128, tk: int = 128, interpret=None):
    if _route(interpret) == "ref":
        heads, kv_heads = q.shape[2], k.shape[2]
        if kv_heads != heads:                    # the oracle has no GQA path
            k = jnp.repeat(k, heads // kv_heads, axis=2)
            v = jnp.repeat(v, heads // kv_heads, axis=2)
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  tq=tq, tk=tk, interpret=_interp(interpret))
