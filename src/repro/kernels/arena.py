"""Segmented DMD data passes over packed leaf arenas (DESIGN.md §7).

The per-leaf kernels (gram.py / gram_row.py / combine.py, plus their
shard_map wrappers in sharded.py) pay one launch PER LEAF per pass — a
transformer config with hundreds of DMD-managed leaves pays hundreds of tiny
dispatches per recorded step. An arena (core/arena.py) packs every
compatible leaf of a schedule group into ONE contiguous BLOCK-MAJOR
``(n_blocks, m, block_n)`` snapshot buffer: the lane axis is split into
``block_n``-lane blocks, each block carries all ``m`` snapshot rows of its
lanes contiguously, and every per-system segment is padded to a block
multiple so no block ever straddles two systems. The kernels here then walk
the whole arena in a single launch:

  * ``gram_row``  (nb, m, bn), (nb, bn)       -> (n_sys, m)    streaming rows
  * ``gram``      (nb, m, bn)                 -> (n_sys, m, m) full recompute
  * ``combine``   (nb, m, bn), (n_sys, m)     -> (N,)          the jump blend

Block-major is the load-bearing layout choice, on every backend at once:

  * CPU/GPU: the block axis is a LEADING batch dimension, so each pass is
    one batched ``dot_general`` that XLA lowers straight to the gemm/gemv
    library (batch dims must lead a batched contraction — with the old
    snapshot-major ``(m, N)`` layout the same contraction forced either a
    full-buffer transpose or a poorly-vectorized fused multiply-reduce,
    measured ~2.5x slower for the streaming row pass on a deep MLP).
  * TPU: the Pallas tile IS the storage tile — block ``i`` of the grid maps
    to ``x[i]`` with no re-tiling, and the (m_pad, block_n) VMEM tile keeps
    the lane axis on the 128-wide minor dimension.
  * The every-step resident record writes one ``(nb, 1, bn)`` slab per
    bucket (``dynamic_update_slice`` on the middle axis) — still a single
    fused op per bucket.

Segmentation is driven by a static ``block_sys`` table mapping each block
to its system index (a "system" = one independent DMD trajectory: an
unstacked leaf, or one stacked layer of a scan-stacked leaf). On TPU the
table rides in scalar-prefetch memory (``PrefetchScalarGridSpec``) and
indexes the OUTPUT BlockSpec: consecutive blocks of the same system revisit
the same (1, m)/(1, m, m) output tile, so the per-system reduction
accumulates in-place in VMEM with zero extra bandwidth — the classic
ragged/segmented grid pattern. The CPU/GPU reference route computes
per-block partials with one batched ``dot_general`` and reduces them with
one ``segment_sum`` — still a single fused XLA op chain, which is the whole
point: O(buckets) dispatches instead of O(leaves).

Padding is exact everywhere for the same reason as the flat kernels: tail
lanes of every segment are zero in the arena (core/arena.py packs them so),
zero lanes contribute zero to every inner product, and the anchor row's
padding is itself zero. The anchor subtraction stays fused: snapshot row 0
of every block IS that block's anchor slice, because all systems in a
bucket share one slot schedule (same group).

Sharded buckets (every leaf sharded over the SAME mesh axes on contracted
dims) reuse sharded.py's pattern: the same local kernels run per shard
under ``shard_map`` on the locally-packed arena (the BLOCK axis is sharded
— shard boundaries are always block boundaries because every shard's lane
count is a block_n multiple), followed by one O(n_sys·m²)/O(n_sys·m) psum
for the Gram passes; ``combine`` needs no collective at all.

Backend dispatch matches kernels/ops.py: compiled Pallas on TPU, the
reference route on CPU/GPU, explicit ``interpret=`` for the
kernel-vs-oracle contract tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def _m_pad(m: int) -> int:
    return max(-(-m // 8) * 8, 8)


# ---------------------------------------------------------------------------
# Reference route (CPU/GPU oracle): one batched dot_general + one
# segment_sum per pass, block axis leading
# ---------------------------------------------------------------------------

def gram_row_ref(x: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
                 anchor_first: bool = False, block_n: int) -> jnp.ndarray:
    """(nb, m, bn), (nb, bn) -> (n_sys, m) of <d_q, d_j> per system.

    Always contracts in fp32, exactly like the per-leaf kernel oracles
    (kernels/ref.py) and the per-tile upcast in the Pallas bodies — the
    upcast fuses into the contraction, so there is no reason to degrade
    bf16 storage further (cfg.gram_upcast only shapes the dot_general
    fallback route, which arenas never take).

    Anchoring uses the partials identity instead of materializing the
    anchored buffer: with qa = q - x0,

        <qa, x_j - x_0> = <qa, x_j> - <qa, x_0>

    so only q is anchored (one (nb, bn) subtract), the batched dot runs on
    the RAW buffer — one streaming read, no (nb, m, bn)-sized anchored
    temporary — and column 0 of the raw partials is subtracted afterwards.
    The identity is algebraic, so it is exact on the dyadic trajectories
    the route-equality pins use; under fp rounding it differs from
    explicit anchoring only by summation-order effects, inside the
    kernel-contract tolerances (the Pallas tile body anchors explicitly in
    VMEM, where the subtract costs no bandwidth)."""
    del block_n                         # implied by the block-major shape
    xf = x.astype(jnp.float32)          # (nb, m, bn)
    qf = q.astype(jnp.float32)          # (nb, bn)
    if anchor_first:
        qf = qf - xf[:, 0, :]
    part = jax.lax.dot_general(
        xf, qf, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # (nb, m)
    if anchor_first:
        part = part - part[:, 0:1]
    return jax.ops.segment_sum(part, jnp.asarray(block_sys),
                               num_segments=n_sys, indices_are_sorted=True)


def gram_ref(x: jnp.ndarray, block_sys, n_sys: int, *,
             anchor_first: bool = False, anchor_mean: bool = False,
             block_n: int) -> jnp.ndarray:
    """(nb, m, bn) -> (n_sys, m, m) full Grams, one per system (fp32
    contraction regardless of storage dtype — see gram_row_ref).

    ``anchor_mean`` subtracts the per-lane snapshot mean before the
    contraction (dmd.gram_matrix's mean path, fp32 like its upcast
    route). Pad lanes are zero, their mean is zero, so padding stays
    exact. Mutually exclusive with ``anchor_first``; mean buckets have
    no streaming row pass (dmd.gram_row_matrix rejects mean), so only
    this full-recompute kernel carries the flag. The once-per-rebuild
    pass anchors explicitly (an (nb, m, bn) fused subtract) — the m×m
    partials of the part-anchor identity don't pay for themselves here."""
    if anchor_first and anchor_mean:
        raise ValueError("anchor_first and anchor_mean are exclusive")
    del block_n
    xf = x.astype(jnp.float32)          # (nb, m, bn)
    if anchor_first:
        xf = xf - xf[:, 0:1, :]
    if anchor_mean:
        xf = xf - jnp.mean(xf, axis=1, keepdims=True)
    part = jax.lax.dot_general(
        xf, xf, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # (nb, m, m)
    return jax.ops.segment_sum(part, jnp.asarray(block_sys),
                               num_segments=n_sys, indices_are_sorted=True)


def combine_ref(x: jnp.ndarray, c: jnp.ndarray, block_sys, *,
                block_n: int) -> jnp.ndarray:
    """(nb, m, bn), (n_sys, m) -> (N,) = S^T c_sys per lane's own system.

    Always fp32, like the per-leaf ref.combine_ref — downcasting the
    coefficients to bf16 storage dtype would silently break the
    arena-vs-per-leaf oracle contract on gram_upcast=False configs
    (the per-leaf kernel route never does).

    A batched dot_general contracting the snapshot axis: same m-reduction
    order as the per-leaf tensordot, so the two routes stay BIT-identical
    whenever the coefficient solves agree (pinned by the
    integer-trajectory test). Block-major makes this a batch-leading
    gemv — no transpose at all, where the old (m, N) layout paid one per
    jump."""
    del block_n
    xf = x.astype(jnp.float32)                                # (nb, m, bn)
    cb = c.astype(jnp.float32)[jnp.asarray(block_sys)]        # (nb, m)
    out = jax.lax.dot_general(
        cb[:, None, :], xf, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)                   # (nb, 1, bn)
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Pallas TPU kernels: one launch per arena, the grid tile IS the storage
# tile x[i], output tile indexed by the prefetched block->system table,
# in-place accumulation across revisits
# ---------------------------------------------------------------------------

def _row_kernel(seg_ref, x_ref, q_ref, out_ref, *, anchor_first: bool):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0,
                           seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    x = x_ref[0].astype(jnp.float32)              # (m_pad, block_n)
    q = q_ref[...].astype(jnp.float32)            # (1, block_n)
    if anchor_first:
        q = q - x[0:1, :]
        x = x - x[0:1, :]
    part = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, m_pad)

    @pl.when(first)
    def _init():
        out_ref[...] = part

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("n_sys", "anchor_first",
                                             "block_n", "interpret"))
def gram_row_pallas(x: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
                    anchor_first: bool = False, block_n: int,
                    interpret: bool = True) -> jnp.ndarray:
    nb, m, _ = x.shape
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, 0)))
    grid = (nb,)
    out = pl.pallas_call(
        functools.partial(_row_kernel, anchor_first=anchor_first),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, mp, block_n), lambda i, s: (i, 0, 0)),
                      pl.BlockSpec((1, block_n), lambda i, s: (i, 0))],
            out_specs=pl.BlockSpec((1, mp), lambda i, s: (s[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_sys, mp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), x, q)
    return out[:, :m]


def _gram_kernel(seg_ref, x_ref, out_ref, *, anchor_first: bool,
                 m_real: int):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0,
                           seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    x = x_ref[0].astype(jnp.float32)              # (m_pad, block_n)
    if anchor_first:
        x = x - x[0:1, :]
    if m_real > 0:
        # mean anchoring: pad rows are zero so sum/m_real is the exact
        # per-lane mean; subtracting it contaminates only the pad rows,
        # whose Gram entries land at indices >= m and are sliced away.
        x = x - jnp.sum(x, axis=0, keepdims=True) / m_real
    part = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)[None]  # (1, m_pad, m_pad)

    @pl.when(first)
    def _init():
        out_ref[...] = part

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("n_sys", "anchor_first",
                                             "anchor_mean", "block_n",
                                             "interpret"))
def gram_pallas(x: jnp.ndarray, block_sys, n_sys: int, *,
                anchor_first: bool = False, anchor_mean: bool = False,
                block_n: int, interpret: bool = True) -> jnp.ndarray:
    if anchor_first and anchor_mean:
        raise ValueError("anchor_first and anchor_mean are exclusive")
    nb, m, _ = x.shape
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, 0)))
    grid = (nb,)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, anchor_first=anchor_first,
                          m_real=m if anchor_mean else 0),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, mp, block_n),
                                   lambda i, s: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, mp, mp), lambda i, s: (s[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_sys, mp, mp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), x)
    return out[:, :m, :m]


def _combine_kernel(seg_ref, c_ref, x_ref, out_ref):
    del seg_ref                                   # consumed by the index maps
    x = x_ref[0].astype(jnp.float32)              # (m_pad, block_n)
    c = c_ref[...].astype(jnp.float32)            # (1, m_pad)
    out_ref[...] = jax.lax.dot_general(
        c, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, block_n)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def combine_pallas(x: jnp.ndarray, c: jnp.ndarray, block_sys, *,
                   block_n: int, interpret: bool = True) -> jnp.ndarray:
    nb, m, _ = x.shape
    n = nb * block_n
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, 0), (0, mp - m), (0, 0)))
        c = jnp.pad(c.astype(jnp.float32), ((0, 0), (0, mp - m)))
    grid = (nb,)
    out = pl.pallas_call(
        _combine_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, mp), lambda i, s: (s[i], 0)),
                      pl.BlockSpec((1, mp, block_n),
                                   lambda i, s: (i, 0, 0))],
            out_specs=pl.BlockSpec((1, block_n), lambda i, s: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), c.astype(jnp.float32), x)
    return out[0]


# ---------------------------------------------------------------------------
# Dispatch (kernels/ops.py contract) + shard_map wrappers for sharded buckets
# ---------------------------------------------------------------------------

def _local_gram_row(x, q, block_sys, n_sys, anchor_first, block_n,
                    interpret):
    if ops._route(interpret) == "ref":
        return gram_row_ref(x, q, block_sys, n_sys,
                            anchor_first=anchor_first, block_n=block_n)
    return gram_row_pallas(x, q, block_sys, n_sys, anchor_first=anchor_first,
                           block_n=block_n, interpret=ops._interp(interpret))


def _local_gram(x, block_sys, n_sys, anchor_first, anchor_mean, block_n,
                interpret):
    if ops._route(interpret) == "ref":
        return gram_ref(x, block_sys, n_sys, anchor_first=anchor_first,
                        anchor_mean=anchor_mean, block_n=block_n)
    return gram_pallas(x, block_sys, n_sys, anchor_first=anchor_first,
                       anchor_mean=anchor_mean, block_n=block_n,
                       interpret=ops._interp(interpret))


def _local_combine(x, c, block_sys, block_n, interpret):
    if ops._route(interpret) == "ref":
        return combine_ref(x, c, block_sys, block_n=block_n)
    return combine_pallas(x, c, block_sys, block_n=block_n,
                          interpret=ops._interp(interpret))


def shard_wrap(mesh, lane_axes: Tuple[str, ...], fn, in_specs, out_specs):
    """sharded.py's shard_map pattern: no mesh / no sharded lanes -> the
    local computation IS the global one; otherwise run per shard. The ONE
    home of the arena shard_map contract — core/arena.py's pack/unpack
    wraps through this too, so the kernel path and the data-layout path
    can never diverge."""
    if mesh is None or not lane_axes:
        return fn
    from repro.distributed.sharding import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def lane_spec(lane_axes: Tuple[str, ...]) -> P:
    """PartitionSpec of an arena's FLAT 1-D lane axis — the leaf-wise
    pack/unpack rows and the combine output (shared with core/arena.py's
    ArenaBucket.lane_spec). Block-major SNAPSHOT buffers shard the same
    mesh axes over their leading block axis instead: see buf_spec."""
    return P(lane_axes if len(lane_axes) > 1 else
             (lane_axes[0] if lane_axes else None))


def _axis_entry(axes: Tuple[str, ...]):
    """One PartitionSpec entry for a (possibly multi-axis) mesh axis set."""
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def buf_spec(axes: Tuple[str, ...]) -> P:
    """PartitionSpec of a block-major (n_blocks, m, block_n) snapshot
    buffer: the mesh axes that sharded the old flat lane axis shard the
    leading BLOCK axis (every shard's lane count is a block_n multiple,
    so shard boundaries are always block boundaries and the global
    (N,) -> (nb, bn) reshape splits the sharded dim divisibly)."""
    return P(_axis_entry(axes), None, None)


def gram_row(buf: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
             anchor_first: bool = False, block_n: int,
             mesh=None, lane_axes: Tuple[str, ...] = (),
             sys_axes: Tuple[str, ...] = (),
             interpret=None) -> jnp.ndarray:
    """One streaming Gram row per system, ONE launch for the whole arena.
    ``buf`` is block-major (nb, m, bn) and ``q`` its blocked query row
    (nb, bn). ``block_sys`` is the (shard-local) block->system table and
    ``n_sys`` the shard-LOCAL system count. Lane-sharded buckets
    (``lane_axes``) run per shard + one O(n_sys·m) psum; system-sharded
    buckets (``sys_axes`` — a scan-stacked leaf whose stacked dim is
    sharded) need NO collective: each shard owns whole systems, and the
    output stays sharded over its system axis."""
    axes = sys_axes + lane_axes

    def local(x, qq):
        r = _local_gram_row(x, qq, block_sys, n_sys, anchor_first, block_n,
                            interpret)
        return jax.lax.psum(r, lane_axes) if lane_axes else r

    return shard_wrap(mesh, axes, local,
                 (buf_spec(axes), P(_axis_entry(axes), None)),
                 P(_axis_entry(sys_axes), None))(buf, q)


def gram(buf: jnp.ndarray, block_sys, n_sys: int, *,
         anchor_first: bool = False, anchor_mean: bool = False,
         block_n: int, mesh=None, lane_axes: Tuple[str, ...] = (),
         sys_axes: Tuple[str, ...] = (),
         interpret=None) -> jnp.ndarray:
    """Full (n_sys, m, m) Gram recompute, ONE launch + one O(n_sys·m²) psum
    over the lane axes (the non-streaming A/B path and the
    restore-staleness rebuild). System-sharded outputs stay sharded."""
    axes = sys_axes + lane_axes

    def local(x):
        g = _local_gram(x, block_sys, n_sys, anchor_first, anchor_mean,
                        block_n, interpret)
        return jax.lax.psum(g, lane_axes) if lane_axes else g

    return shard_wrap(mesh, axes, local,
                 (buf_spec(axes),),
                 P(_axis_entry(sys_axes), None, None))(buf)


def combine(buf: jnp.ndarray, c: jnp.ndarray, block_sys, *,
            block_n: int, mesh=None,
            lane_axes: Tuple[str, ...] = (),
            sys_axes: Tuple[str, ...] = (), interpret=None) -> jnp.ndarray:
    """(N,) fp32 jump blend, ONE launch, zero collectives: c is replicated
    over the lane axes (sharded over the system axes, matching the Gram
    stack) and every block contracts only its own system's replicated
    snapshot axis, so the flat output inherits the arena's lane sharding."""
    axes = sys_axes + lane_axes

    def local(x, cc):
        return _local_combine(x, cc, block_sys, block_n, interpret)

    ls = lane_spec(axes)
    return shard_wrap(mesh, axes, local,
                 (buf_spec(axes), P(_axis_entry(sys_axes), None)),
                 ls)(buf, c)
