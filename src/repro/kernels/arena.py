"""Segmented DMD data passes over packed leaf arenas (DESIGN.md §7).

The per-leaf kernels (gram.py / gram_row.py / combine.py, plus their
shard_map wrappers in sharded.py) pay one launch PER LEAF per pass — a
transformer config with hundreds of DMD-managed leaves pays hundreds of tiny
dispatches per recorded step. An arena (core/arena.py) packs every
compatible leaf of a schedule group into ONE contiguous (m, N) buffer whose
lane axis is split into per-system segments, each padded to a multiple of
the bucket's ``block_n`` so no kernel block ever straddles two systems.
The kernels here then walk the whole arena in a single launch:

  * ``gram_row``  (m, N), (N,)        -> (n_sys, m)    streaming rows
  * ``gram``      (m, N)              -> (n_sys, m, m) full recompute
  * ``combine``   (m, N), (n_sys, m)  -> (N,)          the jump blend

Segmentation is driven by a static ``block_sys`` table mapping each
``block_n``-lane block to its system index (a "system" = one independent
DMD trajectory: an unstacked leaf, or one stacked layer of a scan-stacked
leaf). On TPU the table rides in scalar-prefetch memory
(``PrefetchScalarGridSpec``) and indexes the OUTPUT BlockSpec: consecutive
blocks of the same system revisit the same (1, m)/(1, m, m) output tile, so
the per-system reduction accumulates in-place in VMEM with zero extra
bandwidth — the classic ragged/segmented grid pattern. The CPU/GPU
reference route computes per-block partials with one batched ``einsum`` and
reduces them with one ``segment_sum`` — still a single fused XLA op chain,
which is the whole point: O(buckets) dispatches instead of O(leaves).

Padding is exact everywhere for the same reason as the flat kernels: tail
lanes of every segment are zero in the arena (core/arena.py packs them so),
zero lanes contribute zero to every inner product, and the anchor row's
padding is itself zero. The anchor subtraction stays fused: arena row 0 IS
the concatenation of every system's anchor slice, because all systems in a
bucket share one slot schedule (same group).

Sharded buckets (every leaf sharded over the SAME mesh axes on contracted
dims) reuse sharded.py's pattern: the same local kernels run per shard
under ``shard_map`` on the locally-packed arena (the lane axis is sharded
so each device holds its own segments), followed by one O(n_sys·m²)/O(n_sys·m)
psum for the Gram passes; ``combine`` needs no collective at all.

Backend dispatch matches kernels/ops.py: compiled Pallas on TPU, the
reference route on CPU/GPU, explicit ``interpret=`` for the
kernel-vs-oracle contract tests.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
from jax.sharding import PartitionSpec as P

from repro.kernels import ops


def _m_pad(m: int) -> int:
    return max(-(-m // 8) * 8, 8)


# ---------------------------------------------------------------------------
# Reference route (CPU/GPU oracle): one einsum + one segment_sum per pass
# ---------------------------------------------------------------------------

def _blocked(x: jnp.ndarray, block_n: int) -> jnp.ndarray:
    """(m, N) -> (m, nb, block_n) upcast to fp32."""
    m, n = x.shape
    return x.astype(jnp.float32).reshape(m, n // block_n, block_n)


def gram_row_ref(x: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
                 anchor_first: bool = False, block_n: int) -> jnp.ndarray:
    """(m, N), (N,) -> (n_sys, m) of <d_q, d_j> per system.

    Always contracts in fp32, exactly like the per-leaf kernel oracles
    (kernels/ref.py) and the per-tile upcast in the Pallas bodies — the
    blocked form never materializes an HBM-sized fp32 copy, so there is
    no reason to degrade bf16 storage further (cfg.gram_upcast only
    shapes the dot_general fallback route, which arenas never take).

    Per-block partials via a fused multiply-reduce rather than a batched
    dot_general: XLA requires batch dims to LEAD a batched contraction, so
    the einsum form transposes the whole (m, N) buffer (measured 2x record
    wall on a deep MLP); the broadcast-multiply + lane-axis reduce fuses
    into one read of the buffer with no transpose."""
    xf = x.astype(jnp.float32)
    qf = q.astype(jnp.float32)
    if anchor_first:
        qf = qf - xf[0]
        xf = xf - xf[:1]
    m, n = xf.shape
    xb = xf.reshape(m, n // block_n, block_n)
    qb = qf.reshape(n // block_n, block_n)
    part = jnp.sum(xb * qb[None], axis=-1)                    # (m, nb)
    return jax.ops.segment_sum(part.T, jnp.asarray(block_sys),
                               num_segments=n_sys, indices_are_sorted=True)


def gram_ref(x: jnp.ndarray, block_sys, n_sys: int, *,
             anchor_first: bool = False, block_n: int) -> jnp.ndarray:
    """(m, N) -> (n_sys, m, m) full Grams, one per system (fp32
    contraction regardless of storage dtype — see gram_row_ref)."""
    xf = x.astype(jnp.float32)
    if anchor_first:
        xf = xf - xf[:1]
    m, n = xf.shape
    xb = xf.reshape(m, n // block_n, block_n)
    part = jnp.einsum("mnb,knb->nmk", xb, xb,
                      preferred_element_type=jnp.float32)     # (nb, m, m)
    return jax.ops.segment_sum(part, jnp.asarray(block_sys),
                               num_segments=n_sys, indices_are_sorted=True)


def combine_ref(x: jnp.ndarray, c: jnp.ndarray, block_sys, *,
                block_n: int) -> jnp.ndarray:
    """(m, N), (n_sys, m) -> (N,) = S^T c_sys per lane's own system.

    Always fp32, like the per-leaf ref.combine_ref — downcasting the
    coefficients to bf16 storage dtype would silently break the
    arena-vs-per-leaf oracle contract on gram_upcast=False configs
    (the per-leaf kernel route never does).

    Deliberately a batched dot_general (NOT the multiply-reduce trick
    gram_row_ref uses): contracting the snapshot axis through a dot keeps
    the same m-reduction order as the per-leaf tensordot, so the two
    routes stay BIT-identical whenever the coefficient solves agree
    (pinned by the integer-trajectory test). The transpose this forces is
    paid once per window — the combine is the jump's pass, not the
    every-step pass."""
    xb = _blocked(x, block_n)
    cb = c.astype(jnp.float32)[jnp.asarray(block_sys)]        # (nb, m)
    out = jnp.einsum("nm,mnb->nb", cb, xb,
                     preferred_element_type=jnp.float32)
    return out.reshape(-1)


# ---------------------------------------------------------------------------
# Pallas TPU kernels: one launch per arena, output tile indexed by the
# prefetched block->system table, in-place accumulation across revisits
# ---------------------------------------------------------------------------

def _row_kernel(seg_ref, x_ref, q_ref, out_ref, *, anchor_first: bool):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0,
                           seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    q = q_ref[...].astype(jnp.float32)            # (1, block_n)
    if anchor_first:
        q = q - x[0:1, :]
        x = x - x[0:1, :]
    part = jax.lax.dot_general(
        q, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, m_pad)

    @pl.when(first)
    def _init():
        out_ref[...] = part

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("n_sys", "anchor_first",
                                             "block_n", "interpret"))
def gram_row_pallas(x: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
                    anchor_first: bool = False, block_n: int,
                    interpret: bool = True) -> jnp.ndarray:
    m, n = x.shape
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_row_kernel, anchor_first=anchor_first),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((mp, block_n), lambda i, s: (0, i)),
                      pl.BlockSpec((1, block_n), lambda i, s: (0, i))],
            out_specs=pl.BlockSpec((1, mp), lambda i, s: (s[i], 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_sys, mp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), x, q.reshape(1, n))
    return out[:, :m]


def _gram_kernel(seg_ref, x_ref, out_ref, *, anchor_first: bool):
    i = pl.program_id(0)
    first = jnp.logical_or(i == 0,
                           seg_ref[i] != seg_ref[jnp.maximum(i - 1, 0)])
    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    if anchor_first:
        x = x - x[0:1, :]
    part = jax.lax.dot_general(
        x, x, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)[None]  # (1, m_pad, m_pad)

    @pl.when(first)
    def _init():
        out_ref[...] = part

    @pl.when(jnp.logical_not(first))
    def _acc():
        out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("n_sys", "anchor_first",
                                             "block_n", "interpret"))
def gram_pallas(x: jnp.ndarray, block_sys, n_sys: int, *,
                anchor_first: bool = False, block_n: int,
                interpret: bool = True) -> jnp.ndarray:
    m, n = x.shape
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    grid = (n // block_n,)
    out = pl.pallas_call(
        functools.partial(_gram_kernel, anchor_first=anchor_first),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((mp, block_n), lambda i, s: (0, i))],
            out_specs=pl.BlockSpec((1, mp, mp), lambda i, s: (s[i], 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_sys, mp, mp), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), x)
    return out[:, :m, :m]


def _combine_kernel(seg_ref, c_ref, x_ref, out_ref):
    del seg_ref                                   # consumed by the index maps
    x = x_ref[...].astype(jnp.float32)            # (m_pad, block_n)
    c = c_ref[...].astype(jnp.float32)            # (1, m_pad)
    out_ref[...] = jax.lax.dot_general(
        c, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)        # (1, block_n)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def combine_pallas(x: jnp.ndarray, c: jnp.ndarray, block_sys, *,
                   block_n: int, interpret: bool = True) -> jnp.ndarray:
    m, n = x.shape
    mp = _m_pad(m)
    if mp != m:
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
        c = jnp.pad(c.astype(jnp.float32), ((0, 0), (0, mp - m)))
    grid = (n // block_n,)
    out = pl.pallas_call(
        _combine_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[pl.BlockSpec((1, mp), lambda i, s: (s[i], 0)),
                      pl.BlockSpec((mp, block_n), lambda i, s: (0, i))],
            out_specs=pl.BlockSpec((1, block_n), lambda i, s: (0, i)),
        ),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(block_sys, jnp.int32), c.astype(jnp.float32), x)
    return out[0]


# ---------------------------------------------------------------------------
# Dispatch (kernels/ops.py contract) + shard_map wrappers for sharded buckets
# ---------------------------------------------------------------------------

def _local_gram_row(x, q, block_sys, n_sys, anchor_first, block_n,
                    interpret):
    if ops._route(interpret) == "ref":
        return gram_row_ref(x, q, block_sys, n_sys,
                            anchor_first=anchor_first, block_n=block_n)
    return gram_row_pallas(x, q, block_sys, n_sys, anchor_first=anchor_first,
                           block_n=block_n, interpret=ops._interp(interpret))


def _local_gram(x, block_sys, n_sys, anchor_first, block_n, interpret):
    if ops._route(interpret) == "ref":
        return gram_ref(x, block_sys, n_sys, anchor_first=anchor_first,
                        block_n=block_n)
    return gram_pallas(x, block_sys, n_sys, anchor_first=anchor_first,
                       block_n=block_n, interpret=ops._interp(interpret))


def _local_combine(x, c, block_sys, block_n, interpret):
    if ops._route(interpret) == "ref":
        return combine_ref(x, c, block_sys, block_n=block_n)
    return combine_pallas(x, c, block_sys, block_n=block_n,
                          interpret=ops._interp(interpret))


def shard_wrap(mesh, lane_axes: Tuple[str, ...], fn, in_specs, out_specs):
    """sharded.py's shard_map pattern: no mesh / no sharded lanes -> the
    local computation IS the global one; otherwise run per shard. The ONE
    home of the arena shard_map contract — core/arena.py's pack/unpack
    wraps through this too, so the kernel path and the data-layout path
    can never diverge."""
    if mesh is None or not lane_axes:
        return fn
    from repro.distributed.sharding import shard_map
    return shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def lane_spec(lane_axes: Tuple[str, ...]) -> P:
    """PartitionSpec of an arena's 1-D lane axis (shared with
    core/arena.py's ArenaBucket.lane_spec)."""
    return P(lane_axes if len(lane_axes) > 1 else
             (lane_axes[0] if lane_axes else None))


def gram_row(buf: jnp.ndarray, q: jnp.ndarray, block_sys, n_sys: int, *,
             anchor_first: bool = False, block_n: int,
             mesh=None, lane_axes: Tuple[str, ...] = (),
             interpret=None) -> jnp.ndarray:
    """One streaming Gram row per system, ONE launch for the whole arena.
    ``block_sys`` is the (shard-local) block->system table. Sharded buckets
    (``lane_axes`` non-empty) run per shard + one O(n_sys·m) psum."""

    def local(x, qq):
        r = _local_gram_row(x, qq, block_sys, n_sys, anchor_first, block_n,
                            interpret)
        return jax.lax.psum(r, lane_axes) if lane_axes else r

    ls = lane_spec(lane_axes)
    return shard_wrap(mesh, lane_axes, local,
                 (P(None, *tuple(ls)), ls), P(None, None))(buf, q)


def gram(buf: jnp.ndarray, block_sys, n_sys: int, *,
         anchor_first: bool = False, block_n: int,
         mesh=None, lane_axes: Tuple[str, ...] = (),
         interpret=None) -> jnp.ndarray:
    """Full (n_sys, m, m) Gram recompute, ONE launch + one O(n_sys·m²) psum
    (the non-streaming A/B path and the restore-staleness rebuild)."""

    def local(x):
        g = _local_gram(x, block_sys, n_sys, anchor_first, block_n,
                        interpret)
        return jax.lax.psum(g, lane_axes) if lane_axes else g

    ls = lane_spec(lane_axes)
    return shard_wrap(mesh, lane_axes, local,
                 (P(None, *tuple(ls)),), P(None, None, None))(buf)


def combine(buf: jnp.ndarray, c: jnp.ndarray, block_sys, *,
            block_n: int, mesh=None,
            lane_axes: Tuple[str, ...] = (), interpret=None) -> jnp.ndarray:
    """(N,) fp32 jump blend, ONE launch, zero collectives: c is replicated
    and every lane contracts only its own system's replicated snapshot
    axis, so the output inherits the arena's lane sharding."""

    def local(x, cc):
        return _local_combine(x, cc, block_sys, block_n, interpret)

    ls = lane_spec(lane_axes)
    return shard_wrap(mesh, lane_axes, local,
                 (P(None, *tuple(ls)), P(None, None)), ls)(buf, c)
