"""Pallas TPU kernel: flash attention forward (causal / sliding-window).

The serving/long-context hot spot for the assigned LM architectures. Online
softmax over k-blocks: grid (B, H, nQ, nK) with the (TQ, d) accumulator and
(TQ,) running max/sum in VMEM scratch carried across the nK axis (the
innermost, sequential grid dim). Causal/window blocks that are fully masked
are skipped via pl.when — block-level sparsity, the flash-2 schedule.

Layout: q/k/v as (B, H, S, d) (head-major so the (S, d) tile is MXU-aligned;
d padded to 128 lanes by the wrapper, TQ/TK multiples of the 8-row sublane).
GQA is handled by the wrapper (kv head index = q head // rep).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  causal: bool, window: int, tq: int, tk: int, sk: int,
                  d_true: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * tq
    k_start = ki * tk
    # block-level skip: no k in this block can be visible to any q here
    visible = True
    if causal:
        visible = q_start + tq - 1 >= k_start
    if window:
        visible = visible & (k_start + tk - 1 > q_start - window)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (TQ, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (TK, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= 1.0 / (d_true ** 0.5)   # true head dim, not the 128-padded one
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
        rel = q_pos - k_pos
        mask = k_pos < sk
        if causal:
            mask &= rel >= 0
        if window:
            mask &= rel < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)      # (TQ, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...]
                         / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "tq", "tk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           tq: int = 128, tk: int = 128,
                           interpret: bool = True) -> jnp.ndarray:
    """q: (B, Sq, H, d); k/v: (B, Sk, K, d), H % K == 0. Returns (B,Sq,H,d).

    Pads Sq/Sk to tile multiples and d to 128; GQA handled by indexing the
    kv head for each q head block.
    """
    B, Sq, H, d = q.shape
    Sk, K = k.shape[1], k.shape[2]
    rep = H // K
    d_pad = max(-(-d // 128) * 128, 128)
    sq_pad = -(-Sq // tq) * tq
    sk_pad = -(-Sk // tk) * tk

    def pad_to(x, s_pad):
        return jnp.pad(x, ((0, 0), (0, s_pad - x.shape[1]), (0, 0),
                           (0, d_pad - d)))

    qh = pad_to(q, sq_pad).transpose(0, 2, 1, 3)        # (B, H, Sq, d)
    kh = pad_to(k, sk_pad).transpose(0, 2, 1, 3)        # (B, K, Sk, d)
    vh = pad_to(v, sk_pad).transpose(0, 2, 1, 3)

    grid = (B, H, sq_pad // tq, sk_pad // tk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, causal=causal, window=window,
                          tq=tq, tk=tk, sk=Sk, d_true=d),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, tq, d_pad),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, tk, d_pad),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
            pl.BlockSpec((1, 1, tk, d_pad),
                         lambda b, h, qi, ki, rep=rep: (b, h // rep, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, tq, d_pad),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, sq_pad, d_pad), q.dtype),
        scratch_shapes=[pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, d_pad), jnp.float32)],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)[:, :Sq, :, :d]
