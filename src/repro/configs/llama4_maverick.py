"""Llama4-Maverick-400B-A17B [hf:meta-llama/Llama-4]: 48L d=5120 40H kv=8
hd=128 vocab=202048; MoE 128 experts top-1 + shared expert (d_ff 8192),
interleaved 1:1 with dense layers (d_ff 16384) => ~400B total / ~17B active.
DMD param_filter="non_expert": top-1 expert trajectories are sparse/
incoherent AND m x 386B of snapshots cannot fit — DESIGN.md §4.
Optimizer=adafactor (factored second moment) so state fits 16 GB/chip.
40 heads not divisible by tp=16 -> kv-SP attention."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=16384,
        vocab_size=202048, act="silu", norm="rms", rope_theta=5e5,
        tie_embeddings=False, max_seq_len=32768,
        moe=MoEConfig(n_experts=128, top_k=1, expert_d_ff=8192,
                      n_shared_experts=1, shared_d_ff=8192, moe_every=2,
                      capacity_factor=1.25))
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=8, s=40, snapshot_dtype="bfloat16",
                      param_filter="non_expert", warmup_steps=200),
        optimizer=OptimizerConfig(name="adafactor", lr=2e-4, b2=0.99,
                                  grad_clip=1.0, schedule="cosine",
                                  warmup_steps=500, total_steps=20000),
        parallel=ParallelConfig(grad_accum=8, remat="block",      # §Perf it.2
                                pad_attn_heads_to=16),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (quadratic).")
