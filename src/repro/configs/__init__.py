"""Config registry: ``get_config("<arch-id>")`` for every assigned arch."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401  (config facade)
    ArchConfig, DMDConfig, DMDControllerConfig, ModelConfig, MoEConfig,
    OptimizerConfig, ParallelConfig, SSMConfig, ShapeConfig, TrainConfig,
    STANDARD_SHAPES, reduced,
)

_ARCH_MODULES: Dict[str, str] = {
    "minicpm-2b": "repro.configs.minicpm_2b",
    "granite-20b": "repro.configs.granite_20b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
    "whisper-base": "repro.configs.whisper_base",
    "qwen2-vl-7b": "repro.configs.qwen2_vl_7b",
    "zamba2-2.7b": "repro.configs.zamba2_2_7b",
    "mamba2-2.7b": "repro.configs.mamba2_2_7b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe",
    "pollutant-mlp": "repro.configs.pollutant_mlp",
}


def list_archs() -> List[str]:
    return [k for k in _ARCH_MODULES if k != "pollutant-mlp"]


def get_config(name: str) -> ArchConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(_ARCH_MODULES[name])
    return mod.get_config()


def shape_by_name(name: str) -> ShapeConfig:
    for s in STANDARD_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


__all__ = [
    "ArchConfig", "DMDConfig", "ModelConfig", "MoEConfig", "OptimizerConfig",
    "ParallelConfig", "SSMConfig", "ShapeConfig", "TrainConfig",
    "STANDARD_SHAPES", "get_config", "list_archs", "shape_by_name", "reduced",
]
