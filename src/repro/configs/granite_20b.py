"""Granite-20B-code [arXiv:2405.04324]: 52L d=6144 48H MQA(kv=1) d_ff=24576
vocab=49152, non-gated GELU MLP (GPT-BigCode lineage; the gated variant
would be 28B — param count pins it). MQA: the single kv head is replicated
across TP; decode KV is sequence-sharded (flash-decoding combine)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="granite-20b", family="dense", n_layers=52, d_model=6144,
        n_heads=48, n_kv_heads=1, head_dim=128, d_ff=24576, vocab_size=49152,
        act="gelu_mlp", norm="rms", tie_embeddings=False,
        max_seq_len=32768)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=8, s=40, snapshot_dtype="bfloat16", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=2e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=16, remat="block"),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (MQA shrinks the "
                   "KV but attention is still full).")
