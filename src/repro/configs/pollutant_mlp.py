"""The paper's own experiment: softsign MLP 6 -> 40 -> 200 -> 1000 -> 2670
(~2.9M params) predicting the pollutant concentration field at 2670 spatial
points from 6 uncertain parameters (K12, K3, D, U0, uh, uv). Paper
hyperparameters: Adam, 3000 epochs full-batch, DMD m=14 s=55 tol=1e-10."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig, TrainConfig)

PAPER_SIZES = (6, 40, 200, 1000, 2670)


def get_config() -> ArchConfig:
    model = ModelConfig(name="pollutant-mlp", family="mlp", act="softsign")
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, tol=1e-10, warmup_steps=0,
                      cooldown_steps=0, anchor="none", affine=False,
                      trust_region=0.0, mode="eig", reset_opt_state=False,
                      snapshot_dtype="float32"),
        optimizer=OptimizerConfig(name="adam", lr=1e-3),
        parallel=ParallelConfig(),
        train=TrainConfig(steps=3000),
        shapes=())
