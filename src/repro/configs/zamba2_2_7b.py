"""Zamba2-2.7B [arXiv:2411.15242]: 54 Mamba-2 layers d=2560 (d_inner=5120,
H=80, P=64, N=64) + ONE shared attention+MLP block invoked every 6 layers
(pure weight sharing; the per-invocation LoRA of the paper is simplified
away — DESIGN.md §9). attn 32H MHA hd=80, d_ff=10240. Runs long_500k
(SSM state is O(1); shared attn blocks use full KV, 9 invocations)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig, SSMConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
        n_heads=32, n_kv_heads=32, head_dim=80, d_ff=10240, vocab_size=32000,
        act="silu", norm="rms", shared_attn_every=6, tie_embeddings=True,
        max_seq_len=524288,
        ssm=SSMConfig(state_dim=64, head_dim=64, conv_width=4, expand=2,
                      n_groups=1, chunk=256))
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, snapshot_dtype="bfloat16", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=8, remat="block"),
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"))
