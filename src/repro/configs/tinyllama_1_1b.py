"""TinyLlama-1.1B [arXiv:2401.02385]: 22L d=2048 32H kv=4 d_ff=5632
vocab=32000 (llama2 arch)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
        n_heads=32, n_kv_heads=4, head_dim=64, d_ff=5632, vocab_size=32000,
        act="silu", norm="rms", tie_embeddings=False, max_seq_len=32768)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=4e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=4, remat="block"),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (quadratic).")
