"""MiniCPM-2B [arXiv:2404.06395]: 40L d=2304 36H MHA d_ff=5760 vocab=122753,
WSD schedule, tied embeddings. 36 heads is not divisible by tp=16 -> kv-SP
attention layout (see models/attention.py)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="minicpm-2b", family="dense", n_layers=40, d_model=2304,
        n_heads=36, n_kv_heads=36, head_dim=64, d_ff=5760, vocab_size=122753,
        act="silu", norm="rms", tie_embeddings=True, max_seq_len=32768)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, snapshot_dtype="bfloat16"),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="wsd", warmup_steps=200,
                                  total_steps=10000, decay_fraction=0.1),
        parallel=ParallelConfig(grad_accum=8, remat="block",
                                pad_attn_heads_to=16),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (quadratic).")
