"""Qwen2-VL-7B [arXiv:2409.12191]: 28L d=3584 28H kv=4 d_ff=18944
vocab=152064, M-RoPE (t/h/w sections 16/24/24 of head_dim/2=64). Vision
tower is a STUB: the backbone consumes token ids + (B,3,S) M-RoPE position
ids from input_specs. 28 heads not divisible by tp=16 -> kv-SP attention."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
        n_heads=28, n_kv_heads=4, head_dim=128, d_ff=18944,
        vocab_size=152064, act="silu", norm="rms", rope_theta=1e6,
        mrope_sections=(16, 24, 24), frontend_stub=True,
        tie_embeddings=False, max_seq_len=32768)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=10, s=40, snapshot_dtype="bfloat16", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=2e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=8, remat="block",
                                pad_attn_heads_to=16),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (quadratic).")
