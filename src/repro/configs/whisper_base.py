"""Whisper-base [arXiv:2212.04356]: enc-dec, 6+6L d=512 8H d_ff=2048
vocab=51865, LayerNorm + GELU + learned positions. Conv frontend is a STUB:
input_specs supplies precomputed (B, 1500, 512) frame embeddings.
max_seq_len raised to 32768 so the assigned decode_32k cell is well-defined
(real whisper caps decoder context at 448 — documented deviation)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="whisper-base", family="encdec", n_layers=6, n_encoder_layers=6,
        d_model=512, n_heads=8, n_kv_heads=8, head_dim=64, d_ff=2048,
        vocab_size=51865, act="gelu_mlp", norm="ln", learned_pos_emb=True,
        encoder_seq_len=1500, frontend_stub=True, tie_embeddings=True,
        max_seq_len=32768)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, warmup_steps=100),
        optimizer=OptimizerConfig(name="adamw", lr=1e-3, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=100,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=1, remat="none",
                                pad_attn_heads_to=16),
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: enc-dec with full attention; 8 heads "
                   "< tp=16 -> kv-SP attention layout.")
