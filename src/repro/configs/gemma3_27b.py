"""Gemma3-27B [hf:google/gemma-3]: 62L d=5376 32H kv=16 d_ff=21504
vocab=262144, 5:1 local(window 1024):global. 62 = 10x(5 local + 1 global)
+ 2 local tail. Runs long_500k: local layers use O(window) ring KV caches;
global layers sequence-shard their KV."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
        n_heads=32, n_kv_heads=16, head_dim=128, d_ff=21504,
        vocab_size=262144, act="gelu", norm="rms", rope_theta=1e6,
        sliding_window=1024, global_every=6, tie_embeddings=True,
        max_seq_len=524288)
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=8, s=40, snapshot_dtype="bfloat16", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=2e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=16, remat="block"),
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"))
