"""Mamba2-2.7B [arXiv:2405.21060]: 64L d=2560 attention-free SSD
(d_inner=5120, H=80, P=64, N=128, chunk=256), vocab=50280. Runs long_500k
(O(1) decode state)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig,
                                OptimizerConfig, ParallelConfig, SSMConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
        n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
        act="silu", norm="rms", tie_embeddings=True, max_seq_len=524288,
        ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2,
                      n_groups=1, chunk=256))
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=14, s=55, snapshot_dtype="bfloat16", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=8, remat="block"),
        shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"))
