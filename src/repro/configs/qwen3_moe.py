"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H kv=4 hd=128
vocab=151936; MoE 128 experts top-8, expert d_ff=768, every layer MoE.
Top-8 routing gives dense-enough expert update trajectories that DMD covers
ALL params here (param_filter='all', bf16 snapshots) — the MoE-DMD showcase
cell (most representative of the paper's technique at scale)."""
from repro.configs.base import (ArchConfig, DMDConfig, ModelConfig, MoEConfig,
                                OptimizerConfig, ParallelConfig)


def get_config() -> ArchConfig:
    model = ModelConfig(
        name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, head_dim=128, d_ff=768, vocab_size=151936,
        act="silu", norm="rms", rope_theta=1e6, tie_embeddings=False,
        max_seq_len=32768,
        moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=768,
                      moe_every=1, capacity_factor=1.25))
    return ArchConfig(
        model=model,
        dmd=DMDConfig(m=8, s=40, snapshot_dtype="bfloat16",
                      param_filter="all", warmup_steps=200),
        optimizer=OptimizerConfig(name="adamw", lr=3e-4, b2=0.95,
                                  weight_decay=0.1, grad_clip=1.0,
                                  schedule="cosine", warmup_steps=200,
                                  total_steps=10000),
        parallel=ParallelConfig(grad_accum=4, remat="block"),  # §Perf it.2
        shapes=("train_4k", "prefill_32k", "decode_32k"),
        skip_notes="long_500k skipped: pure full attention (quadratic).")
