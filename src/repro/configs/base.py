"""Configuration dataclasses for the repro framework.

Plain frozen dataclasses (no pydantic dependency in the hot path): a config is
a *value*, hashable where possible, so jitted step functions can close over it
as a static argument.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

from repro.core.schedule import DMDGroupRule


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    expert_d_ff: int = 0
    n_shared_experts: int = 0       # shared (always-on) experts, llama4-style
    shared_d_ff: int = 0
    moe_every: int = 1              # 1 = every layer is MoE; 2 = alternate dense/MoE
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01
    # weight-stationary (default): expert weights FSDP over "data" on the
    # model dim -> re-gathered every use. activation-stationary: expert
    # weights FSDP over their ffn dim (stay resident); the (much smaller)
    # dispatched activations all-gather instead. See §Perf hillclimb #1.
    weight_stationary: bool = True


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 0              # N (SSD state size per head)
    head_dim: int = 64              # P
    conv_width: int = 4
    expand: int = 2                 # d_inner = expand * d_model
    n_groups: int = 1               # B/C groups (Mamba-2)
    chunk: int = 256                # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 256
    vocab_size: int = 256
    act: str = "silu"               # silu (swiglu) | gelu (geglu) | gelu_mlp | softsign
    norm: str = "rms"               # rms | ln
    rope_theta: float = 10000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t,h,w) split of head_dim/2
    sliding_window: int = 0          # 0 = full attention
    global_every: int = 0            # gemma3: every k-th layer is global, rest local
    tie_embeddings: bool = True
    max_seq_len: int = 8192
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (zamba2): one shared attention block applied every `shared_attn_every`
    # ssm layers.
    shared_attn_every: int = 0
    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0         # fixed frame count from the (stubbed) frontend
    learned_pos_emb: bool = False
    # vlm / audio stub: inputs are precomputed embeddings rather than token ids
    frontend_stub: bool = False
    dtype: str = "bfloat16"          # activation/param compute dtype
    logit_softcap: float = 0.0       # gemma-style final-logit softcapping
    vocab_pad_to: int = 16           # Megatron-style vocab padding for TP

    @property
    def padded_vocab(self) -> int:
        p = self.vocab_pad_to
        return -(-self.vocab_size // p) * p if p else self.vocab_size

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_moe_layer(self, idx: int) -> bool:
        m = self.moe
        return m.n_experts > 0 and (idx % m.moe_every == m.moe_every - 1)

    def is_global_attn_layer(self, idx: int) -> bool:
        """gemma3 5:1 pattern: layer idx is global iff (idx+1) % global_every == 0."""
        if self.global_every <= 0:
            return self.sliding_window == 0
        return (idx + 1) % self.global_every == 0


# ---------------------------------------------------------------------------
# DMD (the paper's technique)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DMDControllerConfig:
    """Loss-gated adaptive jump controller (DESIGN.md §5).

    The paper tunes the number of backprop steps per DMD estimation by hand;
    the controller closes that loop: every jump is gated on a held-out
    microbatch loss evaluated inside the jitted DMD step (accept / halve the
    effective relax and re-blend / reject with bit-exact rollback), and the
    per-group accept history adapts the effective horizon ``s_g`` and the
    POD truncation. ``enabled=False`` (the default) is bit-exact with the
    ungated schedule — no gate forward, no controller state in TrainState.
    """
    enabled: bool = False
    eval_rows: int = 32             # held-out microbatch rows for the gate
                                    # (0 = use the full eval batch; clamped
                                    # to the actual eval-batch size — never
                                    # slices past it)
    accept_tol: float = 1e-3        # accept iff loss_post <= loss_pre *
                                    # (1 + accept_tol). The old 0.0 default
                                    # rejected noise-level TIES: with small
                                    # eval_rows the gate loss carries fp32
                                    # sampling noise and a jump that changed
                                    # nothing real flapped to REJECT. A small
                                    # positive tol tolerates noise-level
                                    # regressions (ISSUE 9).
    val_gate: bool = False          # gate on the trainer's persistent
                                    # validation split (disjoint from the
                                    # training stream) even when the caller
                                    # hands fit() an eval_batch. False keeps
                                    # the caller's batch — the PR-8 pinned
                                    # path. Either way the gate NEVER falls
                                    # back to drawing from the training
                                    # iterator (train/loop.py).
    grow: float = 1.5               # s_eff multiplier on consecutive full
                                    # accepts (capped at the group's s)
    shrink: float = 0.5             # s_eff multiplier on a rejected jump
    s_min: float = 1.0              # lower bound for the adapted horizon
    relax_floor: float = 0.125      # lower bound for the effective relax
                                    # scale (scaled down on every scale-back)
    gain_ema: float = 0.8           # EMA decay of the per-jump relative gain
                                    # (loss_pre - loss_final) / loss_pre
    energy: float = 0.995           # target cumulative-energy fraction for
                                    # the POD rank (replaces the global tol
                                    # noise floor while the controller is on;
                                    # per-group override: DMDGroupRule.energy)
    ridge: float = 0.0              # base Tikhonov shrinkage of the jump
                                    # solve, RELATIVE to sigma_max^2
                                    # (core/dmd.py::_ridge_inv_sigma);
                                    # 0 = the bit-exact legacy solve.
                                    # Per-group override: DMDGroupRule.ridge.
    ridge_max: float = 0.1          # clamp for the meta-tuned per-group
                                    # ridge_eff (controller state)
    shrink_levels: Tuple[float, ...] = (0.5,)
                                    # SCALED-branch relax line search: blend
                                    # fractions tried in order (each blends
                                    # level*jump + (1-level)*current) after a
                                    # rejected full jump. The default (0.5,)
                                    # is the PR-4 single blind halving —
                                    # bit-exact with the PR-8 gated path.
    meta_lr: float = 0.0            # > 0 (matpow mode only): after each gate
                                    # round, backprop the gate-batch loss
                                    # through the differentiable jump and EMA
                                    # each jumped group's relax/ridge knobs
                                    # toward the descent direction (Weiner &
                                    # Semaan, PAPERS.md). 0 = off (bit-exact).


@dataclass(frozen=True)
class DMDConfig:
    enabled: bool = True
    m: int = 14                     # snapshots per DMD round (paper: 14)
    s: int = 55                     # extrapolation horizon in steps (paper: 55)
    tol: float = 1e-4               # singular-value filter sigma_r/sigma_0 > tol
                                    # (paper: 1e-10 with float64; 1e-4 is the
                                    # fp32 Gram noise floor — see dmd.py)
    atol: float = 0.0               # ABSOLUTE sigma floor joined to the
                                    # relative tol/energy mask (pymor-style
                                    # atol/rtol truncation, dmd.py); 0 = off
    warmup_steps: int = 100         # plain steps before the first snapshot window
    cooldown_steps: int = 10        # unrecorded steps after each jump: lets the
                                    # optimizer moments re-adapt so the next
                                    # window measures clean dynamics
    mode: str = "matpow"            # matpow (TPU-native) | eig (host callback)
    clamp_eigs: bool = False        # eig mode only: |lambda| <- min(|lambda|, 1)
    anchor: str = "first"           # none (paper) | first | mean; see dmd.py
    affine: bool = True             # affine-augmented DMD (rank-one Gram update)
    trust_region: float = 2.0       # cap jump at tr*s*rms_step; 0 = off (paper)
    relax: float = 1.0              # w <- (1-relax) w_m + relax * w_dmd
    snapshot_dtype: str = "float32" # fp32 | bfloat16 snapshot storage
    gram_upcast: bool = True        # False: stream bf16 with f32 accumulation
                                    # (halves DMD jump bandwidth; see §Perf)
    streaming_gram: bool = True     # maintain the (stack..., m, m) Gram
                                    # incrementally in TrainState: one O(m*n)
                                    # row pass per record fused into the
                                    # train step, so `apply` is pure O(m^3)
                                    # algebra + one combine pass. False =
                                    # seed behavior (full O(m^2*n) recompute
                                    # at every apply), kept as the A/B
                                    # baseline and correctness oracle.
                                    # Requires anchor in {none, first}.
    arena: bool = True              # pack compatible leaves (same schedule
                                    # group / dtype / sharding class) into
                                    # contiguous per-bucket arenas: ONE
                                    # segmented kernel launch and ONE batched
                                    # coefficient solve per group instead of
                                    # one per leaf (core/arena.py,
                                    # DESIGN.md §7). False = the per-leaf
                                    # route everywhere — the bit-exact A/B
                                    # oracle.
    arena_block_n: int = 512        # arena segment quantum / kernel n-tile
                                    # cap (rounded to 128-lane multiples and
                                    # clamped to the bucket's widest member);
                                    # every segment is padded to a multiple
                                    # so kernel blocks never straddle leaves
    arena_native: bool = True       # arena-native parameter residency
                                    # (DESIGN.md §7): during Trainer.fit the
                                    # managed params of packed leaves live IN
                                    # the bucket's contiguous device buffer;
                                    # the forward reads zero-copy slice views
                                    # and record is one dynamic_update_slice
                                    # per bucket instead of a pack-copy
                                    # gather. False = the PR-5 pack-copy
                                    # route — the bit-exact A/B oracle.
                                    # Residency only engages for optimizers
                                    # whose moments are elementwise
                                    # (train/step.py::RESIDENT_OPTIMIZERS).
    scope: str = "leaf"             # leaf | bucket — the DMD system
                                    # granularity (DESIGN.md §9). "leaf"
                                    # (default) fits one operator per system
                                    # (one per leaf / stacked layer) — the
                                    # bit-exact legacy route. "bucket" fits
                                    # ONE shared Koopman operator per arena
                                    # bucket over the concatenated bucket
                                    # state: the bucket Gram is the
                                    # segment-SUM of the per-system Grams
                                    # (pad lanes are zero, every segment
                                    # shares the bucket's slot schedule, so
                                    # the sum IS the concatenated-state
                                    # Gram), the jump solves n_buckets
                                    # systems per group instead of n_leaves
                                    # (eig host-callback batches shrink
                                    # identically), and the combine
                                    # broadcasts one coefficient vector per
                                    # bucket. Cross-layer modes become
                                    # expressible (Turjeman et al.;
                                    # Manojlović et al., PAPERS.md).
                                    # System-sharded buckets (sys_axes) stay
                                    # per-system either way — collapsing
                                    # them would need a cross-shard psum
                                    # over the stack axis. Checkpoints stay
                                    # leaf-wise on disk in both scopes.
    kernel_route: str = "auto"      # auto | pallas_flat | pallas_shard_map |
                                    # dot_general: force the per-leaf kernel
                                    # route in core/leafplan.py. "auto" picks
                                    # per leaf (flat unsharded -> pallas_flat,
                                    # stacked/sharded -> pallas_shard_map).
                                    # A forced pallas_flat only applies where
                                    # flattening is safe (unstacked,
                                    # unsharded); other leaves keep the auto
                                    # choice. See DESIGN.md §3.
    param_filter: str = "all"       # all | non_expert | matrices_only
                                    # (legacy strings — mapped onto exclusion
                                    # group rules by core/schedule.py)
    min_param_size: int = 0         # skip leaves smaller than this many elements
    groups: Tuple[DMDGroupRule, ...] = ()
                                    # per-leaf schedule groups (DESIGN.md §4):
                                    # each rule's structural matcher (path
                                    # regex / ndim / size) either excludes
                                    # matching leaves or gives them their own
                                    # (m, s, warmup, cooldown, relax, anneal,
                                    # phase) schedule; unset fields inherit
                                    # the globals above, which form the
                                    # default group 0. First match wins.
                                    # Phase offsets stagger jumps across
                                    # groups (at most one group's jump spike
                                    # per step instead of every leaf at once).
    anneal: float = 1.0             # multiplicative decay of `relax` per DMD round
    controller: DMDControllerConfig = field(
        default_factory=DMDControllerConfig)
                                    # loss-gated adaptive jump controller
                                    # (core/controller.py, DESIGN.md §5):
                                    # accept / scale-back / reject-with-
                                    # rollback gate on a held-out microbatch,
                                    # auto-tuned per-group horizons, energy-
                                    # based POD rank. Off by default (bit-
                                    # exact with the ungated schedule).
    reset_opt_state: bool = True    # reset Adam moments after a DMD jump (the
                                    # jump teleports weights; stale moments
                                    # poison the next window's dynamics).
                                    # Per-group override: DMDGroupRule.
                                    # reset_opt — with staggered groups only
                                    # the JUMPED groups' moments reset, and
                                    # slow groups (norms/biases) usually opt
                                    # out entirely (DESIGN.md §4).


# ---------------------------------------------------------------------------
# Optimizer / schedule
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adam"              # sgd|momentum|adam|adamw|adafactor|adam8bit
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0          # 0 = off; else global-norm clip
    schedule: str = "constant"      # constant|cosine|wsd|linear_warmup
    warmup_steps: int = 0
    total_steps: int = 10000
    decay_fraction: float = 0.1     # WSD: fraction of total steps in decay phase
    min_lr_ratio: float = 0.1


# ---------------------------------------------------------------------------
# Parallelism / runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    grad_accum: int = 1              # microbatch accumulation factor
    remat: str = "none"              # none | block | full
    zero1_over_pod: bool = False     # shard optimizer state over pod axis
    grad_compression: str = "none"   # none | int8 (cross-pod quantized all-reduce)
    scan_layers: bool = True         # lax.scan over layer stacks
    pad_attn_heads_to: int = 0       # padded head-TP for indivisible heads
    # serving
    kv_seq_shard_threshold: int = 16 # shard KV by kv-head if n_kv >= this else by seq


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 0        # 0 = off
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3


@dataclass(frozen=True)
class ShapeConfig:
    """One dry-run cell: (kind, seq_len, global_batch)."""
    name: str = "train_4k"
    kind: str = "train"              # train | prefill | decode
    seq_len: int = 4096
    global_batch: int = 256


STANDARD_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", "train", 4096, 256),
    ShapeConfig("prefill_32k", "prefill", 32768, 32),
    ShapeConfig("decode_32k", "decode", 32768, 128),
    ShapeConfig("long_500k", "decode", 524288, 1),
)


@dataclass(frozen=True)
class ArchConfig:
    """Top-level bundle: everything needed to build + run one architecture."""
    model: ModelConfig
    dmd: DMDConfig = field(default_factory=DMDConfig)
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    # which standard shapes apply; names from STANDARD_SHAPES
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    skip_notes: str = ""

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(model: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    shrink = dict(
        n_layers=min(model.n_layers, 4),
        d_model=min(model.d_model, 64),
        n_heads=min(model.n_heads, 4),
        n_kv_heads=min(model.n_kv_heads, 2),
        head_dim=min(model.head_dim, 16),
        d_ff=min(model.d_ff, 128),
        vocab_size=min(model.vocab_size, 512),
        max_seq_len=min(model.max_seq_len, 256),
    )
    if model.n_kv_heads == model.n_heads:       # keep MHA shape relation
        shrink["n_kv_heads"] = shrink["n_heads"]
    if model.n_kv_heads == 1:
        shrink["n_kv_heads"] = 1
    if model.moe.n_experts > 0:
        shrink["moe"] = dataclasses.replace(
            model.moe, n_experts=min(model.moe.n_experts, 8),
            top_k=min(model.moe.top_k, 2),
            expert_d_ff=min(model.moe.expert_d_ff, 64),
            shared_d_ff=min(model.moe.shared_d_ff, 64),
        )
    if model.ssm.state_dim > 0:
        shrink["ssm"] = dataclasses.replace(
            model.ssm, state_dim=min(model.ssm.state_dim, 16),
            head_dim=min(model.ssm.head_dim, 16), chunk=32)
    if model.n_encoder_layers > 0:
        shrink["n_encoder_layers"] = min(model.n_encoder_layers, 2)
        shrink["encoder_seq_len"] = min(model.encoder_seq_len, 32)
    if model.global_every > 0:
        shrink["n_layers"] = max(shrink["n_layers"], model.global_every)
    if model.shared_attn_every > 0:
        shrink["n_layers"] = max(shrink["n_layers"], model.shared_attn_every)
    if model.sliding_window > 0:
        shrink["sliding_window"] = min(model.sliding_window, 32)
    if model.mrope_sections:
        hd = shrink.get("head_dim", model.head_dim)
        s1 = max(hd // 8, 1)
        rest = hd // 2 - s1
        shrink["mrope_sections"] = (s1, rest // 2, rest - rest // 2)
    shrink.update(overrides)
    return dataclasses.replace(model, **shrink)
