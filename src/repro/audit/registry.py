"""Pass registry + report types for the static auditor.

A pass is a named function ``(ctx) -> list[Violation]`` over an
``AuditContext`` (repro.audit.targets). Registration is declarative::

    @register_pass("donation-alias",
                   doc="every buffer/Gram leaf aliases input->output")
    def donation_alias(ctx): ...

``run_passes`` executes the registered table in registration order and
folds the results into an ``AuditReport`` that renders as text (the CLI
report) or a JSON-able dict (``AUDIT_<arch>.json``). A pass that raises
is itself a violation (severity ``error``) — an auditor that crashes must
not read as a clean bill.
"""
from __future__ import annotations

import traceback
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class Violation:
    """One audited invariant, broken. ``where`` names the target or table
    row (e.g. ``train_step`` or ``bucket g0-float32/seg /a``); ``detail``
    is the human-readable evidence (counts, shapes, offsets)."""
    passname: str
    where: str
    detail: str
    severity: str = "error"        # "error" fails the audit; "warning" is
                                   # reported but does not flip the exit code

    def to_dict(self) -> dict:
        return {"pass": self.passname, "where": self.where,
                "detail": self.detail, "severity": self.severity}


@dataclass
class PassResult:
    name: str
    doc: str
    violations: List[Violation] = field(default_factory=list)
    info: Dict[str, object] = field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return not any(v.severity == "error" for v in self.violations)


@dataclass
class AuditReport:
    arch: str
    meta: Dict[str, object]
    results: List[PassResult] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    @property
    def violations(self) -> List[Violation]:
        return [v for r in self.results for v in r.violations]

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "ok": self.ok,
            "meta": dict(self.meta),
            "passes": [{
                "name": r.name, "ok": r.ok, "doc": r.doc,
                "violations": [v.to_dict() for v in r.violations],
                "info": {k: _jsonable(v) for k, v in r.info.items()},
            } for r in self.results],
        }

    def render(self) -> str:
        lines = [f"repro.audit — {self.arch} "
                 f"({', '.join(f'{k}={v}' for k, v in self.meta.items())})",
                 "=" * 72]
        for r in self.results:
            mark = "PASS" if r.ok else "FAIL"
            lines.append(f"[{mark}] {r.name:<22} {r.doc}")
            for k, v in sorted(r.info.items()):
                lines.append(f"       . {k} = {_jsonable(v)}")
            for v in r.violations:
                tag = "!" if v.severity == "error" else "~"
                lines.append(f"       {tag} {v.where}: {v.detail}")
        n_err = sum(1 for v in self.violations if v.severity == "error")
        n_warn = sum(1 for v in self.violations if v.severity == "warning")
        lines.append("=" * 72)
        lines.append(f"{'CLEAN' if self.ok else 'VIOLATIONS'}: "
                     f"{n_err} error(s), {n_warn} warning(s) across "
                     f"{len(self.results)} passes")
        return "\n".join(lines)


def _jsonable(v):
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


PassFn = Callable[[object], Tuple[List[Violation], Dict[str, object]]]

_REGISTRY: Dict[str, Tuple[PassFn, str]] = {}


def register_pass(name: str, doc: str = ""):
    """Decorator: add ``fn(ctx) -> (violations, info)`` to the registry.
    Passes run in registration order (repro.audit.passes imports define
    the canonical order)."""
    def deco(fn: PassFn) -> PassFn:
        _REGISTRY[name] = (fn, doc or (fn.__doc__ or "").strip().split(
            "\n")[0])
        return fn
    return deco


def get_pass(name: str) -> PassFn:
    return _REGISTRY[name][0]


def list_passes() -> List[str]:
    return list(_REGISTRY)


def run_passes(ctx, only: Optional[Sequence[str]] = None) -> AuditReport:
    names = list(only) if only else list(_REGISTRY)
    unknown = [n for n in names if n not in _REGISTRY]
    if unknown:
        raise KeyError(f"unknown audit pass(es) {unknown}; "
                       f"known: {list(_REGISTRY)}")
    report = AuditReport(arch=ctx.arch, meta=ctx.meta())
    for name in names:
        fn, doc = _REGISTRY[name]
        result = PassResult(name=name, doc=doc)
        try:
            violations, info = fn(ctx)
            result.violations = list(violations)
            result.info = dict(info)
        except Exception as e:                       # pragma: no cover
            result.violations = [Violation(
                passname=name, where="(pass crashed)",
                detail=f"{type(e).__name__}: {e}\n"
                       f"{traceback.format_exc(limit=6)}")]
        report.results.append(result)
    return report
