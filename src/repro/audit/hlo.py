"""Shared HLO-text analysis primitives — the ONE home for every regex the
repo runs over compiled HLO.

Before this module the donation audit (tests/test_donation.py), the
shard_map all-gather audits (tests/dist_worker.py) and the dry-run
collective inventory (launch/dryrun.py) each carried their own copy of
the shape/collective parsing; a dtype added to one byte map silently
missed the others. Everything textual now lives here; the audit passes
(repro.audit.passes) and those callers all import these helpers.

Conventions: shapes are matched as HLO shape strings (``f32[4,2,32]``);
``shape_str(leaf)`` renders a JAX leaf the same way so pytree leaves and
HLO operands compare directly.
"""
from __future__ import annotations

import re
from typing import Dict, Iterable, List, Set, Tuple

# HLO dtype -> bytes/element (shared by every byte-accounting consumer)
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
               "u32": 4, "u16": 2, "s16": 2, "s8": 1, "u8": 1, "pred": 1,
               "c64": 8, "c128": 16}

_JAX_DTYPE = {"float64": "f64", "float32": "f32", "bfloat16": "bf16",
              "float16": "f16", "int64": "s64", "int32": "s32",
              "uint32": "u32", "int16": "s16", "uint16": "u16",
              "int8": "s8", "uint8": "u8", "bool": "pred"}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "reduce-scatter",
                    "all-to-all", "collective-permute")


def shape_str(leaf) -> str:
    """JAX leaf -> its HLO shape string (``bf16[4,2,32]``)."""
    d = _JAX_DTYPE.get(str(leaf.dtype), str(leaf.dtype))
    return d + "[" + ",".join(str(int(s)) for s in leaf.shape) + "]"


def shape_bytes(s: str) -> int:
    """Total bytes of one HLO shape string (0 if unparsable)."""
    m = _SHAPE_RE.match(s)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BYTES.get(m.group(1), 4)


def alias_count(hlo: str) -> int:
    """Number of entries in the module's ``input_output_alias`` table
    (0 when the module has none — nothing was donated)."""
    for line in hlo.splitlines():
        if "input_output_alias" in line:
            return len(re.findall(r"\{\d+\}: \(\d+", line))
    return 0


def copy_ops(hlo: str, shapes: Iterable[str]) -> List[str]:
    """Copy ops whose result starts with one of ``shapes`` — a donated
    buffer that silently lost its donation shows up as exactly such a
    copy (the HLO sometimes carries a layout suffix, hence prefix
    matching)."""
    shapes = tuple(shapes)
    copies = re.findall(r"= (\S+?)(?:\{[^}]*\})? copy\(", hlo)
    return [c for c in copies if any(c.startswith(s) for s in shapes)]


def convert_ops(hlo: str) -> List[Tuple[str, str]]:
    """(result_shape, operand_shape) for every dtype ``convert`` whose
    operand shape is inline in the instruction text. The dtype-flow pass
    matches these against the managed buffer/Gram shapes."""
    out = []
    for m in re.finditer(
            r"= ([a-z]+[0-9]+\[[0-9,]*\])[^=\n]*? convert\(([a-z]+[0-9]+"
            r"\[[0-9,]*\])", hlo):
        out.append((m.group(1), m.group(2)))
    return out


def collective_ops(hlo: str) -> List[Tuple[str, int]]:
    """(kind, operand_bytes) per collective instruction (``-done`` halves
    of async pairs are skipped so nothing double-counts)."""
    out = []
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ROOT )?%?[\w.\-]+ = (.*?) (all-reduce|"
                     r"all-gather|reduce-scatter|all-to-all|"
                     r"collective-permute)(-start|-done)?\(", line)
        if not m or m.group(3) == "-done":
            continue
        nbytes = 0
        for ms in _SHAPE_RE.finditer(m.group(1)):
            n = 1
            for d in ms.group(2).split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES.get(ms.group(1), 4)
        out.append((m.group(2), nbytes))
    return out


def allgather_shapes(hlo: str) -> List[str]:
    """Normalized result shape strings ("f32[4,26624]") of every
    all-gather instruction — the collective-budget pass matches these
    against the snapshot-buffer / Gram shape sets: a gather RESULTING in a
    buffer-shaped tensor is the reshard-to-replicated failure mode, even
    in programs (the fused step, the gated jump) whose model-parallel
    forward legitimately gathers activation-sized tensors."""
    out: List[str] = []
    for line in hlo.splitlines():
        m = re.match(r"\s*(?:ROOT )?%?[\w.\-]+ = (.*?) all-gather"
                     r"(?:-start)?\(", line)
        if not m:
            continue
        out.extend(f"{ms.group(1)}[{ms.group(2)}]"
                   for ms in _SHAPE_RE.finditer(m.group(1)))
    return out


def max_allgather_bytes(hlo: str) -> int:
    """Largest all-gather operand in an HLO text, in bytes — the audit
    primitive behind the "no buffer-sized all-gather" invariant
    (DESIGN.md §3.4/§7): the sharded Gram route psums O(n_sys·m²)
    partials and must never gather an O(m·n) buffer."""
    return max((b for k, b in collective_ops(hlo) if k == "all-gather"),
               default=0)


def parse_collectives(hlo: str) -> Tuple[Dict[str, int], Dict[str, int]]:
    """({kind: total_operand_bytes}, {kind: count}) — shard-local shapes;
    multiply by participating devices for global traffic. (The dry-run's
    §Roofline inventory and the collective-budget pass share this.)"""
    totals: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for kind, nbytes in collective_ops(hlo):
        totals[kind] = totals.get(kind, 0) + nbytes
        counts[kind] = counts.get(kind, 0) + 1
    return totals, counts


def dmd_state_shapes(state) -> Tuple[Set[str], Set[str], Set[str]]:
    """(buffer_shapes, gram_shapes, all_dmd_shapes) of a TrainState — the
    shape strings the donation / dtype-flow / collective passes key on."""
    import jax

    bufs: Set[str] = set()
    grams: Set[str] = set()
    for kp, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        if leaf is None:
            continue
        k = jax.tree_util.keystr(kp)
        if "dmd_buffers" in k:
            bufs.add(shape_str(leaf))
        elif "dmd_gram" in k:
            grams.add(shape_str(leaf))
    return bufs, grams, bufs | grams
