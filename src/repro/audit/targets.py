"""Audit-target construction: one config -> the traced/compiled programs
and static tables every pass runs over.

For an ``--arch`` (plus ``--reduced`` / ``--mesh``) this builds the SAME
jitted entry points the Trainer runs — via the introspection hook
``train/step.py::audit_step_fns`` (same donate_argnums, same static
argnames) — and traces + compiles each once:

  * ``train_step``       — the fused step (record + streaming Gram inside),
  * ``dmd_step``         — the plain (ungated) jump, every group,
  * ``dmd_step_gated``   — the loss-gated controller variant (built from a
                           controller-enabled clone of the config),
  * ``record_update``    — record + Gram maintenance standalone (buffers
                           and Grams donated), so the data-pass invariants
                           are auditable in isolation.

plus the static tables: the LeafPlan pytree, the ArenaBucket table, and
the resolved GroupSchedule table (their ``*_records`` export hooks feed
the AUDIT_*.json artifact directly).

``mutate=`` applies a named seeded violation (repro.audit.mutations) so
tests and the CI mutation lane can prove each pass bites.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Optional, Tuple

PyTree = Any

# Model-shrink overrides shared with the tier-1 audits
# (tests/test_donation.py, tests/test_trace_size.py): the audit CLI and the
# test suite must lower the SAME reduced programs or their pins diverge.
REDUCED_OVERRIDES = dict(n_layers=2, d_model=32, d_ff=64, vocab_size=128,
                         n_heads=2, n_kv_heads=1, head_dim=16)
REDUCED_BATCH, REDUCED_SEQ = 4, 16

# pollutant-mlp --reduced: a same-family softsign MLP small enough for the
# CI fast lane (the full paper sizes stay the default).
REDUCED_MLP_SIZES = (6, 16, 32, 40)


@dataclass(frozen=True)
class AuditTarget:
    """One traced+compiled program under audit."""
    name: str
    jaxpr: Any                      # ClosedJaxpr of the traced call
    hlo: str                        # compiled HLO text
    donated: bool                   # donate_argnums applied at jit time
    n_state_leaves: int             # leaves of the donated arg (arg 0)
    n_dmd_leaves: int               # buffer+gram leaves within it
    buffer_shapes: FrozenSet[str]   # HLO shape strings (audit.hlo)
    gram_shapes: FrozenSet[str]


@dataclass
class AuditContext:
    arch: str
    reduced: bool
    mesh_shape: Optional[Tuple[int, ...]]
    mutate: Optional[str]
    acfg: Any
    acc: Any                        # DMDAccelerator (plans/arena built)
    mesh: Any
    plans: PyTree
    arena: Dict[str, Any]           # {key: ArenaBucket}
    groups: Tuple[Any, ...]         # resolved GroupSchedule table
    state: Any                      # TrainState (shape source of truth)
    targets: Dict[str, AuditTarget] = field(default_factory=dict)
    # serve-engine build info (repro.serve.audit.attach_serve): program
    # registry counts for the serve-compile pass, or None when no serving
    # build was attached (--serve).
    serve: Optional[Dict[str, Any]] = None

    @property
    def cfg(self):
        return self.acfg.dmd

    @property
    def config_key(self) -> str:
        key = self.arch
        if self.reduced:
            key += "-reduced"
        if self.mesh_shape:
            key += "-mesh"
        return key

    def meta(self) -> Dict[str, Any]:
        return {"reduced": self.reduced,
                "mesh": ("x".join(map(str, self.mesh_shape))
                         if self.mesh_shape else None),
                "mutate": self.mutate,
                "config_key": self.config_key}

    def tables(self) -> Dict[str, Any]:
        """The static tables as JSON-able records (the export hooks)."""
        from repro.core import arena as arena_mod
        from repro.core import leafplan, schedule as sched_mod
        return {"plans": leafplan.plan_records(self.plans),
                "arena": arena_mod.layout_table(
                    self.arena, scope=getattr(self.cfg, "scope", "leaf")),
                "groups": sched_mod.schedule_records(self.groups)}


class MLPModel:
    """Trainer-compatible wrapper for the paper's regression MLP (the
    pollutant-mlp arch has no LanguageModel)."""

    def __init__(self, sizes, act: str = "softsign"):
        self.sizes = tuple(sizes)
        self.act = act

    def init(self, key=None):
        import jax
        from repro.models.mlp_net import init_mlp
        return init_mlp(key if key is not None else jax.random.PRNGKey(0),
                        self.sizes)

    def loss(self, params, batch):
        from repro.models.mlp_net import mse_loss
        return mse_loss(params, batch["x"], batch["y"], self.act), None


def _build_model_and_config(arch: str, reduced_flag: bool):
    """(model, acfg, example_batch) for one audit build."""
    import jax.numpy as jnp
    from repro.configs import get_config, reduced
    from repro.configs.base import OptimizerConfig, TrainConfig

    acfg = get_config(arch)
    if acfg.model.family == "mlp":
        from repro.configs.pollutant_mlp import PAPER_SIZES
        sizes = REDUCED_MLP_SIZES if reduced_flag else PAPER_SIZES
        batch_rows = 8
        model = MLPModel(sizes, acfg.model.act)
        batch = {"x": jnp.zeros((batch_rows, sizes[0]), jnp.float32),
                 "y": jnp.zeros((batch_rows, sizes[-1]), jnp.float32)}
        return model, acfg, batch

    from repro.configs.base import DMDConfig
    from repro.models.transformer import LanguageModel
    if reduced_flag:
        mc = reduced(acfg.model, **REDUCED_OVERRIDES)
        acfg = dataclasses.replace(
            acfg, model=mc,
            dmd=DMDConfig(enabled=True, m=4, s=10, tol=1e-4,
                          warmup_steps=4, cooldown_steps=2,
                          arena=acfg.dmd.arena),
            optimizer=OptimizerConfig(name="adam", lr=3e-3,
                                      schedule="constant"),
            parallel=dataclasses.replace(acfg.parallel, grad_accum=1,
                                         remat="none"),
            train=TrainConfig(global_batch=REDUCED_BATCH,
                              seq_len=REDUCED_SEQ))
    mc = acfg.model
    model = LanguageModel(mc, head_tp=False if reduced_flag else None,
                          chunk_k=min(16 if reduced_flag else 1024,
                                      acfg.train.seq_len))
    b, s = acfg.train.global_batch, acfg.train.seq_len
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "labels": jnp.zeros((b, s), jnp.int32)}
    if mc.mrope_sections:
        batch["positions"] = jnp.zeros((b, 3, s), jnp.int32)
    return model, acfg, batch


def _init_state(model, acfg, acc, mesh=None):
    import jax
    import jax.numpy as jnp
    from repro.optim import make_optimizer
    from repro.train.state import TrainState
    from repro.train.step import state_resident

    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(acfg.optimizer)
    bufs = acc.init(params) if acfg.dmd.enabled else None
    state = TrainState(params, opt.init(params), jnp.zeros((), jnp.int32),
                       bufs, acc.init_grams(bufs), acc.init_controller())
    if mesh is not None:
        # Audit the launch-path placement (launch/inputs.state_specs):
        # donated inputs arriving in their final sharding — a replicated
        # state would make the step's constrain() calls reshard donated
        # args and read as spurious copies.
        from jax.sharding import NamedSharding
        from repro.launch.inputs import state_specs
        specs = state_specs(state, mesh, plans=acc.plans_for(params),
                            arena=acc.arena_for(params))
        state = jax.tree_util.tree_map(
            lambda l, s: jax.device_put(l, NamedSharding(mesh, s)),
            state, specs)
    # Same entry conversion Trainer.fit applies (train/step.py): the audit
    # must lower the step programs over the SAME resident layout training
    # runs with, or the residency pass would audit a program that never
    # executes.
    return state_resident(acc, acfg, state)


def trace_target(name: str, jitted, args, kwargs, state,
                 donated: bool = True) -> AuditTarget:
    """Trace + compile ONE jitted entry point into an AuditTarget — the
    seam the tier-1 tests use to route their existing Trainer programs
    through the shared passes without rebuilding a full context."""
    import jax
    from repro.audit import hlo as hlo_mod

    traced = jitted.trace(*args, **kwargs)
    hlo = traced.lower().compile().as_text()
    bufs, grams, _ = hlo_mod.dmd_state_shapes(state)
    n_dmd = sum(
        1 for kp, l in jax.tree_util.tree_flatten_with_path(state)[0]
        if l is not None and any(
            k in jax.tree_util.keystr(kp)
            for k in ("dmd_buffers", "dmd_gram")))
    return AuditTarget(
        name=name, jaxpr=traced.jaxpr, hlo=hlo, donated=donated,
        n_state_leaves=len(jax.tree_util.tree_leaves(state)),
        n_dmd_leaves=n_dmd,
        buffer_shapes=frozenset(bufs), gram_shapes=frozenset(grams))


def serve_target(name: str, jitted, args, caches,
                 donated: bool = True) -> AuditTarget:
    """AuditTarget for a serving program (launch/serve.py::serve_fns):
    the KV caches play the role of the managed tensors — every cache leaf
    must alias input->output (donated arg 2) and no cache-shaped copy may
    survive compilation, exactly the donation-alias invariant the train
    programs pin on their snapshot buffers."""
    import jax
    from repro.audit import hlo as hlo_mod

    import jax.numpy as jnp

    traced = jitted.trace(*args)
    hlo = traced.lower().compile().as_text()
    leaves = [l for l in jax.tree_util.tree_leaves(caches)
              if l is not None]
    # the copy ban covers the KV tensors (floating dtypes); the s32 length
    # counters are 8-byte scalars XLA may copy freely — they still count
    # toward the alias floor (every cache leaf must be donated).
    shapes = frozenset(hlo_mod.shape_str(l) for l in leaves
                       if jnp.issubdtype(l.dtype, jnp.floating))
    return AuditTarget(
        name=name, jaxpr=traced.jaxpr, hlo=hlo, donated=donated,
        n_state_leaves=len(leaves), n_dmd_leaves=len(leaves),
        buffer_shapes=shapes, gram_shapes=frozenset())


def jaxpr_target(name: str, jaxpr, state=None) -> AuditTarget:
    """AuditTarget from a bare jaxpr (no compile): enough for the
    jaxpr-only passes (trace-budget, host-callback). ``jaxpr`` may be a
    ClosedJaxpr (jax.make_jaxpr output) or an inner Jaxpr."""
    from repro.audit import hlo as hlo_mod

    inner = getattr(jaxpr, "jaxpr", jaxpr)
    if state is not None:
        bufs, grams, _ = hlo_mod.dmd_state_shapes(state)
    else:
        bufs, grams = set(), set()
    return AuditTarget(name=name, jaxpr=inner, hlo="", donated=True,
                       n_state_leaves=0, n_dmd_leaves=0,
                       buffer_shapes=frozenset(bufs),
                       gram_shapes=frozenset(grams))


def adhoc_context(arch: str, acfg, targets: Dict[str, AuditTarget], *,
                  mesh=None, plans=None, arena=None, groups=(),
                  state=None, reduced: bool = False) -> AuditContext:
    """A partial AuditContext over caller-built targets — the tier-1
    tests wrap their existing Trainer programs in one of these and call
    the shared pass functions directly (same invariants as the CLI, no
    duplicate HLO-regex logic). ``arch`` doubles as the pin key
    (AuditContext.config_key), so a test pinning a bespoke model names it
    here and registers its ceiling in repro/audit/pins.py."""
    return AuditContext(
        arch=arch, reduced=reduced, mesh_shape=None, mutate=None,
        acfg=acfg, acc=None, mesh=mesh, plans=plans,
        arena=dict(arena or {}), groups=tuple(groups), state=state,
        targets=dict(targets))


def build_context(arch: str, *, reduced: bool = False,
                  mesh_shape: Optional[Tuple[int, ...]] = None,
                  mutate: Optional[str] = None,
                  serve: bool = False) -> AuditContext:
    """Build every audit target + static table for one config.

    ``mesh_shape`` (e.g. ``(2, 4)``) traces under a real mesh — the
    process must already expose enough devices (the CLI sets
    ``--xla_force_host_platform_device_count`` before importing jax).

    ``serve=True`` (CLI ``--serve``) additionally builds a reduced
    serving engine over the same model family, drives a warmup + steady
    workload through it, and attaches its program-registry counts
    (``ctx.serve``) and compiled decode program (``serve_decode`` target)
    for the serve-compile pass."""
    import contextlib

    import jax
    import jax.numpy as jnp

    from repro.audit import mutations as mut_mod
    from repro.configs.base import DMDControllerConfig
    from repro.distributed.sharding import mesh_context
    from repro.train.step import audit_step_fns

    mutation = mut_mod.get(mutate) if mutate else None

    model, acfg, batch = _build_model_and_config(arch, reduced)
    if mutation is not None and mutation.config is not None:
        acfg = mutation.config(acfg)
    donate = mutation.donate if mutation is not None else True

    mesh = None
    cm = contextlib.nullcontext()
    if mesh_shape:
        axis_names = {1: ("model",), 2: ("data", "model"),
                      3: ("pod", "data", "model")}[len(mesh_shape)]
        mesh = jax.make_mesh(tuple(mesh_shape), axis_names)
        cm = mesh_context(mesh)

    with cm:
        acc, fns = audit_step_fns(model, acfg, mesh=mesh, donate=donate)
        if mutation is not None and mutation.wrap_fns is not None:
            fns = mutation.wrap_fns(acc, fns, mesh)
        state = _init_state(model, acfg, acc, mesh)
        plans = acc.plans_for(state.params)
        arena = acc.arena_for(state.params)

        ctx = AuditContext(
            arch=arch, reduced=reduced,
            mesh_shape=tuple(mesh_shape) if mesh_shape else None,
            mutate=mutate, acfg=acfg, acc=acc, mesh=mesh, plans=plans,
            arena=dict(arena), groups=acc.groups, state=state)

        step = jnp.asarray(5, jnp.int32)
        relax = jnp.ones((acc.n_groups,), jnp.float32)
        ctx.targets["train_step"] = trace_target(
            "train_step", fns["train_step"], (state, batch, step), {},
            state, donate)
        ctx.targets["dmd_step"] = trace_target(
            "dmd_step", fns["dmd_step"], (state, relax),
            {"groups": None}, state, donate)
        slots = jnp.asarray(acc.slots(5))
        if state.dmd_buffers is not None:
            ctx.targets["record_update"] = trace_target(
                "record_update", fns["record_update"],
                (state.dmd_buffers, state.dmd_gram, state.params, slots),
                {}, state, donate)

        # Gated (controller) variant: a controller-enabled clone — the
        # rollback branch must thread the WHOLE donated state through.
        gated_acfg = dataclasses.replace(
            acfg, dmd=dataclasses.replace(
                acfg.dmd, controller=DMDControllerConfig(enabled=True,
                                                         eval_rows=4)))
        gacc, gfns = audit_step_fns(model, gated_acfg, mesh=mesh,
                                    donate=donate)
        if mutation is not None and mutation.wrap_fns is not None:
            gfns = mutation.wrap_fns(gacc, gfns, mesh)
        gstate = _init_state(model, gated_acfg, gacc, mesh)
        grelax = jnp.ones((gacc.n_groups,), jnp.float32)
        ctx.targets["dmd_step_gated"] = trace_target(
            "dmd_step_gated", gfns["dmd_step"], (gstate, grelax, batch),
            {"groups": None}, gstate, donate)

    # Serving build OUTSIDE the mesh context: the engine's vmapped decode
    # is a single-host program (its constrain() calls are identity with no
    # active mesh) — mesh serving placement is launch/inputs.py's
    # serve_state_specs, exercised by its own tests.
    if serve or (mutation is not None and mutation.serve):
        from repro.serve.audit import attach_serve
        attach_serve(ctx, mutate=(mutation.serve_cfg
                                  if mutation is not None else None))

    if mutation is not None and mutation.post is not None:
        mutation.post(ctx)
    return ctx
