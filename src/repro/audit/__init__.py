"""repro.audit — compile-time invariant auditor (DESIGN.md §8).

The DMD speedup survives at scale only because of fragile compile-time
invariants: donated buffers (no hidden copies of the O(m·n) snapshot
state), all-gather-free sharded Grams (the psum'd partials are O(n_sys·m²),
never a gather of the buffer), O(buckets) traces (the packed-arena route,
DESIGN.md §7), fp32 Grams with no silent casts, no host round-trips inside
the jitted hot loop, 128-lane-aligned arena segments, and a
collision-free group schedule. PRs 1–5 each re-guarded a slice of these
with one-off regexes over compiled HLO; this package is the ONE reusable
static-analysis layer: a registry of passes that run over (a) lowered
jaxprs + compiled HLO of the fused train step, both dmd_step variants and
the record/update path, and (b) the static LeafPlan / GroupSchedule /
ArenaBucket tables — for any config, before paying for a benchmark run.

    PYTHONPATH=src python -m repro.audit --arch tinyllama-1.1b --reduced
    PYTHONPATH=src python -m repro.audit.lint src/

The CLI emits a text report plus ``AUDIT_<arch>.json`` and exits nonzero
on violation. The CI ``audit`` lane runs it over the pinned configs, and
``--mutate <name>`` seeds known violations (dropped donation, forced
all-gather, misaligned arena offset, overlapping group rules) to prove
every pass bites. tests/test_donation.py, tests/test_trace_size.py and
tests/test_sharded_kernels.py route through the same passes — no
standalone HLO-regex logic anywhere else.
"""
from repro.audit.registry import (AuditReport, PassResult, Violation,
                                  get_pass, list_passes, register_pass)

__all__ = ["AuditReport", "PassResult", "Violation", "get_pass",
           "list_passes", "register_pass", "run_audit"]


def run_audit(arch: str, *, reduced: bool = False, mesh_shape=None,
              mutate=None, passes=None) -> AuditReport:
    """Build the audit targets for ``arch`` and run every registered pass
    (or the named subset). Convenience wrapper over
    ``targets.build_context`` + ``registry.run_passes`` — the CLI in
    ``__main__`` adds the report file / exit-code handling."""
    from repro.audit import passes as _passes  # noqa: F401  (registers)
    from repro.audit.registry import run_passes
    from repro.audit.targets import build_context

    ctx = build_context(arch, reduced=reduced, mesh_shape=mesh_shape,
                        mutate=mutate)
    return run_passes(ctx, only=passes)
