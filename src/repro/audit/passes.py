"""The built-in audit passes (DESIGN.md §8).

Each pass is a pure function over an ``AuditContext`` — traced jaxprs,
compiled HLO, and the static plan/schedule/arena tables — registered under
a stable name. The registry order below is the report order:

  donation-alias            dropped donate_argnums / buffer-shaped copies
  collective-budget         analytic psum budget + buffer-sized all-gather ban
  trace-budget              per-target eqn/launch ceilings (repro.audit.pins)
  solve-budget              batched coefficient-solve rows per jump within the
                            scope budget (bucket scope: one per bucket)
  dtype-flow                silent fp32<->bf16 casts on Gram/buffer tensors
  host-callback-in-hot-loop pure/io_callback in a jitted step (eig whitelist)
  arena-layout              offset-table / alignment / eligibility invariants
  arena-residency           resident params: no bucket-sized pack gathers in
                            the hot data passes (record is a pointer bump)
  schedule-conflict         overlapping rules, phase-residue collisions, clamps
  serve-compile             serve engine: program count <= bucket ceiling,
                            zero steady-state recompiles, donated copy-free
                            decode over the slot-stacked caches

These are the SAME invariant checks the tier-1 audits assert
(tests/test_donation.py, tests/test_trace_size.py route through them) —
the CLI just runs them over every target at once and emits AUDIT_*.json.
"""
from __future__ import annotations

import math
from typing import Dict, List

from repro.audit import hlo as H
from repro.audit.registry import Violation, register_pass

ROUTES = ("pallas_flat", "pallas_shard_map", "dot_general")

# collective-budget slack: XLA may split/fuse psums, carry counters, or pad;
# the budget bounds the ORDER, not the byte.
PSUM_SLACK, PSUM_FLOOR = 4, 4096

# Targets whose all-reduce volume is NOT bounded by the DMD psum budget:
# the gradient psum under data parallelism (train_step) and the gate
# forward's activation collectives (the gated jump) are legitimately
# buffer-/activation-sized. The all-gather ban still applies to them.
_UNBUDGETED = ("train_step", "dmd_step_gated")


def _prod(xs) -> int:
    out = 1
    for x in xs:
        out *= int(x)
    return out


# ---------------------------------------------------------------------------
# donation-alias
# ---------------------------------------------------------------------------

@register_pass(
    "donation-alias",
    "every buffer/Gram leaf aliases input->output; zero dmd-shaped copies")
def donation_alias(ctx):
    vs: List[Violation] = []
    info: Dict[str, object] = {}
    # (expected alias floor, exact?) per target — the fused step and the
    # gated jump must alias the WHOLE TrainState; the plain jump prunes
    # dead param inputs so only a floor is pinned there.
    for name, t in sorted(ctx.targets.items()):
        if name in ("train_step", "dmd_step_gated"):
            expect, exact = t.n_state_leaves, True
        else:
            expect, exact = t.n_dmd_leaves, False
        ac = H.alias_count(t.hlo)
        info[f"{name}.alias_count"] = ac
        info[f"{name}.alias_expected"] = ("==" if exact else ">=") + str(expect)
        if t.donated and (ac != expect if exact else ac < expect):
            vs.append(Violation(
                "donation-alias", name,
                f"input_output_alias covers {ac} leaves, expected "
                f"{'==' if exact else '>='} {expect} — a donation was "
                "dropped (missing donate_argnums or a dead donated input)"))
        elif not t.donated and ac >= max(expect, 1):
            # mutation sanity: donate=() must NOT alias
            info[f"{name}.note"] = "undonated build still aliases?"
        if not t.donated:
            vs.append(Violation(
                "donation-alias", name,
                "jit compiled without donate_argnums on the state "
                f"(alias table covers {ac} of {expect} leaves)"))
        buf_copies = H.copy_ops(t.hlo, t.buffer_shapes)
        gram_copies = H.copy_ops(t.hlo, t.gram_shapes)
        info[f"{name}.dmd_copies"] = len(buf_copies) + len(gram_copies)
        if buf_copies:
            vs.append(Violation(
                "donation-alias", name,
                f"{len(buf_copies)} snapshot-buffer-shaped copy op(s) in "
                f"compiled HLO (dropped donation): "
                f"{sorted(set(buf_copies))[:4]}"))
        if gram_copies:
            # The SPMD partitioner conservatively copies the O(n_sys*m^2)
            # Gram stack across called computations on sharded builds —
            # same order as the psum budget, not the O(m*n) failure mode.
            vs.append(Violation(
                "donation-alias", name,
                f"{len(gram_copies)} Gram-shaped copy op(s): "
                f"{sorted(set(gram_copies))[:4]}",
                severity="warning" if ctx.mesh is not None else "error"))
    return vs, info


# ---------------------------------------------------------------------------
# collective-budget
# ---------------------------------------------------------------------------

def psum_budget_bytes(ctx) -> int:
    """Analytic per-call Gram psum budget: O(n_sys * m^2) fp32 words over
    every lane-sharded bucket / per-leaf plan (DESIGN.md §6) — the ONLY
    collectives the DMD data pass is allowed."""
    from repro.core.arena import arena_paths
    from repro.core.leafplan import plan_entries

    total = 0
    for b in ctx.arena.values():
        if b.lane_axes:
            # n_sys_global: a system-sharded bucket psums one Gram partial
            # per GLOBAL system (each sys shard reduces its local rows over
            # the lane axes), so the analytic volume scales with the full
            # stack, not the per-shard slice.
            total += b.n_sys_global * (b.m * b.m + b.m) * 4
    packed = arena_paths(ctx.arena)
    for p in plan_entries(ctx.plans):
        if p.path in packed:
            continue
        if p.psum_axes():
            n_sys = _prod(p.shape[:p.stack_dims]) if p.stack_dims else 1
            total += n_sys * (p.m * p.m + p.m) * 4
    return total


@register_pass(
    "collective-budget",
    "all-reduce bytes within the analytic O(n_sys*m^2) psum budget; "
    "no buffer-sized all-gather anywhere")
def collective_budget(ctx):
    vs: List[Violation] = []
    info: Dict[str, object] = {}
    budget = psum_budget_bytes(ctx) * PSUM_SLACK + PSUM_FLOOR
    info["psum_budget_bytes"] = budget
    buf_bytes = [H.shape_bytes(s) for s in ctx.targets.get(
        "train_step", next(iter(ctx.targets.values()))).buffer_shapes]
    min_buf = min(buf_bytes) if buf_bytes else None
    info["min_buffer_bytes"] = min_buf
    for name, t in sorted(ctx.targets.items()):
        totals, counts = H.parse_collectives(t.hlo)
        info[f"{name}.collectives"] = {k: [counts[k], totals[k]]
                                       for k in sorted(totals)}
        # Buffer-shaped all-gather: banned in EVERY target. The model
        # forward's TP gathers are activation-sized and never land on a
        # snapshot shape; a gather RESULTING in one means a managed tensor
        # was resharded to replicated instead of psum'd in Gram form.
        # Gram-SHAPED gathers are deliberately out of scope: a system-
        # sharded bucket's (n_sys, m, m) stack is P(sys_axes, None, None),
        # and the jump's gcat concatenate legitimately gathers those
        # O(n_sys*m^2) rows — same order as the psum budget, which still
        # bounds them via max_allgather_bytes below.
        dmd_shapes = set(t.buffer_shapes)
        hits = [s for s in H.allgather_shapes(t.hlo) if s in dmd_shapes]
        if hits:
            vs.append(Violation(
                "collective-budget", name,
                f"all-gather materializes a snapshot-buffer-shaped tensor "
                f"({sorted(set(hits))}): sharded DMD must psum "
                "O(n_sys*m^2) Gram partials, never gather a buffer"))
        if name not in _UNBUDGETED:
            ag = H.max_allgather_bytes(t.hlo)
            if min_buf is not None and ag >= min_buf:
                vs.append(Violation(
                    "collective-budget", name,
                    f"buffer-sized all-gather ({ag} B >= smallest "
                    f"snapshot buffer {min_buf} B) in a DMD-only program"
                    " (no model forward to justify it)"))
            ar = totals.get("all-reduce", 0)
            if ar > budget:
                vs.append(Violation(
                    "collective-budget", name,
                    f"all-reduce volume {ar} B exceeds the analytic Gram "
                    f"psum budget {budget} B (O(n_sys*m^2) fp32 words "
                    f"x{PSUM_SLACK} slack)"))
    return vs, info


# ---------------------------------------------------------------------------
# trace-budget
# ---------------------------------------------------------------------------

@register_pass(
    "trace-budget",
    "jaxpr equation / kernel-launch counts within the pinned ceilings")
def trace_budget(ctx):
    from repro import trace
    from repro.audit import pins

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    for name, t in sorted(ctx.targets.items()):
        n = trace.count_eqns(t.jaxpr)
        launches = trace.count_launch_ops(t.jaxpr)
        info[f"{name}.eqns"] = n
        info[f"{name}.launches"] = launches
        pin = pins.trace_ceiling(ctx.config_key, name)
        if pin is None:
            info[f"{name}.pin"] = "none (unpinned config: counts are info)"
            continue
        info[f"{name}.pin"] = dict(pin)
        if "eqns" in pin and n > pin["eqns"]:
            vs.append(Violation(
                "trace-budget", name,
                f"{n} jaxpr equations > pinned ceiling {pin['eqns']} for "
                f"{ctx.config_key} — trace growth regression (see "
                "repro/audit/pins.py for the bump procedure)"))
        if "launches" in pin and launches > pin["launches"]:
            vs.append(Violation(
                "trace-budget", name,
                f"{launches} launch-class ops > pinned ceiling "
                f"{pin['launches']} for {ctx.config_key}"))
    return vs, info


# ---------------------------------------------------------------------------
# solve-budget
# ---------------------------------------------------------------------------

# Targets that trace the jump's coefficient solves. The fused train_step
# never solves (record + streaming Gram only) and stays out of scope.
_SOLVE_TARGETS = ("dmd_step", "dmd_step_gated")


def solve_budget_rows(ctx) -> int:
    """Analytic per-jump solve budget: how many dmd_coefficients systems
    one full jump (every group) may batch under ``cfg.scope``. Leaf scope
    solves one system per packed system plus one per unpacked per-leaf
    system; bucket scope collapses every bucket-scoped bucket to ONE
    shared Koopman operator (DESIGN.md §9), so its contribution is
    ``gram_lead(scope)`` — 1 per bucket (sys-sharded buckets stay
    per-system)."""
    from repro.core.arena import arena_paths
    from repro.core.leafplan import plan_entries

    scope = getattr(ctx.cfg, "scope", "leaf")
    total = sum(b.gram_lead(scope) for b in ctx.arena.values())
    packed = arena_paths(ctx.arena)
    for p in plan_entries(ctx.plans):
        if p.path in packed:
            continue
        total += _prod(p.shape[:p.stack_dims]) if p.stack_dims else 1
    return total


def _batch_rows(aval) -> int:
    shape = getattr(aval, "shape", ())
    return _prod(shape[:-2]) if len(shape) >= 2 else 1


@register_pass(
    "solve-budget",
    "batched coefficient-solve rows (POD eigh / eig host-callback) per "
    "jump within the dmd.scope budget — bucket scope: one per bucket")
def solve_budget(ctx):
    """Counts the BATCH rows of the solve primitives in the traced jump,
    not the equation count: dmd_coefficients runs one eigh over the
    (n, m, m) Gram stack (the POD basis both modes share) and, in eig
    mode, one pure_callback over the (n, r, r) Atilde stack — ``n`` IS
    the number of systems solved and the eig callback's host batch. A
    silent fallback to per-leaf solves under ``scope="bucket"`` keeps the
    eqn count identical and only the rows give it away."""
    from repro import trace

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    budget = solve_budget_rows(ctx)
    info["solve_budget_rows"] = budget
    info["scope"] = getattr(ctx.cfg, "scope", "leaf")

    def eigh_rows(eqn) -> int:
        return _batch_rows(eqn.invars[0].aval) \
            if str(eqn.primitive) == "eigh" else 0

    def callback_rows(eqn) -> int:
        return _batch_rows(eqn.invars[-1].aval) \
            if "callback" in str(eqn.primitive) else 0

    for name in _SOLVE_TARGETS:
        t = ctx.targets.get(name)
        if t is None:
            continue
        ne = trace.sum_eqns(t.jaxpr, eigh_rows)
        nc = trace.sum_eqns(t.jaxpr, callback_rows)
        info[f"{name}.eigh_rows"] = ne
        info[f"{name}.callback_rows"] = nc
        for kind, n in (("POD eigh", ne), ("eig host-callback", nc)):
            if n > budget:
                vs.append(Violation(
                    "solve-budget", name,
                    f"{n} {kind} rows > per-jump solve budget {budget} "
                    f"(scope={info['scope']}): the jump batches more "
                    "coefficient systems than the scope allows — a "
                    "bucket-scoped bucket fell back to per-leaf solves"))
    return vs, info


# ---------------------------------------------------------------------------
# dtype-flow
# ---------------------------------------------------------------------------

def _twin(shape: str, dtype: str) -> str:
    return dtype + "[" + shape.split("[", 1)[1]


@register_pass(
    "dtype-flow",
    "no silent fp32<->bf16 casts on Gram or snapshot-buffer tensors")
def dtype_flow(ctx):
    import jax.numpy as jnp

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    snap_bf16 = jnp.dtype(ctx.cfg.snapshot_dtype) == jnp.bfloat16
    upcast_ok = bool(getattr(ctx.cfg, "gram_upcast", True))
    info["snapshot_dtype"] = str(jnp.dtype(ctx.cfg.snapshot_dtype))
    info["gram_upcast"] = upcast_ok
    for name, t in sorted(ctx.targets.items()):
        converts = H.convert_ops(t.hlo)
        info[f"{name}.converts"] = len(converts)
        for res, opnd in converts:
            # Grams are pinned fp32 end-to-end: any downcast is an error.
            if opnd in t.gram_shapes and res == _twin(opnd, "bf16"):
                vs.append(Violation(
                    "dtype-flow", name,
                    f"Gram tensor downcast {opnd} -> {res}: Grams must "
                    "stay fp32 (accumulated inner products)"))
            if opnd not in t.buffer_shapes:
                continue
            if not snap_bf16 and res == _twin(opnd, "bf16"):
                vs.append(Violation(
                    "dtype-flow", name,
                    f"snapshot buffer downcast {opnd} -> {res} with "
                    "snapshot_dtype=float32 (silent precision loss)"))
            if snap_bf16 and not upcast_ok and res == _twin(opnd, "f32"):
                vs.append(Violation(
                    "dtype-flow", name,
                    f"whole-buffer upcast {opnd} -> {res} with "
                    "gram_upcast=False: the bf16 path must accumulate in "
                    "f32 WITHOUT materializing an f32 buffer copy"))
    return vs, info


# ---------------------------------------------------------------------------
# host-callback-in-hot-loop
# ---------------------------------------------------------------------------

@register_pass(
    "host-callback-in-hot-loop",
    "no pure_callback/io_callback in jitted steps (eig-mode jump whitelisted)")
def host_callback_in_hot_loop(ctx):
    from repro import trace

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    eig = ctx.cfg.mode == "eig"

    def is_cb(eqn) -> bool:
        return "callback" in str(eqn.primitive)

    for name, t in sorted(ctx.targets.items()):
        n = trace.count_eqns(t.jaxpr, is_cb)
        info[f"{name}.callbacks"] = n
        if n == 0:
            continue
        if eig and name.startswith("dmd_step"):
            info[f"{name}.whitelist"] = ("eig-mode batched eigensolve "
                                         "(core/dmd.py::_host_eig)")
            continue
        vs.append(Violation(
            "host-callback-in-hot-loop", name,
            f"{n} host callback(s) in a jitted hot-loop program — each "
            "forces a device->host sync per call (only the eig-mode "
            "batched eigensolve inside dmd_step is whitelisted)"))
    return vs, info


# ---------------------------------------------------------------------------
# arena-layout
# ---------------------------------------------------------------------------

@register_pass(
    "arena-layout",
    "128-lane alignment, no system-straddling blocks, offset table "
    "consistent with the LeafPlan pytree, eligibility partition exact")
def arena_layout(ctx):
    from repro.core.arena import arena_eligible, arena_paths
    from repro.core.leafplan import plan_entries

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    entries = plan_entries(ctx.plans)
    by_path = {p.path: p for p in entries}
    packed = arena_paths(ctx.arena)
    info["n_leaves"] = len(entries)
    info["n_packed"] = len(packed)
    info["n_buckets"] = len(ctx.arena)

    # Eligibility partition: packed iff eligible. Since the residency PR,
    # anchor=mean leaves pack (the full-recompute gram kernel fuses the
    # mean subtraction) and leading-dim sharded-stack leaves pack into
    # single-segment system-sharded buckets; only the dot_general route
    # and non-leading sharded stack dims stay excluded. Every excluded
    # leaf must still carry a valid per-leaf plan (it trains through the
    # per-leaf route, not silently dropped).
    for p in entries:
        elig = arena_eligible(p, ctx.cfg, ctx.mesh)
        if elig and p.path not in packed:
            vs.append(Violation(
                "arena-layout", p.path,
                "arena-eligible leaf missing from every ArenaBucket "
                "(pays per-leaf dispatch it shouldn't)"))
        if not elig and p.path in packed:
            vs.append(Violation(
                "arena-layout", p.path,
                f"ineligible leaf packed into an arena (route={p.route}, "
                f"anchor={ctx.cfg.anchor}, sharded={p.sharded}) — the "
                "dot_general route and non-leading sharded stack dims "
                "cannot run the segmented kernels"))
        if p.path not in packed:
            if p.route not in ROUTES:
                vs.append(Violation("arena-layout", p.path,
                                    f"unknown per-leaf route {p.route!r}"))
            if p.sched is None or p.m < 2:
                vs.append(Violation(
                    "arena-layout", p.path,
                    f"per-leaf plan has no usable window (m={p.m})"))
            if p.route != "dot_general" and p.block_n % 128 != 0:
                vs.append(Violation(
                    "arena-layout", p.path,
                    f"per-leaf block_n={p.block_n} is not a 128-lane "
                    "multiple"))

    seen: Dict[str, str] = {}
    for key in sorted(ctx.arena):
        b = ctx.arena[key]
        where = f"arena[{key}]"
        if b.block_n <= 0 or b.block_n % 128 != 0:
            vs.append(Violation(
                "arena-layout", where,
                f"block_n={b.block_n} is not a positive 128-lane multiple"))
        sys_cursor = lane_cursor = 0
        for s in b.segments:
            seg_where = f"{where}:{s.path}"
            if s.path in seen:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"leaf packed twice (also in {seen[s.path]})"))
            seen[s.path] = key
            plan = by_path.get(s.path)
            if plan is None:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    "segment has no LeafPlan (stale offset table)"))
            elif (tuple(s.shape) != tuple(plan.shape)
                  or s.stack_dims != plan.stack_dims
                  or s.param_dtype != plan.dtype
                  or b.group != plan.group):
                vs.append(Violation(
                    "arena-layout", seg_where,
                    "segment disagrees with the LeafPlan table "
                    f"(shape {tuple(s.shape)} vs {tuple(plan.shape)}, "
                    f"stack {s.stack_dims} vs {plan.stack_dims}, dtype "
                    f"{s.param_dtype} vs {plan.dtype}, group {b.group} "
                    f"vs {plan.group})"))
            if s.sys_start != sys_cursor:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"sys_start={s.sys_start}, expected {sys_cursor} "
                    "(non-contiguous system packing)"))
            if s.lane_start != lane_cursor:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"lane_start={s.lane_start}, expected {lane_cursor} "
                    "(offset table out of step with segment lengths)"))
            if b.block_n > 0 and s.lane_start % b.block_n != 0:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"lane_start={s.lane_start} not aligned to "
                    f"block_n={b.block_n}: a block would straddle the "
                    "previous system"))
            if b.block_n > 0 and s.seg_lanes % b.block_n != 0:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"seg_lanes={s.seg_lanes} not a block_n={b.block_n} "
                    "multiple (block straddles the next system)"))
            want = _prod(s.local_shape[s.stack_dims:])
            if s.flat_local != want:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"flat_local={s.flat_local} != prod(local_shape"
                    f"[stack:])={want}"))
            if s.seg_lanes < s.flat_local:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"seg_lanes={s.seg_lanes} < flat_local="
                    f"{s.flat_local}: lanes would be truncated"))
            n_sys_want = _prod(s.local_shape[:s.stack_dims]) or 1
            if s.n_sys != n_sys_want:
                vs.append(Violation(
                    "arena-layout", seg_where,
                    f"n_sys={s.n_sys} != prod(stack shape)={n_sys_want}"))
            sys_cursor += s.n_sys
            lane_cursor += s.n_sys * s.seg_lanes
        if lane_cursor != b.n_lanes_local:
            vs.append(Violation(
                "arena-layout", where,
                f"segment lanes sum to {lane_cursor} but the bucket "
                f"carries n_lanes_local={b.n_lanes_local}"))
    return vs, info


# ---------------------------------------------------------------------------
# arena-residency
# ---------------------------------------------------------------------------

# The pack-copy signature lives in the DATA passes: the fused step's record
# arm and the standalone record_update. The jump programs legitimately
# build bucket-sized 1-D rows (core/arena.py::jump combines modes into one
# flat row per bucket) and stay out of scope.
_RESIDENCY_TARGETS = ("train_step", "record_update")


@register_pass(
    "arena-residency",
    "resident params: record is one dynamic_update_slice per bucket — no "
    "bucket-sized 1-D pack concatenate/gather in the data passes")
def arena_residency(ctx):
    """With arena-native residency on (dmd.arena_native, DESIGN.md §7) the
    managed params LIVE in the flat (N,) buckets, so recording a snapshot
    never re-packs leaves: a bucket-sized 1-D concatenate/gather in the
    traced step means the pack-copy route leaked back in and the PR-5 cost
    (one full gather per record) is being paid silently.

    Checked on the JAXPR, not the optimized HLO: XLA may rewrite the
    view-gradient pad+add chains into concatenates, which are harmless —
    the jaxpr shows what the program asked for, not what the compiler
    canonicalized it into.
    """
    from repro import trace
    from repro.core import arena as arena_mod

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    resident = bool(ctx.state is not None
                    and arena_mod.is_arena_state(
                        getattr(ctx.state, "params", None)))
    native = bool(getattr(ctx.cfg, "arena_native", True))
    info["resident"] = resident
    info["arena_native"] = native
    if not resident:
        # Consistency: if residency is configured, buckets exist, and the
        # optimizer supports flat-buffer updates, the audited state MUST
        # be resident — otherwise every pass below lowered programs that
        # training never runs.
        from repro.train.step import RESIDENT_OPTIMIZERS
        opt = getattr(getattr(ctx.acfg, "optimizer", None), "name", None)
        info["optimizer"] = opt
        if native and ctx.arena and opt in RESIDENT_OPTIMIZERS:
            vs.append(Violation(
                "arena-residency", "state",
                f"arena_native on, optimizer {opt!r} supports residency "
                "and buckets exist, but the audited TrainState is NOT "
                "resident — the audit is lowering a layout training "
                "never executes (targets.py must apply state_resident)"))
        return vs, info
    if not ctx.arena:
        return vs, info

    floor = min(b.n_lanes for b in ctx.arena.values())
    info["min_bucket_lanes"] = floor

    def is_pack(eqn) -> bool:
        # The pack gather is a 1-D concatenate of per-leaf flats into a
        # bucket row (core/arena.py::pack_row). Model-side concatenates
        # are >=2-D activations; slices/views transpose to pads, not
        # concatenates — so "1-D and bucket-sized" is the signature.
        if str(eqn.primitive) not in ("concatenate", "gather"):
            return False
        shape = getattr(eqn.outvars[0].aval, "shape", ())
        return len(shape) == 1 and _prod(shape) >= floor

    for name in _RESIDENCY_TARGETS:
        t = ctx.targets.get(name)
        if t is None:
            continue
        n = trace.count_eqns(t.jaxpr, is_pack)
        info[f"{name}.pack_ops"] = n
        if n:
            vs.append(Violation(
                "arena-residency", name,
                f"{n} bucket-sized 1-D concatenate/gather op(s) traced "
                "with RESIDENT params: record must degenerate to one "
                "dynamic_update_slice per bucket (the pack-copy route "
                "leaked back in — core/arena.py::record resident branch)"))
    return vs, info


# ---------------------------------------------------------------------------
# schedule-conflict
# ---------------------------------------------------------------------------

@register_pass(
    "schedule-conflict",
    "no overlapping group rules, no phase-residue collisions between "
    "staggered groups, resolved table within clamps")
def schedule_conflict(ctx):
    from repro.core.leafplan import plan_entries
    from repro.core.schedule import jump_collisions, rules_for_config

    vs: List[Violation] = []
    info: Dict[str, object] = {}
    groups = list(ctx.groups)
    info["n_groups"] = len(groups)

    for g in groups:
        where = f"group[{g.index}:{g.name}]"
        if g.m < 2:
            vs.append(Violation("schedule-conflict", where,
                                f"m={g.m}: DMD needs >= 2 snapshots"))
        if g.s < 1:
            vs.append(Violation("schedule-conflict", where,
                                f"s={g.s}: horizon must be >= 1"))
        if min(g.warmup_steps, g.cooldown_steps, g.phase) < 0:
            vs.append(Violation(
                "schedule-conflict", where,
                f"negative schedule field (warmup={g.warmup_steps}, "
                f"cooldown={g.cooldown_steps}, phase={g.phase})"))
        if g.cycle != g.m + g.cooldown_steps:
            vs.append(Violation(
                "schedule-conflict", where,
                f"cycle={g.cycle} != m+cooldown={g.m + g.cooldown_steps}"))
        if not (0.0 <= g.energy <= 1.0):   # 0.0 = unset (tol mask rules)
            vs.append(Violation(
                "schedule-conflict", where,
                f"energy={g.energy} outside [0, 1]"))
        ridge = float(getattr(g, "ridge", 0.0))
        if not (ridge >= 0.0 and math.isfinite(ridge)):
            vs.append(Violation(
                "schedule-conflict", where,
                f"ridge={ridge} must be finite and >= 0"))

    # Controller-key clamps (ISSUE 9): the gated step trusts these at
    # trace time — an unsatisfiable gate or an empty/out-of-range shrink
    # ladder is a config bug the first jump would hit at runtime.
    ccfg = getattr(ctx.cfg, "controller", None)
    if ccfg is not None and getattr(ccfg, "enabled", False):
        rmax = float(getattr(ccfg, "ridge_max", 0.0))
        levels = tuple(getattr(ccfg, "shrink_levels", (0.5,)) or ())
        info["controller"] = {
            "accept_tol": float(ccfg.accept_tol), "ridge_max": rmax,
            "shrink_levels": [float(f) for f in levels],
            "meta_lr": float(getattr(ccfg, "meta_lr", 0.0)),
            "val_gate": bool(getattr(ccfg, "val_gate", False)),
        }
        if float(ccfg.accept_tol) <= -1.0:
            vs.append(Violation(
                "schedule-conflict", "controller",
                f"accept_tol={ccfg.accept_tol} <= -1: the gate can never "
                "accept a positive-loss jump (every round rolls back)"))
        if not levels:
            vs.append(Violation(
                "schedule-conflict", "controller",
                "shrink_levels is empty: the SCALED branch has no rungs"))
        for f in levels:
            if not 0.0 < float(f) < 1.0:
                vs.append(Violation(
                    "schedule-conflict", "controller",
                    f"shrink_levels entry {f} outside (0, 1)"))
        if not (rmax >= 0.0 and math.isfinite(rmax)):
            vs.append(Violation(
                "schedule-conflict", "controller",
                f"ridge_max={rmax} must be finite and >= 0"))
        mlr = float(getattr(ccfg, "meta_lr", 0.0))
        if not (0.0 <= mlr <= 1.0):
            vs.append(Violation(
                "schedule-conflict", "controller",
                f"meta_lr={mlr} outside [0, 1] (EMA step)"))
        for g in groups:
            ridge = float(getattr(g, "ridge", 0.0))
            if rmax > 0 and ridge > rmax:
                vs.append(Violation(
                    "schedule-conflict", f"group[{g.index}:{g.name}]",
                    f"ridge={ridge} above controller.ridge_max={rmax}: the "
                    "meta-tuner would clamp it down on the first round",
                    severity="warning"))

    # Overlapping non-exclude rules: first-match-wins makes the second
    # rule dead for every shared leaf — a config bug, not a tiebreak.
    rules = [r for r in rules_for_config(ctx.cfg) if not r.exclude]
    overlaps = 0
    for p in plan_entries(ctx.plans):
        ndim, size = len(p.shape), _prod(p.shape)
        hits = [r.name for r in rules if r.matches(p.path, ndim, size)]
        if len(hits) > 1:
            overlaps += 1
            vs.append(Violation(
                "schedule-conflict", p.path,
                f"{len(hits)} group rules match one leaf "
                f"({', '.join(hits)}): all but the first are dead here"))
    info["overlapping_leaves"] = overlaps

    # Member counts: a rule-defined group no leaf selects is dead config.
    members = [0] * len(groups)
    for p in plan_entries(ctx.plans):
        if p.group is not None and 0 <= p.group < len(groups):
            members[p.group] += 1
    info["group_members"] = members
    for g, n in zip(groups, members):
        if n == 0 and g.index > 0:
            vs.append(Violation(
                "schedule-conflict", f"group[{g.index}:{g.name}]",
                "group rule matches no leaf (dead group)",
                severity="warning"))

    # Phase-residue collisions (CRT): an ERROR only between groups that
    # DECLARED distinct phases — they opted into staggering and the
    # config fails to deliver it. Same-phase collisions (the synchronous
    # default) are reported as info.
    pairs = jump_collisions(groups)
    info["jump_collisions"] = [list(p) for p in pairs]
    for ia, ib in pairs:
        a, b = groups[ia], groups[ib]
        if a.phase != b.phase:
            ra = (a.warmup_steps + a.phase + a.cycle - 1) % a.cycle
            rb = (b.warmup_steps + b.phase + b.cycle - 1) % b.cycle
            vs.append(Violation(
                "schedule-conflict",
                f"group[{a.index}:{a.name}]+group[{b.index}:{b.name}]",
                f"declared distinct phases ({a.phase} vs {b.phase}) but "
                f"jump residues collide (r={ra} mod {a.cycle} meets "
                f"r={rb} mod {b.cycle}, gcd={math.gcd(a.cycle, b.cycle)})"
                " — the stagger never takes effect"))
    return vs, info


# ---------------------------------------------------------------------------
# serve-compile
# ---------------------------------------------------------------------------

@register_pass(
    "serve-compile",
    "serve engine compiles <= bucket ceiling, zero steady recompiles, "
    "donated copy-free decode")
def serve_compile(ctx):
    """The serving engine's compile + donation contract (DESIGN.md §10).

    Over ``ctx.serve`` (attached by repro.serve.audit.attach_serve):

      * the AOT program registry never exceeds the analytic bucket
        ceiling (1 decode + prefill per prompt x batch bucket + insert
        per batch bucket + the ParamStore landing copy);
      * ZERO compiles after ``mark_steady()`` — steady state serves from
        the warm registry, a recompile means a shape leaked past the
        bucket policy (the ``force-recompile`` mutation's exact-length
        "buckets" are the seeded violation);
      * the engine dropped no requests while doing it.

    Over the ``serve_decode`` target (the compiled decode program): every
    slot-stacked cache leaf aliases input->output (donated decode state)
    and no cache-shaped copy survives compilation — same invariant the
    donation-alias pass pins for serve_fns, here for the slot table.
    """
    vs: List[Violation] = []
    info: Dict[str, object] = {}
    s = getattr(ctx, "serve", None)
    if not s:
        info["note"] = ("no serving build attached — run the CLI with "
                        "--serve")
        return vs, info
    info.update(s)
    if s.get("skipped"):
        return vs, info

    if int(s["n_programs"]) > int(s["max_programs"]):
        vs.append(Violation(
            "serve-compile", "registry",
            f"{s['n_programs']} compiled programs exceed the bucket "
            f"ceiling {s['max_programs']} ({s['n_prompt_buckets']} prompt "
            f"x {s['n_batch_buckets']} batch buckets): some shape is not "
            "bucketed"))
    if int(s["steady_compiles"]) > 0:
        vs.append(Violation(
            "serve-compile", "registry",
            f"{s['steady_compiles']} compiles AFTER warmup: steady state "
            "must serve entirely from the warm program registry"))
    if int(s.get("dropped", 0)) > 0:
        vs.append(Violation(
            "serve-compile", "engine",
            f"{s['dropped']} requests dropped during the audit workload"))

    t = ctx.targets.get("serve_decode")
    if t is not None:
        copies = H.copy_ops(t.hlo, t.buffer_shapes)
        info["decode_cache_copies"] = len(copies)
        if copies:
            vs.append(Violation(
                "serve-compile", "serve_decode",
                f"{len(copies)} cache-shaped copies in the compiled "
                f"decode (e.g. {copies[0]}): the slot-stacked KV update "
                "is not in-place"))
        ac = H.alias_count(t.hlo)
        info["decode_alias_count"] = ac
        if ac < t.n_dmd_leaves:
            vs.append(Violation(
                "serve-compile", "serve_decode",
                f"only {ac} input->output aliases for {t.n_dmd_leaves} "
                "slot-stacked cache leaves: decode state donation "
                "dropped"))
    return vs, info
