"""Pinned trace-size ceilings for the trace-budget pass.

A pin is a hard ceiling on the jaxpr equation count (and optionally the
launch-class op count, trace.LAUNCH_PRIMS) of ONE audit target under ONE
config key (``AuditContext.config_key`` — arch name plus ``-reduced`` /
``-mesh`` suffixes). Unpinned (config, target) pairs report their counts
as info and never fail: pins are opt-in, per config we actually gate in CI.

Measured values (jax 0.4.37, CPU lowering) are noted next to each ceiling;
ceilings carry ~25-40% headroom over measured so routine jax upgrades
don't trip them.

Bump procedure (DESIGN.md §8): a legitimate trace growth (new fused
feature, jax version bump) raises a ceiling in THIS file, in the same PR
as the change that grew the trace, with the newly measured count in the
comment. Never bump to "make CI green" without knowing which equations
appeared — run ``python -m repro.audit --arch <arch> --reduced`` and diff
the per-target counts first.
"""
from __future__ import annotations

from typing import Dict, Optional

# {config_key: {target: {"eqns": ceiling, "launches": ceiling}}}
#
# Re-measured for arena-native residency (dmd.arena_native): the fused
# step's record arm is one dynamic_update_slice per bucket instead of the
# pack concatenate, so counts DROPPED everywhere except the gated jump
# (whose three gate-loss evals each expand the leaf views). Ceilings sit
# below the PACK-COPY route's measured counts where one exists, so a
# fallback to the PR-5 route fails the pin before any slack is eaten.
TRACE_PINS: Dict[str, Dict[str, Dict[str, int]]] = {
    # Reduced tinyllama (the tier-1 audit model): train_step measured 723
    # resident vs 870 pack-copy vs 1137 per-leaf.
    "tinyllama-1.1b-reduced": {
        "train_step": {"eqns": 850},        # measured 723 (pack-copy: 870)
        "dmd_step": {"eqns": 450},          # measured 309 (groups=None)
        "dmd_step_gated": {"eqns": 1450},   # measured 1191
        "record_update": {"eqns": 135},     # measured 99 (pack-copy: 140)
    },
    # The paper's pollutant MLP (PAPER_SIZES, m=14, mode=eig, anchor=none).
    "pollutant-mlp": {
        "train_step": {"eqns": 340},        # measured 258 (pack-copy: 355)
        "dmd_step": {"eqns": 450},          # measured 308
        "dmd_step_gated": {"eqns": 850},    # measured 621
        "record_update": {"eqns": 80},      # measured 44 (pack-copy: 85)
    },
    "pollutant-mlp-reduced": {
        "train_step": {"eqns": 270},        # measured 214 (pack-copy: 280)
        "dmd_step": {"eqns": 450},          # measured 298
        "dmd_step_gated": {"eqns": 800},    # measured 566
        "record_update": {"eqns": 70},      # measured 44 (pack-copy: 72)
    },
    # tests/test_trace_size.py's bespoke 24-layer MLP (48 DMD leaves, one
    # bucket; m=6): measured 1143 resident vs 1731 pack-copy vs 2906
    # per-leaf.
    "deep-mlp-24x32": {
        "train_step": {"eqns": 1500},
    },
    # Bucket-scope Koopman DMD (dmd.scope="bucket", DESIGN.md §9) on the
    # same reduced tinyllama build (tests/test_trace_size.py): train_step
    # is eqn-identical to leaf scope (the data passes only swap the static
    # block->system table) and the jump shrinks slightly. Eqn pins alone
    # CANNOT catch a silent fallback to per-leaf solves — the batched
    # eigh is one equation either way (21 rows leaf vs 2 rows == n_buckets
    # bucket here); the solve-budget pass owns that guard and the same
    # test routes the jump through it.
    "tinyllama-1.1b-reduced-bucket": {
        "train_step": {"eqns": 850},        # measured 723 (== leaf scope)
        "dmd_step": {"eqns": 430},          # measured 297 (leaf scope: 309)
    },
}


def trace_ceiling(config_key: str, target: str) -> Optional[Dict[str, int]]:
    """The pinned ceilings for one (config, target), or None if unpinned."""
    return TRACE_PINS.get(config_key, {}).get(target)
