"""Pinned trace-size ceilings for the trace-budget pass.

A pin is a hard ceiling on the jaxpr equation count (and optionally the
launch-class op count, trace.LAUNCH_PRIMS) of ONE audit target under ONE
config key (``AuditContext.config_key`` — arch name plus ``-reduced`` /
``-mesh`` suffixes). Unpinned (config, target) pairs report their counts
as info and never fail: pins are opt-in, per config we actually gate in CI.

Measured values (jax 0.4.37, CPU lowering) are noted next to each ceiling;
ceilings carry ~25-40% headroom over measured so routine jax upgrades
don't trip them.

Bump procedure (DESIGN.md §8): a legitimate trace growth (new fused
feature, jax version bump) raises a ceiling in THIS file, in the same PR
as the change that grew the trace, with the newly measured count in the
comment. Never bump to "make CI green" without knowing which equations
appeared — run ``python -m repro.audit --arch <arch> --reduced`` and diff
the per-target counts first.
"""
from __future__ import annotations

from typing import Dict, Optional

# {config_key: {target: {"eqns": ceiling, "launches": ceiling}}}
TRACE_PINS: Dict[str, Dict[str, Dict[str, int]]] = {
    # Reduced tinyllama (the tier-1 audit model): train_step ceiling is
    # the historical tests/test_trace_size.py pin (measured 870 arena-on
    # vs 1137 per-leaf at PR 5 — the pin sits BELOW the per-leaf count so
    # a route regression fails before slack is eaten).
    "tinyllama-1.1b-reduced": {
        "train_step": {"eqns": 1100},       # measured 870
        "dmd_step": {"eqns": 550},          # measured 375 (groups=None)
        "dmd_step_gated": {"eqns": 1450},   # measured 1193
        "record_update": {"eqns": 250},     # measured 140
    },
    # The paper's pollutant MLP (PAPER_SIZES, m=14, mode=eig, anchor=none).
    "pollutant-mlp": {
        "train_step": {"eqns": 500},        # measured 355
        "dmd_step": {"eqns": 500},          # measured 336
        "dmd_step_gated": {"eqns": 850},    # measured 574
        "record_update": {"eqns": 150},     # measured 85
    },
    "pollutant-mlp-reduced": {
        "train_step": {"eqns": 450},        # measured 280
        "dmd_step": {"eqns": 500},          # measured 318
        "dmd_step_gated": {"eqns": 800},    # measured 529
        "record_update": {"eqns": 150},     # measured 72
    },
    # tests/test_trace_size.py's bespoke 24-layer MLP (48 DMD leaves, one
    # bucket; m=6): measured 1731 arena vs 2906 per-leaf at PR 5.
    "deep-mlp-24x32": {
        "train_step": {"eqns": 2200},
    },
}


def trace_ceiling(config_key: str, target: str) -> Optional[Dict[str, int]]:
    """The pinned ceilings for one (config, target), or None if unpinned."""
    return TRACE_PINS.get(config_key, {}).get(target)
