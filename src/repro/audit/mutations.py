"""Seeded violations: named mutations that break ONE audited invariant.

The audit lane is only trustworthy if it is known to bite — each mutation
here injects exactly the defect its pass exists to catch, and
tests/test_audit.py (plus the CI mutation step) asserts the mutated build
exits nonzero while the clean build stays green:

  drop-donation    compile every step without donate_argnums
                   -> donation-alias fails (empty alias table + copies)
  force-allgather  reshard arena buffers sharded->replicated inside
                   record_update (needs --mesh) -> collective-budget fails
                   (buffer-sized all-gather)
  misalign-arena   shift one ArenaSegment's lane_start off the block grid
                   -> arena-layout fails (alignment + contiguity)
  force-pack       expand resident params leaf-wise inside record_update
                   so the PR-5 pack-copy concatenate reappears
                   -> arena-residency fails (bucket-sized 1-D gather)
  force-leaf-solves bucket-scope build whose dmd_step still batches one
                   coefficient system per leaf -> solve-budget fails
                   (eigh/callback rows exceed the one-per-bucket budget)
  overlap-groups   add two match-everything group rules with distinct
                   phases -> schedule-conflict fails (overlap; and if the
                   residues still collide, the stagger check too)
  force-recompile  degrade the serve engine's prompt buckets to exact
                   lengths (every novel length compiles a fresh prefill)
                   -> serve-compile fails (steady-state compiles > 0 and
                   registry above the bucket ceiling)

Mutations compose with ``build_context`` at four seams: ``config``
rewrites the ArchConfig before anything is built, ``donate`` feeds
``audit_step_fns``, ``wrap_fns`` replaces jitted entry points, ``post``
edits the static tables after the build (for table-only passes), and
``serve``/``serve_cfg`` attach + rewrite the serving-engine build
(repro.serve.audit.attach_serve).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional


@dataclass(frozen=True)
class Mutation:
    name: str
    doc: str
    expect_fail: str                     # the pass this mutation must trip
    donate: bool = True
    needs_mesh: bool = False
    config: Optional[Callable] = None    # acfg -> acfg
    wrap_fns: Optional[Callable] = None  # (acc, fns, mesh) -> fns
    post: Optional[Callable] = None      # ctx -> None
    serve: bool = False                  # attach the serving-engine build
    serve_cfg: Optional[Callable] = None  # ServeConfig -> ServeConfig


_REGISTRY: Dict[str, Mutation] = {}


def _register(m: Mutation) -> Mutation:
    _REGISTRY[m.name] = m
    return m


def get(name: str) -> Mutation:
    if name not in _REGISTRY:
        raise KeyError(f"unknown mutation {name!r}; have "
                       f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_mutations():
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------

_register(Mutation(
    name="drop-donation",
    doc="compile train/dmd/record steps with donate_argnums=()",
    expect_fail="donation-alias",
    donate=False))


def _force_allgather_fns(acc, fns, mesh):
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import arena as arena_mod

    if mesh is None:
        raise ValueError("force-allgather needs --mesh (a sharded build): "
                         "on one device there is nothing to gather")

    def record_update(buffers, grams, params, slots):
        if arena_mod.is_arena_state(buffers):
            arenas, leaf = arena_mod.split_state(buffers)
            gathered = {}
            for key, buf in arenas.items():
                b = acc._arena_table()[key]
                if b.lane_axes:
                    # block-shard, then demand the replicated buffer back:
                    # GSPMD must materialize a full-buffer all-gather.
                    buf = jax.lax.with_sharding_constraint(
                        buf, NamedSharding(mesh, b.buffer_spec()))
                    buf = jax.lax.with_sharding_constraint(
                        buf, NamedSharding(mesh, P()))
                gathered[key] = buf
            buffers = arena_mod.make_state(gathered, leaf)
        return acc.record(buffers, params, slots, grams)

    out = dict(fns)
    out["record_update"] = jax.jit(record_update, donate_argnums=(0, 1))
    return out


_register(Mutation(
    name="force-allgather",
    doc="reshard arena buffers sharded->replicated inside record_update",
    expect_fail="collective-budget",
    needs_mesh=True,
    wrap_fns=_force_allgather_fns))


def _misalign_arena(ctx) -> None:
    for key in sorted(ctx.arena):
        b = ctx.arena[key]
        if not b.segments:
            continue
        seg = dataclasses.replace(b.segments[-1],
                                  lane_start=b.segments[-1].lane_start + 1)
        ctx.arena[key] = dataclasses.replace(
            b, segments=b.segments[:-1] + (seg,))
        return
    raise ValueError("misalign-arena: no arena segments in this config "
                     "(dmd.arena off or every leaf excluded)")


_register(Mutation(
    name="misalign-arena",
    doc="shift one ArenaSegment.lane_start off the 128-lane block grid",
    expect_fail="arena-layout",
    post=_misalign_arena))


def _force_pack_fns(acc, fns, mesh):
    import jax

    from repro.core import arena as arena_mod

    def record_update(buffers, grams, params, slots):
        if not arena_mod.is_arena_state(params):
            raise ValueError(
                "force-pack needs a RESIDENT build (dmd.arena_native on "
                "with a resident-capable optimizer) — the audited state "
                "has per-leaf params, there is nothing to force back")
        # Expand the flat buckets to per-leaf tensors before recording:
        # acc.record sees leaf-wise params and falls back to the pack-copy
        # route, so the bucket-sized concatenate the arena-residency pass
        # bans reappears in the traced program.
        params = arena_mod.tree_leafwise(acc._arena_table(), params)
        return acc.record(buffers, params, slots, grams)

    out = dict(fns)
    out["record_update"] = jax.jit(record_update, donate_argnums=(0, 1))
    return out


_register(Mutation(
    name="force-pack",
    doc="expand resident params leaf-wise inside record_update (pack-copy "
        "route resurfaces)",
    expect_fail="arena-residency",
    wrap_fns=_force_pack_fns))


def _bucket_scope_config(acfg):
    return dataclasses.replace(
        acfg, dmd=dataclasses.replace(acfg.dmd, scope="bucket"))


def _force_leaf_solves_fns(acc, fns, mesh):
    import jax

    from repro.core.accelerator import _none_like, jump_tree
    from repro.train.state import TrainState

    # The silent-fallback defect in one seam: the build is bucket-scope
    # (budget = one solve per bucket) but the jump program still batches
    # one coefficient system per LEAF. Grams pass as None so the jump
    # recomputes them from the buffers with the leaf-scope block tables —
    # the state's (1, m, m) bucket Grams never shape-constrain the trace.
    # Only the ungated build mutates: the gated variant's donation pass
    # pins an EXACT whole-state alias table this plain-jump stand-in
    # cannot reproduce, and one tripped target is all the lane needs.
    if acc.controller_on:
        return fns
    leaf_cfg = dataclasses.replace(acc.cfg, scope="leaf")

    def dmd_step(state, relax, *extra, groups=None):
        plans = acc.plans_for(state.params)
        params, mean_rank = jump_tree(
            leaf_cfg, plans, state.params, state.dmd_buffers,
            _none_like(state.dmd_buffers), relax, groups=groups,
            arena=acc.arena_for(state.params))
        new_state = TrainState(params, state.opt_state, state.step,
                               state.dmd_buffers, state.dmd_gram,
                               state.controller)
        return new_state, {"mean_rank": mean_rank}

    out = dict(fns)
    out["dmd_step"] = jax.jit(dmd_step, static_argnames=("groups",),
                              donate_argnums=(0,))
    return out


_register(Mutation(
    name="force-leaf-solves",
    doc="bucket-scope build whose jump still batches one coefficient "
        "system per leaf (the silent per-leaf-solve fallback)",
    expect_fail="solve-budget",
    config=_bucket_scope_config,
    wrap_fns=_force_leaf_solves_fns))


def _overlap_groups(acfg):
    from repro.core.schedule import DMDGroupRule
    rules = (DMDGroupRule(name="overlap-a", path_regex="", phase=0),
             DMDGroupRule(name="overlap-b", path_regex="", phase=1))
    return dataclasses.replace(
        acfg, dmd=dataclasses.replace(acfg.dmd, groups=rules))


_register(Mutation(
    name="overlap-groups",
    doc="two match-everything group rules with distinct phases",
    expect_fail="schedule-conflict",
    config=_overlap_groups))


def _force_recompile_serve_cfg(scfg):
    # Exact-length prompt "buckets": each novel steady-state length
    # compiles a fresh prefill program, so steady_compiles > 0 and the
    # registry outgrows the analytic bucket ceiling.
    return dataclasses.replace(scfg, force_recompile=True)


_register(Mutation(
    name="force-recompile",
    doc="serve engine with exact-length prompt buckets (fresh prefill "
        "compile per novel steady-state length)",
    expect_fail="serve-compile",
    serve=True,
    serve_cfg=_force_recompile_serve_cfg))
