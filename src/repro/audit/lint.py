"""AST lint: ``PYTHONPATH=src python -m repro.audit.lint src/``.

Static source-level rules complementing the jaxpr/HLO passes — things
that are invisible after tracing because they already happened at trace
time. Scope is two-tier:

HOT modules (kernels/ + the jitted step/DMD core — see HOT_PREFIXES),
where trace-time host work either breaks under jit or silently bakes a
host value into the compiled program:

  host-time        time.time/perf_counter/monotonic/sleep, datetime.now —
                   a wall-clock read at trace time is a frozen constant
  host-callback    jax.pure_callback / io_callback / debug.callback
                   (whitelist: core/dmd.py, the eig-mode eigensolve — the
                   jaxpr-level pass checks where it may be CALLED from)
  host-sync        .item() / jax.device_get / .block_until_ready() —
                   device->host syncs inside kernel/step code
  nonstatic-shape  int(...)/float(...) wrapped around a jnp./jax. call —
                   concretizes a traced value at trace time
                   (ConcretizationTypeError under jit, or a silently
                   frozen shape/scalar)

EVERY module:

  unused-import    import debt (also enforced by ruff F401 in CI; this
                   rule keeps the check runnable in the hermetic test
                   container where ruff is not installed)

Exit code is nonzero iff any finding. ``# lint: allow-<rule>`` on the
offending line suppresses it (used sparingly; each use is greppable).
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path
from typing import List, Tuple

# Modules whose code runs (or is traced) inside the jitted hot loop.
HOT_PREFIXES = (
    "repro/kernels/",
    "repro/core/",
    "repro/train/step.py",
)
# The eig-mode batched eigensolve is the ONE sanctioned host callback.
CALLBACK_WHITELIST = ("repro/core/dmd.py",)

HOST_TIME = {("time", "time"), ("time", "perf_counter"),
             ("time", "monotonic"), ("time", "sleep"),
             ("datetime", "now"), ("datetime.datetime", "now")}
HOST_CALLBACK = {"pure_callback", "io_callback"}
HOST_SYNC = {"item", "block_until_ready", "device_get"}

Finding = Tuple[str, int, str, str]     # (file, line, rule, detail)


def _dotted(node) -> str:
    """'a.b.c' for an attribute/name chain, '' otherwise."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _allowed(src_lines: List[str], lineno: int, rule: str) -> bool:
    line = src_lines[lineno - 1] if 0 < lineno <= len(src_lines) else ""
    if f"lint: allow-{rule}" in line:
        return True
    # one comment serves both linters: a ruff-style noqa for the matching
    # code (F401 = unused import) suppresses the same rule here
    return rule == "unused-import" and "noqa" in line and "F401" in line


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str, src: str, hot: bool):
        self.rel = rel
        self.lines = src.splitlines()
        self.hot = hot
        self.findings: List[Finding] = []
        self.imports: dict = {}          # alias -> (lineno, col)
        self.used: set = set()

    def _add(self, node, rule: str, detail: str):
        if not _allowed(self.lines, node.lineno, rule):
            self.findings.append((self.rel, node.lineno, rule, detail))

    # -- unused-import bookkeeping ------------------------------------
    def visit_Import(self, node):
        for a in node.names:
            alias = a.asname or a.name.split(".")[0]
            self.imports.setdefault(alias, node.lineno)

    def visit_ImportFrom(self, node):
        for a in node.names:
            if a.name == "*":
                continue
            alias = a.asname or a.name
            self.imports.setdefault(alias, node.lineno)

    def visit_Name(self, node):
        if isinstance(node.ctx, ast.Load):
            self.used.add(node.id)

    def visit_Assign(self, node):
        # names re-exported via __all__ count as used (package façades)
        targets = [t.id for t in node.targets if isinstance(t, ast.Name)]
        if "__all__" in targets:
            for el in ast.walk(node.value):
                if isinstance(el, ast.Constant) and isinstance(el.value,
                                                               str):
                    self.used.add(el.value)
        self.generic_visit(node)

    # -- hot-module rules ---------------------------------------------
    def visit_Call(self, node):
        dotted = _dotted(node.func)
        leaf = dotted.rsplit(".", 1)[-1] if dotted else ""
        if self.hot:
            for mod, fn in HOST_TIME:
                if dotted == f"{mod}.{fn}":
                    self._add(node, "host-time",
                              f"{dotted}() at trace time is a frozen "
                              "host-clock read")
            if leaf in HOST_CALLBACK and not any(
                    self.rel.endswith(w) for w in CALLBACK_WHITELIST):
                self._add(node, "host-callback",
                          f"{dotted or leaf}() outside the eig whitelist "
                          f"({CALLBACK_WHITELIST[0]})")
            if leaf in HOST_SYNC and isinstance(node.func, ast.Attribute):
                self._add(node, "host-sync",
                          f".{leaf}() forces a device->host sync in a "
                          "hot module")
            if (isinstance(node.func, ast.Name)
                    and node.func.id in ("int", "float") and node.args):
                inner = node.args[0]
                if isinstance(inner, ast.Call):
                    d = _dotted(inner.func)
                    if d.startswith(("jnp.", "jax.")):
                        self._add(
                            node, "nonstatic-shape",
                            f"{node.func.id}({d}(...)) concretizes a "
                            "traced value at trace time — shape math in "
                            "kernel/step modules must be static Python "
                            "ints")
        self.generic_visit(node)

    def finish(self):
        for alias, lineno in sorted(self.imports.items(),
                                    key=lambda kv: kv[1]):
            if alias in self.used or alias == "_":
                continue
            if alias in ("annotations",):    # from __future__
                continue
            if not _allowed(self.lines, lineno, "unused-import"):
                self.findings.append(
                    (self.rel, lineno, "unused-import",
                     f"{alias!r} imported but unused"))


def lint_file(path: Path, root: Path) -> List[Finding]:
    rel = path.relative_to(root).as_posix() if root in path.parents \
        else path.as_posix()
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [(rel, e.lineno or 0, "syntax", str(e))]
    hot = any(rel.endswith(h) or f"/{h}" in rel or rel.startswith(h)
              for h in HOT_PREFIXES)
    v = _Visitor(rel, src, hot)
    v.visit(tree)
    v.finish()
    return v.findings


def lint_paths(paths) -> List[Finding]:
    findings: List[Finding] = []
    for p in paths:
        p = Path(p)
        root = p if p.is_dir() else p.parent
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f, root))
    return findings


def main(argv=None) -> int:
    args = argv if argv is not None else sys.argv[1:]
    if not args:
        print("usage: python -m repro.audit.lint <path> [path ...]")
        return 2
    findings = lint_paths(args)
    for rel, line, rule, detail in findings:
        print(f"{rel}:{line}: [{rule}] {detail}")
    print(f"repro.audit.lint: {len(findings)} finding(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
