"""CLI: ``PYTHONPATH=src python -m repro.audit --arch <name> [--reduced]``.

Prints the text report, writes ``AUDIT_<config_key>.json`` (report + the
static plan/schedule/arena tables) under ``--out``, and exits nonzero iff
any pass records an error-severity violation. ``--mutate`` seeds a named
violation (repro.audit.mutations) — CI uses it to prove the lane bites:

    python -m repro.audit --arch tinyllama-1.1b --reduced            # clean
    python -m repro.audit --arch tinyllama-1.1b --reduced \\
        --mutate drop-donation                                       # rc=1

``--mesh DxM`` audits the sharded build: it must be parsed BEFORE jax is
imported so the host-platform device count can be forced (same idiom as
launch/dryrun.py) — hence the lazy imports below.
"""
import argparse
import json
import os
import sys


def _parse_mesh(s):
    try:
        dims = tuple(int(x) for x in s.lower().split("x"))
        assert dims and all(d >= 1 for d in dims)
        return dims
    except Exception:
        raise argparse.ArgumentTypeError(
            f"--mesh wants DxM (e.g. 2x4), got {s!r}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.audit",
        description="static invariant auditor (DESIGN.md §8)")
    ap.add_argument("--arch", required=True,
                    help="arch config name (repro.configs)")
    ap.add_argument("--reduced", action="store_true",
                    help="shrink the model to the tier-1 audit size")
    ap.add_argument("--mesh", type=_parse_mesh, default=None,
                    help="audit the sharded build on a DxM host mesh "
                         "(forces that many CPU devices)")
    ap.add_argument("--mutate", default=None,
                    help="seed a named violation (see repro.audit."
                         "mutations; CI mutation check)")
    ap.add_argument("--serve", action="store_true",
                    help="also build + exercise the serving engine and "
                         "run the serve-compile pass over it")
    ap.add_argument("--passes", default=None,
                    help="comma-separated subset of passes to run")
    ap.add_argument("--out", default=".",
                    help="directory for AUDIT_<config_key>.json "
                         "(default .)")
    ap.add_argument("--no-json", action="store_true",
                    help="skip the JSON artifact")
    args = ap.parse_args(argv)

    if args.mesh:
        n = 1
        for d in args.mesh:
            n *= d
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={n}")
        os.environ.setdefault("JAX_PLATFORMS", "cpu")

    from repro.audit import passes as _passes  # noqa: F401  (registers)
    from repro.audit.registry import run_passes
    from repro.audit.targets import build_context

    only = args.passes.split(",") if args.passes else None
    ctx = build_context(args.arch, reduced=args.reduced,
                        mesh_shape=args.mesh, mutate=args.mutate,
                        serve=args.serve)
    report = run_passes(ctx, only=only)

    print(report.render())
    if not args.no_json:
        payload = report.to_dict()
        payload["meta"] = ctx.meta()
        payload["tables"] = ctx.tables()
        # keyed by config_key (arch + -reduced/-mesh) so the CI audit lane
        # can run several builds of one arch into the same artifact dir
        path = os.path.join(args.out, f"AUDIT_{ctx.config_key}.json")
        os.makedirs(args.out or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, default=str)
        print(f"wrote {path}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
