"""Logical-axis sharding rules: param-path regex -> PartitionSpec.

The mesh has physical axes ("pod", "data", "model") (pod optional). Logical
mapping (see DESIGN.md §6):
  * batch            -> ("pod", "data")      activations
  * tensor-parallel  -> "model"              heads / ffn hidden / vocab / experts
  * fsdp             -> "data"               the non-TP dim of every >=2D param
  * pod              -> pure DP (params replicated; optimizer state may add
                        "pod" sharding via ZeRO-1 flag)

Specs are derived from the param path name + trailing dims, so stacked
(scan-over-layers) leading dims are automatically replicated. A contextvar
mesh makes `constrain` a no-op on plain CPU tests (no mesh active), so model
code can sprinkle constraints unconditionally.
"""
from __future__ import annotations

import contextlib
import contextvars
import re
from typing import Any, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_rep: bool = True):
    """`jax.shard_map` across JAX versions (0.4.x only has the experimental
    spelling; same semantics for the keyword form used here).

    check_rep=False disables the per-primitive replication check — required
    whenever the body contains a `pallas_call` (no replication rule exists
    for it; the kernels/sharded.py wrappers pass it explicitly). Newer JAX
    renamed the flag `check_vma`; both spellings are tried.
    """
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    for flag in ("check_rep", "check_vma"):
        try:
            return impl(f, **kw, **{flag: check_rep})
        except TypeError:
            continue
    return impl(f, **kw)


def set_mesh(mesh: Mesh):
    """Ambient-mesh context manager across JAX versions.

    `jax.set_mesh` only exists in newer JAX; 0.4.x spells it
    `jax.sharding.use_mesh`, and before that the Mesh object itself is the
    context manager. All three make `mesh` the ambient mesh for named-axis
    sharding constraints, which is all this codebase needs.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    use_mesh = getattr(jax.sharding, "use_mesh", None)
    if use_mesh is not None:
        return use_mesh(mesh)
    return mesh


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    token = _MESH.set(mesh)
    try:
        with set_mesh(mesh):
            yield mesh
    finally:
        _MESH.reset(token)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


def batch_axes(mesh: Optional[Mesh] = None):
    mesh = mesh or current_mesh()
    if mesh is not None and "pod" in mesh.axis_names:
        return ("pod", "data")
    return ("data",)


def constrain(x, *spec):
    """with_sharding_constraint if a mesh is active, else identity.

    Spec entries may be logical names: "batch" expands to ("pod","data") when
    the pod axis exists.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    resolved = tuple(batch_axes(mesh) if s == "batch" else s for s in spec)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# Param partition rules
# ---------------------------------------------------------------------------
# Each rule: (path regex, spec for the TRAILING dims). Leading (stack) dims
# are padded with None. "fsdp" -> "data", "tp" -> "model".
_RULES = [
    # embeddings: (vocab, d_model) — vocab on TP, d on FSDP
    (r"(^|/)(emb|lm_head)$", ("tp", "fsdp")),
    (r"pos_emb$", (None, "fsdp")),
    # attention projections
    (r"wqkv$", ("fsdp", "tp")),
    (r"w[qkv]$", ("fsdp", "tp")),
    (r"wo$", ("tp", "fsdp")),
    # mlp
    (r"w_(gate|in)$", ("fsdp", "tp")),
    (r"w_out$", ("tp", "fsdp")),
    # moe experts: (E, d, f) / (E, f, d) — experts on TP (EP), d on FSDP
    (r"experts_(gate|in)$", ("tp", "fsdp", None)),
    (r"experts_out$", ("tp", None, "fsdp")),
    (r"router$", ("fsdp", None)),
    # mamba (split per-component projections — see models/ssm.py)
    (r"in_proj/(z|x|dt)$", ("fsdp", "tp")),
    (r"in_proj/(B|C)$", ("fsdp", None)),
    (r"out_proj$", ("tp", "fsdp")),
    (r"conv_w/x$", (None, "tp")),
    (r"conv_w/(B|C)$", None),
    (r"(A_log|dt_bias|skip_d)$", ("tp",)),
    # small vectors / scalars: replicated
    (r"(scale|bias|b)$", None),
]


def normalize_path(keystr: str) -> str:
    """jax keystr "['a']['b'].k" -> "/a/b/k" for regex rules."""
    s = re.sub(r"\['([^']+)'\]", r"/\1", keystr)
    s = s.replace(".", "/").replace("[", "/").replace("]", "")
    return s


def rule_for_path(path: str):
    """Raw logical trailing-dims rule for a param path (or None)."""
    path = normalize_path(path)
    for pattern, trailing in _RULE_OVERRIDES + _RULES:
        if re.search(pattern, path):
            return trailing
    return None


def resolve_rule(trailing, ndim: int, shape, mesh: Optional[Mesh]) -> P:
    """Logical trailing rule -> physical PartitionSpec with divisibility."""
    mesh = mesh or current_mesh()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}

    def physical(logical, dim_size):
        ax = {"tp": "model", "fsdp": "data"}.get(logical, logical)
        if ax is None:
            return None
        size = axis_sizes.get(ax, 1)
        if dim_size is not None and size > 1 and dim_size % size != 0:
            return None
        return ax

    if trailing is None:
        return P()
    trailing = trailing[-ndim:] if ndim < len(trailing) else trailing
    pad = (None,) * (ndim - len(trailing))
    dims = list(shape[-len(trailing):]) if shape is not None \
        else [None] * len(trailing)
    resolved = tuple(physical(t, d) for t, d in zip(trailing, dims))
    return P(*(pad + resolved))


_RULE_OVERRIDES: list = []


def set_rule_overrides(overrides):
    """Prepend (regex, trailing-rule) pairs to the param rules — the per-arch
    sharding-strategy knob used by the §Perf hillclimbs (e.g. llama4's
    activation-stationary MoE)."""
    global _RULE_OVERRIDES
    _RULE_OVERRIDES = list(overrides or [])


def spec_for_path(path: str, ndim: int, mesh: Optional[Mesh] = None,
                  shape=None) -> P:
    """Map a param path + shape to a PartitionSpec (physical axis names)."""
    path = normalize_path(path)
    mesh = mesh or current_mesh()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}

    def physical(logical, dim_size):
        ax = {"tp": "model", "fsdp": "data"}.get(logical, logical)
        if ax is None:
            return None
        size = axis_sizes.get(ax, 1)
        if dim_size is not None and size > 1 and dim_size % size != 0:
            return None                      # indivisible -> replicate
        return ax

    for pattern, trailing in _RULE_OVERRIDES + _RULES:
        if re.search(pattern, path):
            if trailing is None:
                return P()
            trailing = trailing[-ndim:] if ndim < len(trailing) else trailing
            pad = (None,) * (ndim - len(trailing))
            dims = list(shape[-len(trailing):]) if shape is not None \
                else [None] * len(trailing)
            resolved = tuple(physical(t, d) for t, d in zip(trailing, dims))
            return P(*(pad + resolved))
    return P()                               # default: replicated


def partition_specs(params: Any, mesh: Optional[Mesh] = None) -> Any:
    """Pytree of PartitionSpecs matching `params` (arrays or ShapeDtypeStructs)."""
    def one(path, leaf):
        return spec_for_path(jax.tree_util.keystr(path), leaf.ndim,
                             mesh, leaf.shape)
    return jax.tree_util.tree_map_with_path(one, params)


def logical_axis_rules():
    return {"tp": "model", "fsdp": "data", "batch": ("pod", "data")}


def named_shardings(params: Any, mesh: Mesh) -> Any:
    specs = partition_specs(params, mesh)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))
