from repro.distributed.sharding import (
    logical_axis_rules, partition_specs, constrain, mesh_context,
    current_mesh, spec_for_path,
)

__all__ = [
    "logical_axis_rules", "partition_specs", "constrain", "mesh_context",
    "current_mesh", "spec_for_path",
]
