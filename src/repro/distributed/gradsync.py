"""Cross-pod gradient synchronization with int8 compression.

The pod<->pod link (DCN) is the slow hop in a multi-pod mesh; gradients
crossing it are the dominant cross-pod traffic. We quantize each gradient
leaf to int8 with a per-leaf absmax scale before the cross-pod all-reduce and
dequantize after: 4x less DCN traffic for a quantization error well below
SGD noise (Dettmers 2022 lineage; error feedback optional per-step because
the residual is re-quantized every step anyway).

Implementation: a fully-manual shard_map over ALL mesh axes — each device
holds its (data, model)-shard of the fp32 gradient, quantizes locally, psums
the int32-accumulated int8 payload over "pod" only, and rescales. Local
shards stay local; only the pod axis moves bytes.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import partition_specs, shard_map

PyTree = Any


def _quantize_psum(g: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    # accumulate in int16 across pods: exact for up to 258 pods
    # (258 * 127 < 32767), and HALF the wire bytes of an fp32 all-reduce
    # (int32 accumulation would silently nullify the compression).
    qsum = jax.lax.psum(q.astype(jnp.int16), "pod")
    npods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
    return qsum.astype(jnp.float32) * scale / npods


def int8_psum_grads(grads: PyTree, mesh) -> PyTree:
    """Mean over the pod axis with int8 on-the-wire representation."""
    specs = partition_specs(grads, mesh)

    def sync(*leaves):
        return tuple(_quantize_psum(g) for g in leaves)

    leaves, treedef = jax.tree_util.tree_flatten(grads)
    spec_leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    synced = shard_map(
        sync, mesh=mesh,
        in_specs=tuple(spec_leaves),
        out_specs=tuple(spec_leaves))(*leaves)
    return jax.tree_util.tree_unflatten(treedef, synced)
