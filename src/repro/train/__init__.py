from repro.train.state import TrainState
from repro.train.step import make_train_step, make_dmd_step, resolve_grad_accum
from repro.train.loop import Trainer

__all__ = ["TrainState", "make_train_step", "make_dmd_step",
           "resolve_grad_accum", "Trainer"]
