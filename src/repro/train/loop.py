"""Host-side training loop: DMD schedule, checkpointing, fault tolerance.

The loop is deliberately thin: all math lives in jitted steps. Host-side
responsibilities:
  * the DMD schedule via DMDAccelerator: the fused train step derives every
    group's (warmup / phase / cooldown / m-window) position from the step
    index in-trace; the loop only decides WHICH groups' windows closed
    (acc.apply_groups) and dispatches the jump masked to those groups —
    with staggered phases that is at most one group's jump spike per step,
  * controller mode (dmd.controller.enabled): the dispatched jump is the
    LOSS-GATED step (accept / scale-back / bit-exact rollback on a held-out
    microbatch — core/controller.py, DESIGN.md §5); the loop only plumbs
    the eval batch, all gating happens in-trace,
  * checkpoint cadence + atomic save + resume (bit-exact, tested),
  * preemption (SIGTERM) -> save-and-exit,
  * failure injection for tests (raise at step k, resume from disk).

Determinism contract: the data iterator is a pure function of the step index
(see repro.data), so a restarted worker replays identical batches — the
straggler/elastic-restart story depends on this.
"""
from __future__ import annotations

import signal
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.core.accelerator import DMDAccelerator
from repro.core import snapshots as snap
from repro.optim import make_optimizer
from repro.train.state import TrainState
from repro.train.step import (make_dmd_step, make_train_step,
                              state_resident, state_unresident)

PyTree = Any


class Trainer:
    def __init__(self, model, acfg, *, mesh=None, loss_fn=None,
                 checkpoint_dir: Optional[str] = None,
                 fail_at_step: Optional[int] = None,
                 val_batch: Optional[PyTree] = None,
                 on_publish: Optional[Callable] = None):
        self.model = model
        self.acfg = acfg
        self.mesh = mesh
        # Serving publish hook (DESIGN.md §10): called as
        # ``on_publish(params_leafwise, version)`` after every jump the
        # controller did NOT reject (every jump when the controller is
        # off) — the trainer side of the live weight hot-swap. The params
        # are exported leaf-wise (acc.params_leafwise), so the hook can
        # feed a ParamStore / WeightsChannel directly.
        self.on_publish = on_publish
        # One accelerator — hence ONE LeafPlan dispatch table — shared by the
        # schedule, the fused train step and the jump (DESIGN.md §3).
        self.acc = DMDAccelerator(
            acfg.dmd, mesh=mesh,
            stack_dims=(model.param_stack_dims()
                        if hasattr(model, "param_stack_dims") else None))
        self.opt = make_optimizer(acfg.optimizer)
        self.checkpoint_dir = checkpoint_dir or acfg.train.checkpoint_dir
        self.fail_at_step = fail_at_step
        self._preempted = False

        self.train_step = jax.jit(
            make_train_step(model, acfg, mesh=mesh, loss_fn=loss_fn,
                            acc=self.acc),
            donate_argnums=(0,))
        # `groups` static: each distinct jumping-group subset compiles its
        # own (small) jump program — the staggered-schedule spike killer.
        # With the controller on, the jitted jump also carries the in-trace
        # loss gate (extra eval_batch argument — train/step.py).
        self.controller_on = self.acc.controller_on
        self.dmd_step = jax.jit(make_dmd_step(acfg, mesh=mesh, acc=self.acc,
                                              model=model, loss_fn=loss_fn),
                                donate_argnums=(0,),
                                static_argnames=("groups",))
        # Persistent validation split for the jump controller's gate
        # (ISSUE 9): carved ONCE at trainer init, NEVER drawn from the
        # training iterator — a gate scored on training rows consumes a
        # training batch (shifting the stream) and happily accepts
        # train-overfit jumps. Callers may hand in their own split; token
        # models get a deterministic carve from the reserved validation
        # stream fold (repro.data.tokens.validation_batch).
        self.val_batch = None
        if self.controller_on:
            self.val_batch = (val_batch if val_batch is not None
                              else self._carve_val_batch())

    def _publish(self, state, dmd_info, version: int) -> None:
        """Fire the serving publish hook for a non-rejected jump. The
        controller's REJECT branch restored the pre-jump state bit-exactly
        (publishing it would be a no-op swap); ACCEPT and SCALED both
        changed the weights being served, so both publish. With the
        controller off every jump publishes."""
        if self.controller_on:
            from repro.core import controller as ctrl_mod
            outcome = dmd_info.get("ctrl_outcome")
            if outcome is not None and int(outcome) == ctrl_mod.REJECT:
                return
        self.on_publish(self.acc.params_leafwise(state.params), version)

    def _carve_val_batch(self) -> Optional[PyTree]:
        """Default validation split for vocab models (the synthetic LM
        stream): one batch at the reserved VAL_FOLD stream offset, shaped
        exactly like a training batch. Models without a vocab (e.g. the
        bench MLP adapters) return None — those callers pass
        ``Trainer(val_batch=...)`` or ``fit(eval_batch=...)`` explicitly."""
        mc = getattr(self.model, "cfg", None)
        vocab = getattr(mc, "vocab_size", None) if mc is not None else None
        if not vocab:
            return None
        from repro.data.tokens import validation_batch
        tc = self.acfg.train
        kw = {}
        if getattr(mc, "mrope_sections", None):
            kw["mrope"] = True
        if getattr(mc, "family", "") == "encdec":
            kw["frames"] = (mc.encoder_seq_len, mc.d_model)
        batch = validation_batch(tc.seed, tc.global_batch, tc.seq_len,
                                 vocab, **kw)
        if self.mesh is not None:
            from repro.launch.inputs import gate_batch_shardings
            batch = jax.device_put(batch,
                                   gate_batch_shardings(batch, self.mesh))
        return batch

    # -- state ---------------------------------------------------------------
    def init_state(self, key=None) -> TrainState:
        params = self.model.init(key if key is not None
                                 else jax.random.PRNGKey(self.acfg.train.seed))
        opt_state = self.opt.init(params)
        bufs = self.acc.init(params) if self.acfg.dmd.enabled else None
        grams = self.acc.init_grams(bufs)
        ctrl = self.acc.init_controller()
        return TrainState(params, opt_state, jnp.zeros((), jnp.int32), bufs,
                          grams, ctrl)

    # -- checkpointing --------------------------------------------------------
    def save(self, state: TrainState, step: int):
        """Checkpoints are always written in the LEAF-WISE layout (arenas
        unpacked into per-leaf buffers/Grams — DESIGN.md §7): on-disk
        format is identical across dmd.arena on/off, so old checkpoints
        load into arena runs and vice versa."""
        if not self.checkpoint_dir:
            return
        from repro.checkpoint import save_checkpoint
        save_checkpoint(self.checkpoint_dir, self.acc.state_leafwise(state),
                        step, keep=self.acfg.train.keep_checkpoints)

    def restore(self, state_like: Optional[TrainState] = None
                ) -> Optional[TrainState]:
        if not self.checkpoint_dir:
            return None
        from repro.checkpoint import restore_checkpoint
        template = state_like if state_like is not None else self.init_state()
        # Leaf-wise on disk (see save): unpack the template's arenas so the
        # manifest paths line up, restore, then re-pack at the end.
        template = self.acc.state_leafwise(template)
        state = restore_checkpoint(self.checkpoint_dir, template,
                                   mesh=self.mesh)
        if state is None:
            return None
        if self.mesh is not None:
            # Elastic restore: re-place every restored leaf against the
            # CURRENT mesh's shardings BEFORE any computation touches the
            # state — a checkpoint written on one topology restores onto
            # any other, and the arena-unpacked template can leave buffer
            # leaves committed to the mesh while Gram leaves are
            # single-device (shard_map outputs vs plain slices), which
            # would poison the first jit below with mixed placements. DMD
            # buffer/Gram specs come from the plan table.
            from repro.launch.inputs import shardings_of, state_specs
            sh = shardings_of(
                state_specs(state, self.mesh,
                            plans=self.acc.plans_for(state.params)),
                self.mesh)
            state = jax.tree_util.tree_map(
                lambda x, s: None if x is None else jax.device_put(x, s),
                state, sh, is_leaf=lambda x: x is None)
        if self.acc.streaming and state.dmd_gram is not None:
            # Pre-streaming checkpoints restore the template's all-zero
            # Grams; rebuild those from the restored buffers so a mid-window
            # resume never applies DMD on a Gram with zeroed rows. Template
            # buffer/Gram shapes come from the same plan table that wrote
            # the checkpoint, so mixed-m (per-group) states round-trip, and
            # every group's window position is re-derived from the restored
            # step index — a mid-window resume with heterogeneous m is
            # bit-exact (tests/test_trainer.py).
            state = state._replace(dmd_gram=snap.recompute_grams(
                state.dmd_gram, state.dmd_buffers, self.acfg.dmd,
                self.acc.plans_for(state.params)))
        return self.acc.state_arenaize(state)

    def _install_preempt_handler(self):
        def handler(signum, frame):
            self._preempted = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass                          # not on the main thread (tests)

    # -- the loop ---------------------------------------------------------------
    def fit(self, batches: Iterator[PyTree], steps: int,
            state: Optional[TrainState] = None,
            log_every: int = 0, on_metrics: Optional[Callable] = None,
            eval_batch: Optional[PyTree] = None) -> TrainState:
        """`eval_batch` (controller mode only) is the held-out microbatch
        the loss gate scores jumps on. None falls back to the trainer's
        persistent validation split (carved at init, disjoint from the
        training stream and step-independent — a preemption-exact resume
        sees the identical gate batch); with ``controller.val_gate=True``
        the validation split is preferred even over an explicit
        `eval_batch`. The gate NEVER draws from the training iterator.
        Sliced to controller.eval_rows rows (clamped to the batch size)."""
        self._install_preempt_handler()
        resumed = self.restore(state)
        if resumed is not None:
            state = resumed
        elif state is None:
            state = self.init_state()
        # Arena-native residency (DESIGN.md §7, train/step.py): for the
        # duration of the loop the packed leaves' params and elementwise
        # optimizer moments live in the bucket buffers; expanded back
        # before returning, so callers (and checkpoints, via
        # state_leafwise in save) never see the wrapper layout.
        state = state_resident(self.acc, self.acfg, state)
        start_step = int(state.step)
        ckpt_every = self.acfg.train.checkpoint_every

        if self.controller_on:
            ccfg = self.acfg.dmd.controller
            # The ISSUE 9 bugfix: the old fallback `eval_batch =
            # next(batches)` consumed (and scored on) the next TRAINING
            # batch — the gate then measured training-trajectory fit, not
            # generalization, and the stream position shifted by one.
            if getattr(ccfg, "val_gate", False) and self.val_batch is not None:
                eval_batch = self.val_batch
            elif eval_batch is None:
                eval_batch = self.val_batch
            if eval_batch is None:
                raise ValueError(
                    "controller mode needs a gate batch disjoint from the "
                    "training stream: pass fit(eval_batch=...) or "
                    "Trainer(val_batch=...) (vocab models carve one "
                    "automatically at init)")
            rows = ccfg.eval_rows
            if rows:
                # clamp to the actual batch size — eval_rows larger than
                # the batch must not silently slice past it
                n_rows = min(int(x.shape[0]) for x in
                             jax.tree_util.tree_leaves(eval_batch))
                rows = min(int(rows), n_rows)
                eval_batch = jax.tree_util.tree_map(
                    lambda x: x[:rows], eval_batch)

        for step in range(start_step, steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            batch = next(batches)
            state, metrics = self.train_step(state, batch,
                                             jnp.asarray(step, jnp.int32))
            apply_groups = (self.acc.apply_groups(step)
                            if self.acfg.dmd.enabled else ())
            if apply_groups:
                relax = jnp.asarray(self.acc.relax_vector(step), jnp.float32)
                if self.controller_on:
                    state, dmd_info = self.dmd_step(state, relax, eval_batch,
                                                    groups=apply_groups)
                else:
                    state, dmd_info = self.dmd_step(state, relax,
                                                    groups=apply_groups)
                metrics.update(dmd_info)
                if self.on_publish is not None:
                    self._publish(state, dmd_info, step + 1)
            if log_every and step % log_every == 0:
                loss = float(metrics["loss"])
                print(f"step {step}: loss={loss:.6f}")
            if on_metrics is not None:
                on_metrics(step, metrics)
            if ckpt_every and (step + 1) % ckpt_every == 0:
                self.save(state, step + 1)
            if self._preempted:
                self.save(state, step + 1)
                print(f"preempted: checkpoint saved at step {step + 1}")
                break
        return state_unresident(self.acc, state)
