"""Jitted train / DMD steps.

train_step(state, batch, step):
  * microbatch gradient accumulation via lax.scan (per-arch grad_accum,
    resolved against the mesh so each microbatch keeps >= 1 row per batch
    shard),
  * fp32 gradient accumulators,
  * fused DMD snapshot recording, driven by the STEP INDEX: the per-group
    slot vector is computed in-trace (schedule.slots_for_step) and each
    schedule group gets its own lax.cond, so a group in warmup/phase/
    cooldown costs nothing while another group records (DESIGN.md §4). With
    dmd.streaming_gram the O(m*n) Gram row update rides in the same
    per-group cond, against params that are already resident from the
    optimizer update. The row pass is kernel-routed per leaf by the
    accelerator's LeafPlan table (DESIGN.md §3): Pallas for flat leaves,
    shard_map'd Pallas for stacked/sharded ones.
  * optional int8-compressed cross-pod gradient sync (distributed/gradsync).

dmd_step(state, relax, groups=None): the paper's jump, masked to the
schedule group(s) whose window closed (`groups` is a STATIC tuple — the
Trainer jits it as a static argname, so a staggered schedule compiles one
small program per jumping group instead of one whole-tree spike). With the
streaming Gram carried in TrainState it is pure O(m^3) coefficient algebra
+ one combine pass per jumped leaf; without it (the
cfg.streaming_gram=False A/B baseline) it recomputes the full O(m^2*n)
Gram. Both steps share the same accelerator instance (hence the same plan
table) — pass `acc=` to avoid rebuilding it.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import leafplan, schedule as sched_mod
from repro.core import snapshots as snap
from repro.core.accelerator import DMDAccelerator, _none_like, jump_tree
from repro.distributed.sharding import constrain
from repro.optim import apply_updates, make_optimizer
from repro.train.state import TrainState

PyTree = Any


def resolve_grad_accum(acfg, mesh, global_batch: int) -> int:
    """Largest accum factor <= config that keeps >=1 row per batch shard."""
    ga = max(acfg.parallel.grad_accum, 1)
    shards = 1
    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        shards = sizes.get("data", 1) * sizes.get("pod", 1)
    while ga > 1 and (global_batch // ga) % shards != 0:
        ga //= 2
    return max(min(ga, global_batch // shards), 1)


def _accelerator_for(model, acfg, mesh, acc: Optional[DMDAccelerator]
                     ) -> DMDAccelerator:
    """Shared accelerator (and hence LeafPlan table) for the step builders:
    use the caller's, or build one wired to the model's structural stack-dim
    annotation."""
    if acc is not None:
        return acc
    sd = None
    if model is not None and hasattr(model, "param_stack_dims"):
        sd = model.param_stack_dims()
    return DMDAccelerator(acfg.dmd, mesh=mesh, stack_dims=sd)


def make_train_step(model, acfg, *, mesh=None, global_batch=None,
                    loss_fn: Callable = None, donate: bool = True,
                    acc: Optional[DMDAccelerator] = None):
    """Returns train_step(state, batch, step) -> (state, metrics).

    `step` is the (traced) optimizer-step index — the per-group DMD slot
    vector is derived from it in-trace, replacing the old single `dmd_slot`
    scalar (which could only express one global window)."""
    opt = make_optimizer(acfg.optimizer)
    gb = global_batch or acfg.train.global_batch
    ga = resolve_grad_accum(acfg, mesh, gb)
    dmd_on = acfg.dmd.enabled
    acc = _accelerator_for(model, acfg, mesh, acc)
    streaming_on = acc.streaming
    _loss = loss_fn or (lambda p, b: model.loss(p, b)[0])

    def train_step(state: TrainState, batch: PyTree, step) -> tuple:
        params = state.params

        def one_loss(p, mb):
            return _loss(p, mb)

        if ga > 1:
            def reshape_mb(x):
                return x.reshape((ga, x.shape[0] // ga) + x.shape[1:])
            mbs = jax.tree_util.tree_map(reshape_mb, batch)
            mbs = jax.tree_util.tree_map(
                lambda x: constrain(x, None, "batch"), mbs)

            def mb_step(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(one_loss)(params, mb)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(mb_step, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / ga, gsum)
            loss = lsum / ga
        else:
            loss, grads = jax.value_and_grad(one_loss)(params, batch)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.float32), grads)

        if acfg.parallel.grad_compression == "int8" and mesh is not None \
                and "pod" in mesh.axis_names:
            from repro.distributed.gradsync import int8_psum_grads
            grads = int8_psum_grads(grads, mesh)

        updates, opt_state = opt.update(grads, state.opt_state, params,
                                        state.step)
        params = apply_updates(params, updates)

        buffers, grams = state.dmd_buffers, state.dmd_gram
        if dmd_on and buffers is not None:
            streaming = streaming_on and grams is not None
            plans = acc.plans_for(params)       # trace-time, cached
            slots = sched_mod.slots_for_step(acc.groups, step)

            # One cond per schedule group: group gi's leaves are written
            # only while gi records (its slot >= 0); other groups' leaves
            # are compile-time pass-throughs inside the branch, so XLA
            # sees the same single-cond program as before for one group.
            for gi in range(len(acc.groups)):
                def write(args, gi=gi):
                    bufs, g = args
                    slot = jnp.maximum(slots[gi], 0)
                    bufs = snap.record(bufs, params, slot, plans, group=gi)
                    if streaming:
                        g = snap.update_grams(g, bufs, params, slot,
                                              acfg.dmd, plans, group=gi)
                    return bufs, g
                buffers, grams = jax.lax.cond(slots[gi] >= 0, write,
                                              lambda a: a, (buffers, grams))

        new_state = TrainState(params, opt_state, state.step + 1, buffers,
                               grams)
        gnorm = jnp.sqrt(sum(jnp.vdot(g, g)
                             for g in jax.tree_util.tree_leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def reset_opt_state_after_jump(opt, opt_state, params, plans, groups,
                               n_groups):
    """Post-jump optimizer-moment reset.

    `groups` is the set of group indices whose moments should reset
    (callers filter by each group's ``reset_opt`` flag —
    DMDAccelerator.reset_groups). When that covers every group this is the
    legacy full ``opt.init`` — bit-exact with the pre-refactor behavior.
    Otherwise (staggered schedule, or reset-exempt groups), reset ONLY
    those groups' leaves' entries in each params-shaped field of the
    optimizer state: a staggered jump must not clobber the moments the
    other groups are accumulating mid-window. Fields that do not mirror
    the param pytree (scalar counters, empty states) are kept as-is in the
    masked case.
    """
    if groups is None or len(frozenset(groups)) >= n_groups:
        return opt.init(params)
    fresh = opt.init(params)
    pdef = jax.tree_util.tree_structure(params)
    gset = frozenset(int(g) for g in groups)

    def merge(old_field, new_field):
        if jax.tree_util.tree_structure(old_field) != pdef:
            return old_field
        return jax.tree_util.tree_map(
            lambda plan, o, n: n if (plan is not None and plan.group in gset)
            else o,
            plans, old_field, new_field, is_leaf=leafplan.is_plan_leaf)

    if jax.tree_util.tree_structure(opt_state) == pdef:
        return merge(opt_state, fresh)            # momentum-style state
    if isinstance(opt_state, tuple):              # NamedTuple of field trees
        return type(opt_state)(*(merge(o, n)
                                 for o, n in zip(opt_state, fresh)))
    return opt_state


def make_dmd_step(acfg, *, mesh=None, acc: Optional[DMDAccelerator] = None,
                  model=None):
    """Returns dmd_step(state, relax, groups=None) -> (state, info): the
    paper's jump. `groups` is a STATIC tuple of schedule-group indices to
    jump (the Trainer passes acc.apply_groups(step) and jits it as a static
    argname); None jumps every group — the legacy single-window call.
    `relax` is a scalar or the per-group vector from acc.relax_vector."""
    cfg = acfg.dmd
    opt = make_optimizer(acfg.optimizer)
    acc = _accelerator_for(model, acfg, mesh, acc)
    streaming_on = acc.streaming

    def dmd_step(state: TrainState, relax,
                 groups: Optional[Sequence[int]] = None) -> tuple:
        if state.dmd_buffers is None:
            return state, {"mean_rank": jnp.zeros((), jnp.float32)}
        grams = state.dmd_gram
        if grams is None or not streaming_on:
            grams = _none_like(state.dmd_buffers)
        plans = acc.plans_for(state.params)
        params, mean_rank = jump_tree(cfg, plans, state.params,
                                      state.dmd_buffers, grams, relax,
                                      groups=groups)
        opt_state = state.opt_state
        # the jump teleports the jumped groups' weights; reset those
        # groups' moments — unless the group opts out (sched.reset_opt)
        reset = acc.reset_groups(groups)
        if reset:
            opt_state = reset_opt_state_after_jump(
                opt, state.opt_state, params, plans, reset, acc.n_groups)
        new_state = TrainState(params, opt_state, state.step,
                               state.dmd_buffers, state.dmd_gram)
        return new_state, {"mean_rank": mean_rank}

    return dmd_step
